//! `specactor` — the leader entrypoint / CLI.
//!
//! ```text
//! specactor plan      --batch 16384 --gpus 256 --accept 0.8 --method draft_small
//! specactor ladder    [--moe]
//! specactor simulate  --trace dapo --step 140 [--policy specactor] [--full]
//! specactor fit       [--artifacts artifacts]   # fit affine costs from the real runtime
//! specactor rollout   --requests 4 --budget 32  # real-engine rollout
//! specactor serve     --rate 20 --arrival poisson|bursty [--smoke]  # continuous batching
//! ```

use std::path::PathBuf;
use std::process::exit;

use specactor::coordinator::global::{plan_initial, rollout, GlobalConfig};
use specactor::coordinator::{RaceArbiter, Reconfigurator};
use specactor::drafter::{DraftCorpus, DraftMethod};
use specactor::engine::{EngineConfig, Request, SlotPlan, VerifyDiscipline, Worker};
use specactor::ladder::Ladder;
use specactor::obs::{chrome_trace, MetricsExporter};
use specactor::planner::costmodel::{AffineCost, CostModel};
use specactor::planner::plan::{search, PlanInput};
use specactor::runtime::Runtime;
use specactor::serve::{
    drive_cluster_open_loop, drive_open_loop, Batcher, ChaosEngine, Cluster, FaultPlan,
    OpenLoopReport, Priority, Replanner, ServeEngine, ServeMetrics, SyntheticEngine, WorkerHealth,
};
use specactor::sim::{scaled, simulate_step, ArrivalProcess, Policy, TraceConfig};
use specactor::util::benchkit::fmt_s;
use specactor::util::cli::Args;
use specactor::util::Rng;

fn usage() -> ! {
    eprintln!(
        "usage: specactor <plan|ladder|simulate|fit|rollout|serve> [options]\n\
         serve: continuous-batching rollout server with open-loop arrivals\n\
           --rate R          mean arrival rate, requests/s (default 20)\n\
           --arrival KIND    poisson | bursty (default poisson)\n\
           --requests N      total requests to offer (default 16)\n\
           --budget B        per-request token budget (default 24)\n\
           --capacity C      concurrent KV slots, rounded to a bucket (default 4)\n\
           --queue-cap Q     admission queue bound, backpressure beyond (default 64)\n\
           --drafter D       sam | ngram | draft_small | draft_mid | auto (default sam;\n\
                             auto = ladder picks per occupancy; applied, not advisory)\n\
           --reconfig-period N  run Algorithm 2 every N rounds (0 = off, default 0)\n\
           --fon-race        race tail stragglers in-process (Algorithm 3): fork the\n\
                             worst below-mean slot into idle slots under next-best\n\
                             draft methods; first finisher wins, admissions preempt\n\
           --corpus          wave-global online draft learning: harvest every\n\
                             accepted token into a shared corpus, publish immutable\n\
                             snapshots to the drafters at round boundaries, seed new\n\
                             admissions' token drafters from them, and feed measured\n\
                             acceptance into the planner priors; with --workers N the\n\
                             corpus is shared across all workers. Token-identical:\n\
                             seeding changes proposals, never verified outputs\n\
           --vanilla         disable speculation (plain decode rounds)\n\
           --overlap         overlapped execution: prefetch next-round drafts behind\n\
                             the fused verify step, stage KV double-buffered, and run\n\
                             admissions/replanning off the decode critical path;\n\
                             token-identical to the sequential default (A/B baseline)\n\
           --grouped-verify  pre-fusion A/B: one target step per (method, window)\n\
                             plan group instead of one fused ragged step per round\n\
           --workers N       serve with N engine workers behind one global queue\n\
                             (heartbeat supervision, slot migration, WorkerFatal\n\
                             recovery by evacuation; default 1 = single-worker loop)\n\
           --chaos SPEC      seeded fault injection; sites (all optional):\n\
                             seed=7,step=0.05,drafter=0.02,slot=0.01,fork=0.05,\n\
                             prefetch=0.02,worker=0.01,transport=0.05,pause=40\n\
                             (per-round rates; worker = kill a worker mid-wave, at\n\
                             most once per worker; transport = flip a bit in a\n\
                             migration frame; pause = weight-update period, rounds)\n\
           --metrics-addr A  serve Prometheus text at http://A/metrics (+ /healthz),\n\
                             e.g. 127.0.0.1:9464; snapshot-based, never blocks ticks\n\
           --trace-out FILE  write per-phase round spans + fault post-mortems as\n\
                             chrome://tracing JSON (load in chrome://tracing/Perfetto)\n\
           --tick-pace-us N  sleep N us of real time per tick (0 = off) so external\n\
                             scrapers can watch a smoke run; virtual time unaffected\n\
           --metrics-hold-ms N  keep the scrape endpoint up N ms after the run ends\n\
                             with the final snapshot published (CI scrape window)\n\
           --smoke           synthetic engine, no artifacts needed (CI)\n\
         see README / PERF.md for the remaining subcommands' options"
    );
    exit(2)
}

fn main() {
    let mut args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            usage()
        }
    };
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "plan" => cmd_plan(args),
        "ladder" => cmd_ladder(args),
        "simulate" => cmd_simulate(args),
        "fit" => cmd_fit(args),
        "rollout" => cmd_rollout(args),
        "serve" => cmd_serve(args),
        _ => usage(),
    }
}

/// Deterministic priority mix for generated open-loop traffic: mostly
/// batch, with interactive and background minorities to exercise the
/// queue's lanes.
fn prio_for(id: u64) -> Priority {
    match id % 8 {
        0 => Priority::Interactive,
        7 => Priority::Background,
        _ => Priority::Batch,
    }
}

fn print_serve_summary<E: ServeEngine>(engine: &str, b: &Batcher<E>, rep: &OpenLoopReport) {
    let m: &ServeMetrics = &b.metrics;
    println!(
        "serve[{engine}]: offered {}  rejected {}  invalid {}  completed {}  in {} ({} ticks)",
        rep.offered,
        rep.rejected,
        m.invalid,
        m.completed,
        fmt_s(rep.elapsed_s),
        rep.ticks
    );
    println!(
        "  tokens {}  sustained {:.1} tok/s  mean occupancy {:.2} (peak {})",
        m.tokens,
        m.tokens_per_second(rep.elapsed_s),
        m.mean_occupancy(),
        b.slots.high_water
    );
    println!(
        "  latency p50 {}  p99 {}  mean queue wait {}",
        fmt_s(m.latency_p50_s()),
        fmt_s(m.latency_p99_s()),
        fmt_s(m.mean_queue_wait_s())
    );
    println!(
        "  replans {}  plan: method={} w={} (occupancy bucket {}, modelled speedup {:.2}x)",
        m.replans,
        b.replan.plan.method,
        b.replan.plan.window,
        b.replan.plan.bucket,
        b.replan.plan.modelled_speedup
    );
    if let Some(rc) = &b.reconfig {
        println!(
            "  reconfig (Algorithm 2): every {} rounds, {} firings, {} slot plans rewritten",
            rc.period(),
            m.reconfigs,
            m.reconfigured_slots
        );
    }
    if b.race.is_some() {
        let by_method: Vec<String> = m
            .race_wins_by_method
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect();
        println!(
            "  fon races (Algorithm 3): {} launched ({} replicas), {} replica wins [{}], \
             {} replicas cancelled ({} rounds wasted)",
            m.races,
            m.race_launches,
            m.race_wins,
            by_method.join(" "),
            m.race_cancelled_replicas,
            m.race_wasted_rounds
        );
    }
    if m.prefetch_hits > 0 || m.prefetch_rollbacks > 0 {
        println!(
            "  overlap: {} prefetch hits, {} rollbacks, {} draft time hidden",
            m.prefetch_hits,
            m.prefetch_rollbacks,
            fmt_s(b.report.draft_hidden_s)
        );
    }
    println!(
        "  rejections: {} shed, {} malformed, {} retry-exhausted",
        b.queue.rejected_shed, m.invalid, b.queue.rejected_retry_exhausted
    );
    println!(
        "  faults: {} degradations ({} re-promotions), {} quarantines \
         ({} requeues, {} recoveries), {} lost",
        m.degradations, m.repromotions, m.quarantines, m.requeues, m.recoveries, m.lost
    );
    let by_method = m.method_acceptance();
    if !by_method.is_empty() {
        let parts: Vec<String> = by_method
            .iter()
            .map(|(meth, rate, acc, dr)| format!("{meth} {rate:.2} ({acc}/{dr})"))
            .collect();
        println!("  acceptance by method: {}", parts.join("  "));
    }
    if m.corpus_publishes > 0 {
        println!(
            "  corpus: {} tokens published, {} seeded admissions, {} publishes, \
             {} evictions, {} decays",
            m.corpus_tokens, m.corpus_seeds, m.corpus_publishes, m.corpus_evictions,
            m.corpus_decays
        );
    }
}

/// Wire the observability surface onto a constructed batcher: per-phase
/// span tracing (on when either flag asks for it), the Prometheus scrape
/// endpoint, and the real-time pacing sleep CI uses to scrape mid-run.
fn wire_observability<E: ServeEngine>(
    mut b: Batcher<E>,
    metrics_addr: Option<&str>,
    trace_out: Option<&str>,
    pace_us: u64,
) -> Batcher<E> {
    if metrics_addr.is_some() || trace_out.is_some() {
        b = b.with_tracing(4096);
    }
    if let Some(addr) = metrics_addr {
        match MetricsExporter::bind(addr) {
            Ok(ex) => {
                eprintln!("metrics: http://{}/metrics", ex.addr);
                b = b.with_exporter(ex);
            }
            Err(e) => {
                eprintln!("metrics exporter: {e:#}");
                exit(1);
            }
        }
    }
    if pace_us > 0 {
        b = b.with_pace(pace_us);
    }
    b
}

/// End-of-run observability: publish the final scrape snapshot (holding
/// the endpoint open for `hold_ms` so a CI scraper has a window), and
/// write the chrome://tracing export when `--trace-out` was given.
fn finish_observability<E: ServeEngine>(
    b: &Batcher<E>,
    rep: &OpenLoopReport,
    trace_out: Option<&str>,
    hold_ms: u64,
) {
    b.publish_final(rep.elapsed_s);
    if let Some(path) = trace_out {
        let Some(t) = b.tracer() else { return };
        let j = chrome_trace(&t.events(), &b.fault_dumps);
        match std::fs::write(path, j.to_string()) {
            Ok(()) => eprintln!(
                "trace: {path} ({} spans held of {} recorded, {} fault dumps)",
                t.len(),
                t.total(),
                b.fault_dumps.len()
            ),
            Err(e) => {
                eprintln!("trace write {path}: {e}");
                exit(1);
            }
        }
    }
    if hold_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    }
}

/// Injection accounting for a `--chaos` run (silent when the plan is
/// inactive, so fault-free output is unchanged).
fn print_chaos_summary<E: ServeEngine>(ce: &ChaosEngine<E>) {
    if !ce.plan.is_active() {
        return;
    }
    println!(
        "  chaos[{}]: {} faults injected ({} step, {} drafter, {} slot, {} fork, \
         {} prefetch, {} worker, {} transport), {} pauses",
        ce.plan.label(),
        ce.injected(),
        ce.injected_step,
        ce.injected_drafter,
        ce.injected_slot,
        ce.injected_fork,
        ce.injected_prefetch,
        ce.injected_worker,
        ce.injected_transport,
        ce.pauses
    );
}

/// Post-run report for a `--workers N` cluster run: global accounting,
/// the migration/evacuation/transport ledgers, and one line per worker.
fn print_cluster_summary<E: ServeEngine>(
    tag: &str,
    c: &Cluster<ChaosEngine<E>>,
    rep: &OpenLoopReport,
) {
    let cm = &c.metrics;
    println!(
        "serve[{tag} x{}]: offered {}  rejected {}  completed {}  in {} ({} ticks)",
        c.len(),
        rep.offered,
        rep.rejected,
        cm.completed,
        fmt_s(rep.elapsed_s),
        rep.ticks
    );
    let tokens: u64 = c.workers().iter().map(|b| b.metrics.tokens).sum();
    println!(
        "  tokens {}  sustained {:.1} tok/s  workers alive {}/{}",
        tokens,
        tokens as f64 / rep.elapsed_s.max(1e-9),
        c.alive(),
        c.len()
    );
    println!(
        "  cluster: {} deaths, {} last-survivor holds, evacuations {} extracted / {} salvaged \
         / {} requeued, {} dup completions dropped",
        cm.worker_deaths,
        cm.last_survivor_holds,
        cm.evac_extracted,
        cm.evac_salvaged,
        cm.evac_requeued,
        cm.dup_completions
    );
    println!(
        "  transport: {} frames, {} corruptions, {} retries, {} escalations, {} backoff ticks",
        c.transport.frames,
        c.transport.corruptions,
        c.transport.retries,
        c.transport.escalations,
        c.transport.backoff_ticks
    );
    if cm.cross_races > 0 || cm.stage_rollbacks > 0 {
        println!(
            "  cross-worker races: {} staged, {} remote wins, {} cancels, {} stage rollbacks",
            cm.cross_races, cm.cross_race_wins, cm.cross_race_cancels, cm.stage_rollbacks
        );
    }
    if cm.corpus_publishes > 0 {
        println!(
            "  corpus (shared): {} tokens published, {} seeded admissions, {} publishes, \
             {} evictions, {} decays",
            cm.corpus_tokens, cm.corpus_seeds, cm.corpus_publishes, cm.corpus_evictions,
            cm.corpus_decays
        );
    }
    for (w, b) in c.workers().iter().enumerate() {
        let health = match c.health()[w] {
            WorkerHealth::Healthy => "healthy",
            WorkerHealth::Suspect => "suspect",
            WorkerHealth::Dead => "dead",
        };
        println!(
            "  worker {w} [{health}]: completed {}  tokens {}  migrations {}>out {}<in  \
             evacuated {}  heartbeat misses {}",
            b.metrics.completed,
            b.metrics.tokens,
            cm.migrations_out[w],
            cm.migrations_in[w],
            cm.evacuations[w],
            cm.heartbeat_misses[w]
        );
        print_chaos_summary(b.engine());
    }
}

fn cmd_serve(mut args: Args) {
    let art = PathBuf::from(args.opt("artifacts", "artifacts"));
    let n = args.opt_parse("requests", 16usize);
    let mut budget = args.opt_parse("budget", 24usize);
    let rate = args.opt_parse("rate", 20.0f64);
    let arrival = args.opt("arrival", "poisson");
    let capacity = args.opt_parse("capacity", 4usize);
    let queue_cap = args.opt_parse("queue-cap", 64usize);
    let drafter = args.opt("drafter", "sam");
    let seed = args.opt_parse("seed", 7u64);
    let reconfig_period = args.opt_parse("reconfig-period", 0u64);
    let fon_race = args.flag("fon-race");
    let corpus = args.flag("corpus");
    let vanilla = args.flag("vanilla");
    let overlap = args.flag("overlap") && !vanilla;
    let grouped = args.flag("grouped-verify");
    let smoke = args.flag("smoke");
    let workers_n = args.opt_parse("workers", 1usize).max(1);
    let chaos = args.opt_maybe("chaos");
    let metrics_addr = args.opt_maybe("metrics-addr");
    let trace_out = args.opt_maybe("trace-out");
    let pace_us = args.opt_parse("tick-pace-us", 0u64);
    let hold_ms = args.opt_parse("metrics-hold-ms", 0u64);
    let discipline = if grouped { VerifyDiscipline::Grouped } else { VerifyDiscipline::Fused };
    args.finish().unwrap_or_else(|e| {
        eprintln!("{e}");
        usage()
    });
    // No --chaos means an inactive plan: ChaosEngine is then a pure
    // pass-through, so both branches keep a single engine type.
    let fplan = match chaos.as_deref().map(FaultPlan::parse).transpose() {
        Ok(p) => p.unwrap_or_default(),
        Err(e) => {
            eprintln!("bad --chaos spec: {e}");
            usage()
        }
    };

    let proc_ = match arrival.as_str() {
        // same long-run offered load as poisson at the same --rate
        "bursty" => ArrivalProcess::bursty_with_mean(rate),
        "poisson" => ArrivalProcess::Poisson { rate },
        other => {
            eprintln!("unknown arrival process {other:?}");
            usage()
        }
    };
    let mut rng = Rng::new(seed);
    let times = proc_.sample(n, &mut rng);

    if smoke {
        // hermetic path: synthetic engine, virtual 1 ms ticks — used by CI
        let arrivals: Vec<(f64, Request, Priority)> = times
            .iter()
            .enumerate()
            .map(|(i, &t)| (t, Request::new(i as u64, vec![0; 8], budget), prio_for(i as u64)))
            .collect();
        if workers_n > 1 {
            // multi-worker cluster: same seed on every engine (the
            // sampling tape is keyed by (seed, request, position), so
            // tokens are identical wherever a request lands); chaos gets
            // a per-worker stream via `for_worker`
            let batchers: Vec<_> = (0..workers_n)
                .map(|w| {
                    let mut e =
                        SyntheticEngine::new(capacity.max(1), seed).with_discipline(discipline);
                    if overlap {
                        e = e.with_overlap();
                    }
                    let e = ChaosEngine::new(e, fplan.for_worker(w));
                    let mut b = Batcher::new(e, queue_cap, Replanner::synthetic(), !vanilla);
                    if overlap {
                        b = b.with_overlap();
                    }
                    if reconfig_period > 0 && !vanilla {
                        b = b.with_reconfig(Reconfigurator::synthetic(reconfig_period));
                    }
                    b
                })
                .collect();
            let mut c = Cluster::new(batchers, queue_cap);
            if fon_race && !vanilla {
                c = c.with_cross_racing();
            }
            if corpus && !vanilla {
                c = c.with_corpus(DraftCorpus::new());
            }
            let exporter = metrics_addr.as_deref().map(|addr| {
                MetricsExporter::bind(addr).unwrap_or_else(|e| {
                    eprintln!("metrics exporter: {e:#}");
                    exit(1)
                })
            });
            if let Some(ex) = &exporter {
                eprintln!("metrics: http://{}/metrics", ex.addr);
            }
            match drive_cluster_open_loop(&mut c, arrivals, Some(1.0e-3)) {
                Ok(rep) => {
                    let _ = c.drain_finished();
                    print_cluster_summary("synthetic", &c, &rep);
                    if let Some(ex) = &exporter {
                        ex.publish(c.collect_registry().render());
                    }
                    if hold_ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
                    }
                }
                Err(e) => {
                    eprintln!("serve --smoke --workers {workers_n} failed: {e}");
                    exit(1);
                }
            }
            return;
        }
        let replan = Replanner::synthetic();
        let mut engine = SyntheticEngine::new(capacity.max(1), seed).with_discipline(discipline);
        if overlap {
            engine = engine.with_overlap();
        }
        let engine = ChaosEngine::new(engine, fplan);
        let mut b = Batcher::new(engine, queue_cap, replan, !vanilla);
        if overlap {
            b = b.with_overlap();
        }
        if reconfig_period > 0 && !vanilla {
            b = b.with_reconfig(Reconfigurator::synthetic(reconfig_period));
        }
        if fon_race && !vanilla {
            b = b.with_racing(RaceArbiter::synthetic());
        }
        if corpus && !vanilla {
            b = b.with_corpus(DraftCorpus::new());
        }
        b = wire_observability(b, metrics_addr.as_deref(), trace_out.as_deref(), pace_us);
        match drive_open_loop(&mut b, arrivals, Some(1.0e-3)) {
            Ok(rep) => {
                print_serve_summary("synthetic", &b, &rep);
                print_chaos_summary(b.engine());
                finish_observability(&b, &rep, trace_out.as_deref(), hold_ms);
            }
            Err(e) => {
                eprintln!("serve --smoke failed: {e}");
                exit(1);
            }
        }
        return;
    }

    let rt = Runtime::load(&art).unwrap_or_else(|e| {
        eprintln!("load artifacts: {e}");
        exit(1)
    });
    let m = rt.manifest.clone();
    budget = budget.min(m.max_new_tokens().unwrap());
    let arrivals: Vec<(f64, Request, Priority)> = times
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let id = i as u64;
            let prompt = m.synth_prompt(id).unwrap();
            (t, Request::new(id, prompt, budget), prio_for(id))
        })
        .collect();
    if !matches!(drafter.as_str(), "auto" | "sam" | "ngram" | "draft_small" | "draft_mid") {
        eprintln!("unknown --drafter {drafter:?}");
        usage()
    }
    let ecfg = EngineConfig {
        // the default plan for slots the batcher does not re-plan; the
        // admission path applies the replanner's (method, window) anyway,
        // so `auto` (no pinned method) just seeds a vanilla default
        plan: if vanilla || drafter == "auto" {
            SlotPlan::vanilla()
        } else {
            SlotPlan::coupled(DraftMethod::parse(&drafter), 3)
        },
        verify: discipline,
        temperature: 1.0,
        seed,
        draft_seed: seed.wrapping_add(1000),
        overlap,
    };
    // --drafter pins the served method (single-rung ladder); `auto` hands
    // method selection to the ladder over the full profiled table. Either
    // way the replanner's choice is APPLIED to slots on admission.
    let profiled_all = TraceConfig::grpo_32b_20k().profiled_acceptance();
    let profiled = if drafter == "auto" {
        profiled_all.clone()
    } else {
        let p = profiled_all
            .iter()
            .find(|(n, _)| *n == drafter)
            .map(|(_, p)| *p)
            .unwrap_or(0.6);
        vec![(drafter.clone(), p)]
    };
    // --overlap prices plans with the overlap-efficiency term: the
    // hidden share of the serialized draft time (see PERF.md) shifts
    // the planner toward larger windows the overlapped engine can
    // afford; the sequential baseline keeps the eff=0 model.
    let cost = if overlap {
        CostModel::paper_32b().with_overlap_eff(0.6)
    } else {
        CostModel::paper_32b()
    };

    if workers_n > 1 {
        // multi-worker cluster over one runtime: every worker shares the
        // artifacts and the sampling seed (tokens are position-keyed, so
        // identical wherever a request lands); chaos streams split per
        // worker. `--fon-race` here means CROSS-WORKER racing.
        let batchers: Vec<_> = (0..workers_n)
            .map(|w| {
                let wk = Worker::with_capacity(&rt, ecfg.clone(), capacity).unwrap_or_else(|e| {
                    eprintln!("worker {w}: {e}");
                    exit(1)
                });
                let wk = ChaosEngine::new(wk, fplan.for_worker(w));
                let replan = Replanner::for_manifest(&m, cost.clone(), profiled.clone(), 7);
                let mut b = Batcher::new(wk, queue_cap, replan, !vanilla);
                if overlap {
                    b = b.with_overlap();
                }
                if reconfig_period > 0 && !vanilla {
                    b = b.with_reconfig(Reconfigurator::for_manifest(
                        &m,
                        cost.clone(),
                        7,
                        reconfig_period,
                    ));
                }
                b
            })
            .collect();
        let mut c = Cluster::new(batchers, queue_cap);
        if fon_race && !vanilla {
            c = c.with_cross_racing();
        }
        if corpus && !vanilla {
            c = c.with_corpus(DraftCorpus::new());
        }
        let exporter = metrics_addr.as_deref().map(|addr| {
            MetricsExporter::bind(addr).unwrap_or_else(|e| {
                eprintln!("metrics exporter: {e:#}");
                exit(1)
            })
        });
        if let Some(ex) = &exporter {
            eprintln!("metrics: http://{}/metrics", ex.addr);
        }
        match drive_cluster_open_loop(&mut c, arrivals, None) {
            Ok(rep) => {
                let _ = c.drain_finished();
                print_cluster_summary("pjrt", &c, &rep);
                if let Some(ex) = &exporter {
                    ex.publish(c.collect_registry().render());
                }
                if hold_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(hold_ms));
                }
            }
            Err(e) => {
                eprintln!("serve --workers {workers_n} failed: {e}");
                exit(1);
            }
        }
        return;
    }

    let worker = Worker::with_capacity(&rt, ecfg, capacity).unwrap_or_else(|e| {
        eprintln!("worker: {e}");
        exit(1)
    });
    let worker = ChaosEngine::new(worker, fplan);
    let replan = Replanner::for_manifest(&m, cost.clone(), profiled, 7);
    let mut b = Batcher::new(worker, queue_cap, replan, !vanilla);
    if overlap {
        b = b.with_overlap();
    }
    if reconfig_period > 0 && !vanilla {
        b = b.with_reconfig(Reconfigurator::for_manifest(&m, cost.clone(), 7, reconfig_period));
    }
    if fon_race && !vanilla {
        // race rank: every profiled method this artifact set can serve
        // (token drafters always qualify; sam joins even unprofiled —
        // the suffix automaton piggybacks on any worker), best-first
        let mut rank: Vec<(String, f64)> = profiled_all
            .iter()
            .filter(|(n, _)| {
                matches!(n.as_str(), "ngram" | "sam") || m.models.contains_key(n)
            })
            .cloned()
            .collect();
        if !rank.iter().any(|(n, _)| n == "sam") {
            rank.push(("sam".to_string(), 0.6));
        }
        rank.sort_by(|x, y| y.1.total_cmp(&x.1));
        b = b.with_racing(RaceArbiter::for_manifest(&m, cost.clone(), rank));
    }
    if corpus && !vanilla {
        b = b.with_corpus(DraftCorpus::new());
    }
    b = wire_observability(b, metrics_addr.as_deref(), trace_out.as_deref(), pace_us);
    match drive_open_loop(&mut b, arrivals, None) {
        Ok(rep) => {
            print_serve_summary("pjrt", &b, &rep);
            print_chaos_summary(b.engine());
            println!(
                "  engine: {} target steps, {} draft steps, acceptance {:.2}",
                b.report.target_steps,
                b.report.draft_steps,
                b.report.acceptance_rate()
            );
            finish_observability(&b, &rep, trace_out.as_deref(), hold_ms);
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            exit(1);
        }
    }
}

fn cmd_plan(mut args: Args) {
    let batch = args.opt_parse("batch", 16384usize);
    let gpus = args.opt_parse("gpus", 256usize);
    let accept = args.opt_parse("accept", 0.8f64);
    let method = args.opt("method", "draft_small");
    let moe = args.flag("moe");
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let m = if moe { CostModel::paper_235b_moe() } else { CostModel::paper_32b() };
    let input = PlanInput {
        global_batch: batch,
        gpus,
        verifier_configs: vec![m.g_ref, m.g_ref * 2],
        accept_p: accept,
        method,
        max_window: 8,
        fixed_batch: None,
        fused_windows: vec![],
    };
    match search(&m, &input) {
        Some(p) => println!(
            "plan: g_d={} g_v={} w={} b={} TGS={:.1} tok/s/replica speedup={:.2}x",
            p.g_d, p.g_v, p.w, p.b, p.tgs, p.speedup
        ),
        None => println!("no speculative plan beats vanilla — run vanilla rollout"),
    }
}

fn cmd_ladder(mut args: Args) {
    let moe = args.flag("moe");
    let batch = args.opt_parse("batch", 128usize);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let (m, trace) = if moe {
        (CostModel::paper_235b_moe(), TraceConfig::grpo_235b_moe())
    } else {
        (CostModel::paper_32b(), TraceConfig::dapo_32b_20k())
    };
    let ladder = Ladder::build_decoupled(&m, batch, 4, &trace.profiled_acceptance());
    println!("draft ladder (decoupled, batch {batch}, window 4):");
    for e in ladder.ranked() {
        println!("  {:<14} profiled p = {:.2}", e.method, e.profiled_p);
    }
    println!("initial selection: {}", ladder.select_initial().method);
}

fn cmd_simulate(mut args: Args) {
    let trace = args.opt("trace", "dapo");
    let step = args.opt_parse("step", 140usize);
    let policy = args.opt("policy", "all");
    let full = args.flag("full");
    let seed = args.opt_parse("seed", 7u64);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let base = match trace.as_str() {
        "grpo" => TraceConfig::grpo_32b_20k(),
        "ppo" => TraceConfig::ppo_32b_20k(),
        "moe" => TraceConfig::grpo_235b_moe(),
        _ => TraceConfig::dapo_32b_20k(),
    };
    let cfg = if full { base } else { scaled(&base, 4, 4_000) };
    let pols: Vec<Policy> = match policy.as_str() {
        "verl" => vec![Policy::Verl],
        "specactor" => vec![Policy::specactor()],
        _ => vec![
            Policy::Verl,
            Policy::Rlhfuse,
            Policy::Verl2x,
            Policy::ModelSpec,
            Policy::NgramSpec,
            Policy::specactor(),
        ],
    };
    for p in pols {
        let r = simulate_step(&cfg, &p, step, seed);
        println!(
            "{:<22} rollout {:>8.1}s  step {:>8.1}s  idle {:>4.0}%  tokens {}",
            p.label(),
            r.rollout_s,
            r.step_s,
            r.idle_frac * 100.0,
            r.total_tokens
        );
    }
}

fn cmd_fit(mut args: Args) {
    let art = PathBuf::from(args.opt("artifacts", "artifacts"));
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let rt = match Runtime::load(&art) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("load artifacts: {e}");
            exit(1)
        }
    };
    let m = rt.manifest.clone();
    println!("fitting affine decode cost of {} from real measurements...", m.target);
    let mut points = Vec::new();
    for &b in &[1usize, 4, 8] {
        let mut cache = rt.new_cache(&m.target, b).unwrap();
        let prompt: Vec<i32> =
            (0..b * m.prompt_len).map(|i| m.reserved + (i as i32 % 200)).collect();
        rt.prefill(&m.target, &prompt, &mut cache).unwrap();
        for l in cache.lens.iter_mut() {
            *l = (m.prompt_len - 1) as i32;
        }
        let toks = vec![m.reserved + 1; b];
        let _ = rt.step(&m.target, &toks, 1, &mut cache.clone()).unwrap();
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let _ = rt.step(&m.target, &toks, 1, &mut cache.clone()).unwrap();
        }
        let t = t0.elapsed().as_secs_f64() / iters as f64;
        println!("  b={b}: {:.1} ms", t * 1e3);
        points.push((b, t));
    }
    let (fit, r2) = AffineCost::fit(&points);
    println!(
        "fit: t(b) = {:.3}ms * b + {:.3}ms  (r2 = {:.3})",
        fit.slope * 1e3,
        fit.intercept * 1e3,
        r2
    );
}

fn cmd_rollout(mut args: Args) {
    let art = PathBuf::from(args.opt("artifacts", "artifacts"));
    let n = args.opt_parse("requests", 4usize);
    let budget = args.opt_parse("budget", 32usize);
    let workers = args.opt_parse("workers", 2usize);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let rt = Runtime::load(&art).unwrap_or_else(|e| {
        eprintln!("load artifacts: {e}");
        exit(1)
    });
    let m = rt.manifest.clone();
    drop(rt);
    let prompts: Vec<(u64, Vec<i32>)> =
        (0..n as u64).map(|i| (i, m.synth_prompt(i).unwrap())).collect();
    let cost = CostModel::paper_32b();
    let profiled = vec![
        ("draft_mid".to_string(), 0.82),
        ("draft_small".to_string(), 0.74),
        ("ngram".to_string(), 0.40),
    ];
    let (method, window) = plan_initial(&cost, &profiled, n, 8, 4);
    println!("plan: method={method} window={window}");
    let gcfg = GlobalConfig {
        artifacts: art,
        n_workers: workers,
        window: Some(window),
        temperature: 1.0,
        seed: 7,
        fon: true,
    };
    // full ladder rank (primary first) so Algorithm 3 has methods to race
    let rank: Vec<String> = std::iter::once(method.clone())
        .chain(profiled.iter().map(|(n, _)| n.clone()).filter(|x| *x != method))
        .collect();
    let summary = rollout(&gcfg, prompts, budget, &rank, window).unwrap();
    let tokens: usize = summary.outcomes.iter().map(|o| o.tokens.len()).sum();
    println!(
        "rollout finished: {} requests, {} tokens, {:.2}s ({:.1} tok/s)",
        summary.outcomes.len(),
        tokens,
        summary.wall_s,
        tokens as f64 / summary.wall_s
    );
    if summary.fon_launches > 0 {
        println!(
            "fon: {} replicas raced in-process in {:.2}s, {} replica wins, {} cancelled \
             ({} replica-rounds wasted)",
            summary.fon_launches,
            summary.fon_race_s,
            summary.fon_wins,
            summary.fon_cancelled_replicas,
            summary.fon_wasted_replica_rounds
        );
    }
}
