//! `specactor` — the leader entrypoint / CLI.
//!
//! ```text
//! specactor plan      --batch 16384 --gpus 256 --accept 0.8 --method draft_small
//! specactor ladder    [--moe]
//! specactor simulate  --trace dapo --step 140 [--policy specactor] [--full]
//! specactor fit       [--artifacts artifacts]   # fit affine costs from the real runtime
//! specactor rollout   --requests 4 --budget 32  # real-engine rollout
//! ```

use std::path::PathBuf;
use std::process::exit;

use specactor::coordinator::global::{plan_initial, rollout, GlobalConfig};
use specactor::ladder::Ladder;
use specactor::planner::costmodel::{AffineCost, CostModel};
use specactor::planner::plan::{search, PlanInput};
use specactor::runtime::Runtime;
use specactor::sim::{scaled, simulate_step, Policy, TraceConfig};
use specactor::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: specactor <plan|ladder|simulate|fit|rollout> [options]\n\
         see README for the option list"
    );
    exit(2)
}

fn main() {
    let mut args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            usage()
        }
    };
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "plan" => cmd_plan(args),
        "ladder" => cmd_ladder(args),
        "simulate" => cmd_simulate(args),
        "fit" => cmd_fit(args),
        "rollout" => cmd_rollout(args),
        _ => usage(),
    }
}

fn cmd_plan(mut args: Args) {
    let batch = args.opt_parse("batch", 16384usize);
    let gpus = args.opt_parse("gpus", 256usize);
    let accept = args.opt_parse("accept", 0.8f64);
    let method = args.opt("method", "draft_small");
    let moe = args.flag("moe");
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let m = if moe { CostModel::paper_235b_moe() } else { CostModel::paper_32b() };
    let input = PlanInput {
        global_batch: batch,
        gpus,
        verifier_configs: vec![m.g_ref, m.g_ref * 2],
        accept_p: accept,
        method,
        max_window: 8,
        fixed_batch: None,
    };
    match search(&m, &input) {
        Some(p) => println!(
            "plan: g_d={} g_v={} w={} b={} TGS={:.1} tok/s/replica speedup={:.2}x",
            p.g_d, p.g_v, p.w, p.b, p.tgs, p.speedup
        ),
        None => println!("no speculative plan beats vanilla — run vanilla rollout"),
    }
}

fn cmd_ladder(mut args: Args) {
    let moe = args.flag("moe");
    let batch = args.opt_parse("batch", 128usize);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let (m, trace) = if moe {
        (CostModel::paper_235b_moe(), TraceConfig::grpo_235b_moe())
    } else {
        (CostModel::paper_32b(), TraceConfig::dapo_32b_20k())
    };
    let ladder = Ladder::build_decoupled(&m, batch, 4, &trace.profiled_acceptance());
    println!("draft ladder (decoupled, batch {batch}, window 4):");
    for e in ladder.ranked() {
        println!("  {:<14} profiled p = {:.2}", e.method, e.profiled_p);
    }
    println!("initial selection: {}", ladder.select_initial().method);
}

fn cmd_simulate(mut args: Args) {
    let trace = args.opt("trace", "dapo");
    let step = args.opt_parse("step", 140usize);
    let policy = args.opt("policy", "all");
    let full = args.flag("full");
    let seed = args.opt_parse("seed", 7u64);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let base = match trace.as_str() {
        "grpo" => TraceConfig::grpo_32b_20k(),
        "ppo" => TraceConfig::ppo_32b_20k(),
        "moe" => TraceConfig::grpo_235b_moe(),
        _ => TraceConfig::dapo_32b_20k(),
    };
    let cfg = if full { base } else { scaled(&base, 4, 4_000) };
    let pols: Vec<Policy> = match policy.as_str() {
        "verl" => vec![Policy::Verl],
        "specactor" => vec![Policy::specactor()],
        _ => vec![
            Policy::Verl,
            Policy::Rlhfuse,
            Policy::Verl2x,
            Policy::ModelSpec,
            Policy::NgramSpec,
            Policy::specactor(),
        ],
    };
    for p in pols {
        let r = simulate_step(&cfg, &p, step, seed);
        println!(
            "{:<22} rollout {:>8.1}s  step {:>8.1}s  idle {:>4.0}%  tokens {}",
            p.label(),
            r.rollout_s,
            r.step_s,
            r.idle_frac * 100.0,
            r.total_tokens
        );
    }
}

fn cmd_fit(mut args: Args) {
    let art = PathBuf::from(args.opt("artifacts", "artifacts"));
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let rt = match Runtime::load(&art) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("load artifacts: {e}");
            exit(1)
        }
    };
    let m = rt.manifest.clone();
    println!("fitting affine decode cost of {} from real measurements...", m.target);
    let mut points = Vec::new();
    for &b in &[1usize, 4, 8] {
        let mut cache = rt.new_cache(&m.target, b).unwrap();
        let prompt: Vec<i32> =
            (0..b * m.prompt_len).map(|i| m.reserved + (i as i32 % 200)).collect();
        rt.prefill(&m.target, &prompt, &mut cache).unwrap();
        for l in cache.lens.iter_mut() {
            *l = (m.prompt_len - 1) as i32;
        }
        let toks = vec![m.reserved + 1; b];
        let _ = rt.step(&m.target, &toks, 1, &mut cache.clone()).unwrap();
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let _ = rt.step(&m.target, &toks, 1, &mut cache.clone()).unwrap();
        }
        let t = t0.elapsed().as_secs_f64() / iters as f64;
        println!("  b={b}: {:.1} ms", t * 1e3);
        points.push((b, t));
    }
    let (fit, r2) = AffineCost::fit(&points);
    println!(
        "fit: t(b) = {:.3}ms * b + {:.3}ms  (r2 = {:.3})",
        fit.slope * 1e3,
        fit.intercept * 1e3,
        r2
    );
}

fn cmd_rollout(mut args: Args) {
    let art = PathBuf::from(args.opt("artifacts", "artifacts"));
    let n = args.opt_parse("requests", 4usize);
    let budget = args.opt_parse("budget", 32usize);
    let workers = args.opt_parse("workers", 2usize);
    args.finish().unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let rt = Runtime::load(&art).unwrap_or_else(|e| {
        eprintln!("load artifacts: {e}");
        exit(1)
    });
    let m = rt.manifest.clone();
    let vocab = rt.model(&m.target).unwrap().vocab as i32;
    drop(rt);
    let prompts: Vec<(u64, Vec<i32>)> = (0..n as u64)
        .map(|i| {
            let p: Vec<i32> = (0..m.prompt_len)
                .map(|j| m.reserved + ((i as i32 * 83 + j as i32) % (vocab - m.reserved)))
                .collect();
            (i, p)
        })
        .collect();
    let cost = CostModel::paper_32b();
    let profiled = vec![
        ("draft_mid".to_string(), 0.82),
        ("draft_small".to_string(), 0.74),
        ("ngram".to_string(), 0.40),
    ];
    let (method, window) = plan_initial(&cost, &profiled, n, 8, 4);
    println!("plan: method={method} window={window}");
    let gcfg = GlobalConfig {
        artifacts: art,
        n_workers: workers,
        window: Some(window),
        temperature: 1.0,
        seed: 7,
        fon: true,
    };
    let summary = rollout(&gcfg, prompts, budget, &[method], window).unwrap();
    let tokens: usize = summary.outcomes.iter().map(|o| o.tokens.len()).sum();
    println!(
        "rollout finished: {} requests, {} tokens, {:.2}s ({:.1} tok/s)",
        summary.outcomes.len(),
        tokens,
        summary.wall_s,
        tokens as f64 / summary.wall_s
    );
}
