//! Speculative draft prefetch: hide round R+1's drafting behind round
//! R's target verify step.
//!
//! The fused round serializes `draft → verify → apply`. Token drafters
//! (ngram / SAM) run on the worker's CPU, so while the accelerator is
//! busy with the ragged verify step the host is idle — exactly the slack
//! the [`Prefetcher`] spends. It owns a *mirror* of each eligible slot's
//! token-drafter state on a worker thread and, as soon as round R's
//! drafts are chosen, begins drafting round R+1 under the **predicted
//! full-accept** outcome (speculation on the speculation). When round R
//! resolves, the worker reconciles:
//!
//! * prediction held (full accept) → the prefetched chunk is used as-is
//!   next round; its drafting cost was hidden behind the verify step;
//! * prediction missed (partial accept) → the mirror **rolls back**: the
//!   mirrored history is truncated to the verified base and replayed
//!   from the actually-accepted tokens (frozen-chain discipline — the
//!   real drafter state in the worker is never touched by predictions,
//!   so rollback is purely the mirror's problem), and the stale chunk is
//!   discarded. The worker re-drafts synchronously, exactly as without
//!   overlap.
//!
//! Eligibility is deliberately narrow: Decoupled-mode token-drafter
//! slots only. Coupled full-accept appends a target-sampled bonus token
//! the mirror cannot predict, and model drafters need the (non-`Send`)
//! runtime. Everything else falls back to the sequential path, which is
//! why overlap can never change tokens: drafts only *propose* — the
//! verifier decides every token either way (losslessness invariant).
//!
//! The thread is an accelerator, never a dependency: if it dies, the
//! worker silently reverts to sequential in-round drafting (counted in
//! [`EngineReport::prefetch_deaths`]) and serving continues lossless —
//! the chaos harness injects exactly this via `SpecError::PrefetchDead`.
//!
//! [`EngineReport::prefetch_deaths`]: crate::engine::EngineReport

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::drafter::corpus::CorpusSnapshot;
use crate::drafter::{DraftMethod, TokenDrafter};

/// Rebuild instruction for one slot's drafter mirror (admit / plan swap).
#[derive(Clone)]
pub struct ResetSpec {
    /// Token-drafter method mirrored for the slot.
    pub method: DraftMethod,
    /// Draft window `k` to prefetch per round.
    pub window: usize,
    /// Full verified token history at reset time.
    pub seq: Vec<i32>,
    /// Corpus snapshot the slot's real drafter was seeded from (None =
    /// cold start). The mirror must build — and rebuild on rollback —
    /// from this exact snapshot, or it predicts different chunks than
    /// the worker-side drafter would draft.
    pub seed: Option<Arc<CorpusSnapshot>>,
}

/// Commands from the worker to the prefetch thread. One FIFO channel
/// carries both the per-round `Predict`/`Resolve` pair and lifecycle
/// resets, so ordering races are impossible by construction.
enum PrefetchCmd {
    /// (Re)build the slot mirror, or clear it (`None` = ineligible).
    Reset { slot: usize, spec: Option<Box<ResetSpec>> },
    /// Round R chose `drafts` for the slot: assume full accept, extend
    /// the mirror, and draft round R+1 now. The `stamp` rides back on
    /// the chunk so the worker can match it against the round whose
    /// prediction actually held (a pure length check is unsound: a
    /// round that accepts `k - 1` drafts plus the correction token
    /// lands on the same history *length* as a full accept, with
    /// different *content*).
    Predict { slot: usize, stamp: u64, drafts: Vec<i32> },
    /// Round R resolved: the slot's verified history is `base_len` old
    /// tokens plus `appended`. Reconcile the mirror (rollback replay on
    /// mismatch).
    Resolve { slot: usize, base_len: usize, appended: Vec<i32> },
    /// Join politely (Drop).
    Shutdown,
}

/// A round-R+1 draft produced ahead of time for one slot.
#[derive(Clone, Debug)]
pub struct PrefetchChunk {
    /// Slot the chunk was drafted for.
    pub slot: usize,
    /// Echo of the producing `Predict`'s stamp: the worker consumes the
    /// chunk only when this matches the stamp of the round it verified
    /// as a full accept.
    pub stamp: u64,
    /// Mirror's history length when drafting — the chunk is usable only
    /// if the slot's real verified history has exactly this length next
    /// round (full-accept prediction held).
    pub base_len: usize,
    /// Predicted round-R+1 draft tokens (length = slot window, padded).
    pub tokens: Vec<i32>,
    /// Wall time the mirror spent drafting, in microseconds — the cost
    /// hidden behind the verify step when the chunk hits.
    pub draft_us: u64,
}

/// One slot's drafter mirror on the prefetch thread.
struct SlotMirror {
    drafter: Box<dyn TokenDrafter>,
    seq: Vec<i32>,
    window: usize,
    /// Method + seeding snapshot, kept so a rollback can rebuild the
    /// drafter exactly as it was first built (a bare `reset()` would
    /// silently drop the corpus seed and diverge from the worker).
    method: DraftMethod,
    seed: Option<Arc<CorpusSnapshot>>,
}

/// Build a mirror drafter the same way the worker built the slot's
/// drafter: seeded clone of `seed` when present, cold constructor
/// otherwise (the snapshot fallback covers a cold/model-method seed
/// defensively — the worker never sends one).
fn mirror_drafter(
    method: &DraftMethod,
    seed: Option<&Arc<CorpusSnapshot>>,
) -> Option<Box<dyn TokenDrafter>> {
    seed.and_then(|snap| snap.seed_token_drafter(method))
        .or_else(|| method.new_token_drafter())
}

fn prefetch_loop(
    cmd_rx: Receiver<PrefetchCmd>,
    chunk_tx: Sender<PrefetchChunk>,
    bucket: usize,
    pad: i32,
) {
    let mut slots: Vec<Option<SlotMirror>> = (0..bucket).map(|_| None).collect();
    let mut toks: Vec<i32> = Vec::new();
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            PrefetchCmd::Reset { slot, spec } => {
                if slot >= slots.len() {
                    continue;
                }
                slots[slot] = spec.and_then(|s| {
                    let mut drafter = mirror_drafter(&s.method, s.seed.as_ref())?;
                    drafter.extend(&s.seq);
                    Some(SlotMirror {
                        drafter,
                        seq: s.seq,
                        window: s.window,
                        method: s.method,
                        seed: s.seed,
                    })
                });
            }
            PrefetchCmd::Predict { slot, stamp, drafts } => {
                let Some(st) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
                    continue;
                };
                // assume every drafted token verifies (full accept)
                st.seq.extend_from_slice(&drafts);
                st.drafter.extend(&drafts);
                let t0 = Instant::now();
                st.drafter.draft_into(st.window, &mut toks);
                toks.resize(st.window, pad);
                let draft_us = t0.elapsed().as_micros() as u64;
                let chunk = PrefetchChunk {
                    slot,
                    stamp,
                    base_len: st.seq.len(),
                    tokens: toks.clone(),
                    draft_us,
                };
                if chunk_tx.send(chunk).is_err() {
                    return; // worker gone
                }
            }
            PrefetchCmd::Resolve { slot, base_len, appended } => {
                let Some(st) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
                    continue;
                };
                let hit = st.seq.len() == base_len + appended.len()
                    && st.seq[base_len.min(st.seq.len())..] == appended[..];
                if hit {
                    continue; // prediction held: mirror already current
                }
                if st.seq.len() >= base_len {
                    // rollback: truncate to the verified base and replay
                    // the actually-accepted tokens over a fresh index,
                    // rebuilt from the original seeding snapshot
                    st.seq.truncate(base_len);
                    st.seq.extend_from_slice(&appended);
                    match mirror_drafter(&st.method, st.seed.as_ref()) {
                        Some(mut d) => {
                            d.extend(&st.seq);
                            st.drafter = d;
                        }
                        None => {
                            st.drafter.reset();
                            st.drafter.extend(&st.seq);
                        }
                    }
                } else {
                    // mirror is behind the verified base: it missed a
                    // lifecycle event — drop it until the next Reset
                    slots[slot] = None;
                }
            }
            PrefetchCmd::Shutdown => return,
        }
    }
}

/// Handle to the prefetch thread. All sends report success as `bool`
/// (`false` = thread dead); the worker reacts by disabling overlap, not
/// by erroring — losing the prefetcher loses performance, never tokens.
pub struct Prefetcher {
    cmd_tx: Sender<PrefetchCmd>,
    chunk_rx: Receiver<PrefetchChunk>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the mirror thread for a `bucket`-slot worker.
    pub fn new(bucket: usize, pad: i32) -> Prefetcher {
        let (cmd_tx, cmd_rx) = channel();
        let (chunk_tx, chunk_rx) = channel();
        let handle = std::thread::Builder::new()
            .name("specactor-prefetch".to_string())
            .spawn(move || prefetch_loop(cmd_rx, chunk_tx, bucket, pad))
            .expect("spawn prefetch thread");
        Prefetcher { cmd_tx, chunk_rx, handle: Some(handle) }
    }

    /// Rebuild (Some) or clear (None) one slot's mirror.
    pub fn reset(&self, slot: usize, spec: Option<ResetSpec>) -> bool {
        self.cmd_tx
            .send(PrefetchCmd::Reset { slot, spec: spec.map(Box::new) })
            .is_ok()
    }

    /// Hand round R's chosen drafts to the mirror; it drafts round R+1
    /// under the full-accept prediction and sends back a chunk echoing
    /// `stamp`.
    pub fn predict(&self, slot: usize, stamp: u64, drafts: Vec<i32>) -> bool {
        self.cmd_tx
            .send(PrefetchCmd::Predict { slot, stamp, drafts })
            .is_ok()
    }

    /// Reconcile the mirror with round R's verified outcome.
    pub fn resolve(&self, slot: usize, base_len: usize, appended: Vec<i32>) -> bool {
        self.cmd_tx
            .send(PrefetchCmd::Resolve { slot, base_len, appended })
            .is_ok()
    }

    /// Non-blocking poll for finished chunks.
    pub fn try_recv(&self) -> Result<PrefetchChunk, TryRecvError> {
        self.chunk_rx.try_recv()
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let _ = self.cmd_tx.send(PrefetchCmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drafter::DraftMethod;

    fn spec(seq: &[i32]) -> ResetSpec {
        ResetSpec { method: DraftMethod::Ngram, window: 4, seq: seq.to_vec(), seed: None }
    }

    fn recv_chunk(p: &Prefetcher) -> PrefetchChunk {
        for _ in 0..2000 {
            match p.try_recv() {
                Ok(c) => return c,
                Err(TryRecvError::Empty) => {
                    std::thread::sleep(std::time::Duration::from_micros(50))
                }
                Err(TryRecvError::Disconnected) => panic!("prefetch thread died"),
            }
        }
        panic!("no chunk within timeout");
    }

    /// A repeating history makes the ngram mirror's prediction exactly
    /// reproducible: the prefetched chunk must equal what a synchronous
    /// drafter with the same (full-accept) history would draft.
    #[test]
    fn predicted_chunk_matches_synchronous_draft() {
        let hist: Vec<i32> = (0..40).map(|i| i % 5).collect();
        let p = Prefetcher::new(2, -1);
        assert!(p.reset(0, Some(spec(&hist))));
        let drafts = vec![0, 1, 2, 3]; // continues the period-5 pattern
        assert!(p.predict(0, 1, drafts.clone()));
        let c = recv_chunk(&p);
        assert_eq!(c.slot, 0);
        assert_eq!(c.stamp, 1);
        assert_eq!(c.base_len, hist.len() + drafts.len());
        let mut oracle = DraftMethod::Ngram.new_token_drafter().unwrap();
        oracle.extend(&hist);
        oracle.extend(&drafts);
        let mut want = oracle.draft(4);
        want.resize(4, -1);
        assert_eq!(c.tokens, want, "mirror must draft exactly like a sync drafter");
    }

    /// Partial accept: Resolve must roll the mirror back to the verified
    /// base and replay, after which a fresh Predict drafts from the
    /// corrected history (not the mis-speculated one).
    #[test]
    fn resolve_rolls_back_mispredicted_history() {
        let hist: Vec<i32> = (0..40).map(|i| i % 5).collect();
        let p = Prefetcher::new(1, -1);
        assert!(p.reset(0, Some(spec(&hist))));
        assert!(p.predict(0, 1, vec![0, 1, 2, 3]));
        let _stale = recv_chunk(&p);
        // verifier accepted only [0, 1] and decoded a correction token 9
        let appended = vec![0, 1, 9];
        assert!(p.resolve(0, hist.len(), appended.clone()));
        // next round drafts from the corrected history
        assert!(p.predict(0, 2, vec![9, 9, 9, 9]));
        let c = recv_chunk(&p);
        assert_eq!(c.base_len, hist.len() + appended.len() + 4);
        let mut oracle = DraftMethod::Ngram.new_token_drafter().unwrap();
        oracle.extend(&hist);
        oracle.extend(&appended);
        oracle.extend(&[9, 9, 9, 9]);
        let mut want = oracle.draft(4);
        want.resize(4, -1);
        assert_eq!(c.tokens, want, "rollback must replay the verified history");
    }

    /// Full accept: Resolve with exactly the predicted tokens is a no-op
    /// (the mirror stays warm — no reset, no replay).
    #[test]
    fn resolve_on_full_accept_keeps_mirror_warm() {
        let hist: Vec<i32> = (0..30).map(|i| i % 3).collect();
        let p = Prefetcher::new(1, -1);
        assert!(p.reset(0, Some(spec(&hist))));
        let drafts = vec![0, 1, 2, 0];
        assert!(p.predict(0, 1, drafts.clone()));
        let c1 = recv_chunk(&p);
        assert!(p.resolve(0, hist.len(), drafts.clone()));
        assert!(p.predict(0, 2, c1.tokens.clone()));
        let c2 = recv_chunk(&p);
        assert_eq!(c2.stamp, 2);
        assert_eq!(c2.base_len, c1.base_len + 4);
    }

    /// Reset(None) clears the mirror: Predicts for the slot are ignored.
    #[test]
    fn cleared_slot_produces_no_chunks() {
        let p = Prefetcher::new(1, -1);
        assert!(p.reset(0, Some(spec(&[1, 2, 3, 1, 2, 3, 1, 2]))));
        assert!(p.reset(0, None));
        assert!(p.predict(0, 1, vec![3, 1]));
        // flush with a second slot-less command and check emptiness
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(matches!(p.try_recv(), Err(TryRecvError::Empty)));
    }

    /// A corpus-seeded mirror must draft exactly like a seeded sync
    /// drafter — including after a rollback, which must rebuild from the
    /// SAME snapshot the slot was originally seeded with.
    #[test]
    fn seeded_mirror_matches_seeded_sync_drafter_across_rollback() {
        use crate::drafter::corpus::DraftCorpus;
        let mut corpus = DraftCorpus::new();
        corpus.add_segment(&(0..40).map(|i| i % 5).collect::<Vec<i32>>());
        corpus.publish();
        let snap = corpus.handle().load();
        let hist: Vec<i32> = (0..10).map(|i| i % 5).collect();
        let mut sp = spec(&hist);
        sp.seed = Some(snap.clone());
        let p = Prefetcher::new(1, -1);
        assert!(p.reset(0, Some(sp)));
        assert!(p.predict(0, 1, vec![0, 1, 2, 3]));
        let _stale = recv_chunk(&p);
        // verifier accepted only [0] and decoded a correction token 7
        let appended = vec![0, 7];
        assert!(p.resolve(0, hist.len(), appended.clone()));
        assert!(p.predict(0, 2, vec![2, 3, 4, 0]));
        let c = recv_chunk(&p);
        let mut oracle = snap.seed_token_drafter(&DraftMethod::Ngram).unwrap();
        oracle.extend(&hist);
        oracle.extend(&appended);
        oracle.extend(&[2, 3, 4, 0]);
        let mut want = oracle.draft(4);
        want.resize(4, -1);
        assert_eq!(c.tokens, want, "rollback must rebuild from the seeding snapshot");
    }

    /// Out-of-range slots must be ignored, not panic the thread.
    #[test]
    fn out_of_range_slot_is_ignored() {
        let p = Prefetcher::new(1, -1);
        assert!(p.reset(7, Some(spec(&[1, 2, 3]))));
        assert!(p.predict(7, 1, vec![1]));
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(matches!(p.try_recv(), Err(TryRecvError::Empty)));
        // thread still alive and serving valid slots
        assert!(p.reset(0, Some(spec(&(0..20).map(|i| i % 4).collect::<Vec<i32>>()))));
        assert!(p.predict(0, 2, vec![0, 1]));
        let c = recv_chunk(&p);
        assert_eq!(c.slot, 0);
    }
}
