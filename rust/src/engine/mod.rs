//! Single-worker generation engine over the PJRT runtime.
//!
//! One [`Worker`] owns a batch slot table, the target model's KV cache and
//! (for model-based drafting) the draft model's cache, and drives rollout
//! in one of three modes:
//!
//! * [`Worker::rollout_vanilla`] — plain auto-regressive decoding,
//! * [`Worker::rollout_coupled`] — draft-k-then-verify speculation
//!   (vanilla speculative decoding, the paper's baseline),
//! * `engine::decoupled::rollout_decoupled` — drafter and verifier on
//!   separate threads with a bounded draft window (§4.1).
//!
//! The batch is **slot-dynamic**: [`Worker::admit`] prefill-joins a new
//! request into a free slot mid-flight and [`Worker::retire`] frees a
//! finished one, so the serve loop (`serve/`) can keep occupancy high
//! under open-loop arrivals while batch-static callers drive the same
//! worker through [`Worker::round`]-based `rollout_*` helpers.
//!
//! All modes produce **identical token sequences** for the same seed (the
//! losslessness invariant; enforced by `rust/tests/losslessness.rs` and —
//! across staggered admits/retires — `rust/tests/serve_lossless.rs`).

pub mod decoupled;
pub mod worker;

pub use worker::{EngineConfig, EngineReport, Request, SpecMode, Worker};
