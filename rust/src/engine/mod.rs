//! Single-worker generation engine over the PJRT runtime.
//!
//! One [`Worker`] owns a batch slot table, the target model's KV cache and
//! (for model-based drafting) the draft model's cache, and drives rollout
//! in one of three modes:
//!
//! * [`Worker::rollout_vanilla`] — plain auto-regressive decoding,
//! * [`Worker::rollout_coupled`] — draft-k-then-verify speculation
//!   (vanilla speculative decoding, the paper's baseline),
//! * `engine::decoupled::rollout_decoupled` — drafter and verifier on
//!   separate threads with a bounded draft window (§4.1).
//!
//! All modes produce **identical token sequences** for the same seed (the
//! losslessness invariant; enforced by `rust/tests/losslessness.rs`).

pub mod decoupled;
pub mod worker;

pub use worker::{EngineConfig, EngineReport, Request, SpecMode, Worker};
