//! Single-worker generation engine over the PJRT runtime.
//!
//! One [`Worker`] owns a batch slot table, the target model's KV cache and
//! (for model-based drafting) per-draft-model caches, and drives rollout
//! under **per-slot speculation plans** ([`SlotPlan`]): each slot chooses
//! its own draft method, window and coupled/decoupled discipline, and
//! [`Worker::round`] verifies the whole batch in **one fused ragged
//! target step** per round ([`VerifyDiscipline::Fused`], the default —
//! β once per round whatever the plan mix; the pre-fusion one-step-per-
//! `(method, window)`-group engine stays behind
//! [`VerifyDiscipline::Grouped`] for A/B). Whole-batch drivers remain as
//! thin wrappers:
//!
//! * [`Worker::rollout_vanilla`] — plain auto-regressive decoding,
//! * [`Worker::rollout_coupled`] — uniform draft-k-then-verify speculation
//!   (vanilla speculative decoding, the paper's baseline),
//! * [`Worker::rollout_planned`] — drain under the current slot plans,
//! * `engine::decoupled::rollout_decoupled_planned` — drafter and verifier
//!   on separate threads with bounded per-slot draft windows (§4.1).
//!
//! The batch is **slot-dynamic**: [`Worker::admit`] prefill-joins a new
//! request into a free slot mid-flight, [`Worker::retire`] frees a
//! finished one, and [`Worker::fork`] clones a live slot (request state +
//! verified-prefix KV row) into a free slot as a Fastest-of-N racing
//! replica (`coordinator::race`), so the serve loop (`serve/`) can keep
//! occupancy high under open-loop arrivals and spend idle slots on tail
//! races; plans are hot-swapped in place by [`Worker::set_plan`]
//! (Algorithm 2 reconfiguration, serve replanning).
//!
//! All modes produce **identical token sequences** for the same seed (the
//! losslessness invariant; enforced by `rust/tests/losslessness.rs` —
//! including mixed-plan batches and mid-rollout plan switches — and,
//! across staggered admits/retires, `rust/tests/serve_lossless.rs`).

pub mod decoupled;
pub mod fault;
pub mod overlap;
pub mod plan;
pub mod worker;

pub use decoupled::{
    rollout_decoupled, rollout_decoupled_planned, rollout_decoupled_planned_corpus,
    rollout_decoupled_planned_traced,
};
pub use fault::{Severity, SpecError};
pub use overlap::{PrefetchChunk, Prefetcher, ResetSpec};
pub use plan::{same_group, PlanMode, SlotPlan, VerifyDiscipline};
pub use worker::{EngineConfig, EngineReport, Request, SlotAccept, Worker};
