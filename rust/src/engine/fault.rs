//! Typed fault taxonomy for the speculation layer.
//!
//! SpecActor's core contract is that speculation is an *accelerator,
//! never a correctness dependency* — so every failure raised by the
//! drafting/verification machinery is classified by what the serve loop
//! may safely do about it:
//!
//! * [`Severity::Degradable`] — the slot's (or the whole batch's)
//!   speculative apparatus failed, but the verified prefix and the
//!   target-model row are intact. The batcher force-replans the affected
//!   slot(s) to `SlotPlan::vanilla()` (window 0 — plain decode, provably
//!   lossless: the sampling tape is keyed by (seed, request, position),
//!   never by plan) and re-promotes them with exponential backoff.
//! * [`Severity::SlotFatal`] — one slot's state (KV row, request
//!   bookkeeping) can no longer be trusted. The batcher quarantines the
//!   slot: retire, re-enqueue the request at the front of its lane with
//!   its already-verified output tokens preserved, and re-admit through
//!   the ordinary staging-prefill + catch-up path, bounded by a
//!   per-request retry budget.
//! * [`Severity::WorkerFatal`] — the engine itself is broken (runtime
//!   error, geometry violation); the serve loop propagates the error.
//!
//! Errors are raised as `anyhow::Error` wrapping a [`SpecError`] (so the
//! existing `Result<_, anyhow::Error>` plumbing is unchanged) and
//! recovered in `Batcher::tick` via `downcast_ref::<SpecError>()` —
//! untyped errors stay fatal, exactly as before this layer existed.

use std::fmt;

/// What the serve loop may safely do about a [`SpecError`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Speculation state lost; verified prefix intact → degrade to
    /// vanilla decode (lossless), re-promote with backoff.
    Degradable,
    /// One slot's state is untrustworthy → quarantine + re-prefill.
    SlotFatal,
    /// The engine is broken → propagate.
    WorkerFatal,
}

/// A classified speculation-layer failure.
#[derive(Clone, Debug)]
pub enum SpecError {
    /// The decoupled drafter thread died (panic / channel closed). All
    /// of its slots degrade; the fused verify path carries them.
    DrafterDead { detail: String },
    /// The overlapped round's prefetch thread died (panic / channel
    /// closed). Purely an accelerator: the worker falls back to
    /// sequential in-round drafting, losing overlap but no tokens.
    PrefetchDead { detail: String },
    /// A draft-model cache catch-up failed for one slot.
    DraftCatchUp { slot: usize, detail: String },
    /// Forking a racing replica failed; the race degrades to the
    /// members already forked (never dooms the primary).
    ForkFailed { src: usize, dst: usize, detail: String },
    /// A draft-model cache row is corrupt for one slot.
    DraftRowCorrupt { slot: usize, detail: String },
    /// The slot's target KV row is invalid — the verified prefix can no
    /// longer be trusted in place.
    KvRowInvalid { slot: usize, detail: String },
    /// The slot's request bookkeeping is inconsistent with the engine.
    RequestStateInconsistent { slot: usize, detail: String },
    /// A cross-worker migration frame failed integrity checks (bad
    /// magic, version mismatch, truncation, checksum). Degradable: the
    /// payload still exists at the source, so `RowTransport` retries
    /// under exponential backoff before the cluster falls back to the
    /// quarantine-style re-prefill path.
    TransportCorrupt { detail: String },
    /// The engine itself failed (runtime step error, geometry).
    Worker { detail: String },
    /// A cluster worker was declared dead — either a `Worker`-severity
    /// fault propagated out of its serve loop or its heartbeat deadline
    /// lapsed. The `Cluster` evacuates its slots instead of aborting.
    WorkerDead { worker: usize },
}

impl SpecError {
    /// The recovery class this failure belongs to.
    pub fn severity(&self) -> Severity {
        match self {
            SpecError::DrafterDead { .. }
            | SpecError::PrefetchDead { .. }
            | SpecError::DraftCatchUp { .. }
            | SpecError::ForkFailed { .. }
            | SpecError::DraftRowCorrupt { .. }
            | SpecError::TransportCorrupt { .. } => Severity::Degradable,
            SpecError::KvRowInvalid { .. } | SpecError::RequestStateInconsistent { .. } => {
                Severity::SlotFatal
            }
            SpecError::Worker { .. } | SpecError::WorkerDead { .. } => Severity::WorkerFatal,
        }
    }

    /// The slot the failure is scoped to (None = batch-wide, e.g. a dead
    /// drafter thread).
    pub fn slot(&self) -> Option<usize> {
        match self {
            SpecError::DrafterDead { .. }
            | SpecError::PrefetchDead { .. }
            | SpecError::TransportCorrupt { .. }
            | SpecError::Worker { .. }
            | SpecError::WorkerDead { .. } => None,
            SpecError::ForkFailed { dst, .. } => Some(*dst),
            SpecError::DraftCatchUp { slot, .. }
            | SpecError::DraftRowCorrupt { slot, .. }
            | SpecError::KvRowInvalid { slot, .. }
            | SpecError::RequestStateInconsistent { slot, .. } => Some(*slot),
        }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::DrafterDead { detail } => write!(f, "drafter thread died: {detail}"),
            SpecError::PrefetchDead { detail } => {
                write!(f, "prefetch thread died: {detail}")
            }
            SpecError::DraftCatchUp { slot, detail } => {
                write!(f, "draft-cache catch-up failed for slot {slot}: {detail}")
            }
            SpecError::ForkFailed { src, dst, detail } => {
                write!(f, "race fork {src} -> {dst} failed: {detail}")
            }
            SpecError::DraftRowCorrupt { slot, detail } => {
                write!(f, "draft model row corrupt for slot {slot}: {detail}")
            }
            SpecError::KvRowInvalid { slot, detail } => {
                write!(f, "KV row invalid for slot {slot}: {detail}")
            }
            SpecError::RequestStateInconsistent { slot, detail } => {
                write!(f, "request state inconsistent for slot {slot}: {detail}")
            }
            SpecError::TransportCorrupt { detail } => {
                write!(f, "migration frame corrupt: {detail}")
            }
            SpecError::Worker { detail } => write!(f, "worker failure: {detail}"),
            SpecError::WorkerDead { worker } => write!(f, "worker {worker} declared dead"),
        }
    }
}

impl std::error::Error for SpecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_classification() {
        let deg = [
            SpecError::DrafterDead { detail: "x".into() },
            SpecError::PrefetchDead { detail: "x".into() },
            SpecError::DraftCatchUp { slot: 1, detail: "x".into() },
            SpecError::ForkFailed { src: 0, dst: 2, detail: "x".into() },
            SpecError::DraftRowCorrupt { slot: 3, detail: "x".into() },
            SpecError::TransportCorrupt { detail: "x".into() },
        ];
        assert!(deg.iter().all(|e| e.severity() == Severity::Degradable));
        let fatal = [
            SpecError::KvRowInvalid { slot: 1, detail: "x".into() },
            SpecError::RequestStateInconsistent { slot: 2, detail: "x".into() },
        ];
        assert!(fatal.iter().all(|e| e.severity() == Severity::SlotFatal));
        assert_eq!(
            SpecError::Worker { detail: "x".into() }.severity(),
            Severity::WorkerFatal
        );
        assert_eq!(SpecError::WorkerDead { worker: 2 }.severity(), Severity::WorkerFatal);
    }

    #[test]
    fn slot_scoping() {
        assert_eq!(SpecError::DrafterDead { detail: "x".into() }.slot(), None);
        assert_eq!(SpecError::PrefetchDead { detail: "x".into() }.slot(), None);
        assert_eq!(SpecError::Worker { detail: "x".into() }.slot(), None);
        assert_eq!(SpecError::TransportCorrupt { detail: "x".into() }.slot(), None);
        assert_eq!(SpecError::WorkerDead { worker: 1 }.slot(), None);
        assert_eq!(
            SpecError::ForkFailed { src: 0, dst: 5, detail: "x".into() }.slot(),
            Some(5)
        );
        assert_eq!(SpecError::KvRowInvalid { slot: 3, detail: "x".into() }.slot(), Some(3));
    }

    #[test]
    fn downcasts_through_anyhow() {
        // The recovery path in Batcher::tick depends on this round-trip.
        let err: anyhow::Error = SpecError::DraftCatchUp { slot: 4, detail: "boom".into() }.into();
        let se = err.downcast_ref::<SpecError>().expect("typed error survives anyhow");
        assert_eq!(se.severity(), Severity::Degradable);
        assert_eq!(se.slot(), Some(4));
        assert!(err.to_string().contains("slot 4"));
    }
}
