//! Per-slot speculation plans: the engine-level currency of the paper's
//! request-level `(w_r, m_r)` pairs.
//!
//! A [`SlotPlan`] describes how ONE batch slot speculates: which draft
//! method proposes tokens, how many tokens per round (`window`, 0 =
//! vanilla decoding), and whether verification runs coupled (bonus token
//! on full accept) or decoupled (no bonus — token dynamics identical to
//! the pipelined drafter thread, so a request can migrate between the
//! in-process round loop and `engine::decoupled` without changing its
//! token stream).
//!
//! Plans are owned per slot by [`Worker`], applied by the serve loop on
//! admission, and rewritten in place by Algorithm 2
//! (`coordinator::reconfig::Reconfigurator`) and Algorithm 3
//! (`coordinator::fon::slot_plans`). Under the default
//! [`VerifyDiscipline::Fused`] every active slot — whatever its plan —
//! joins ONE ragged target step per round; under
//! [`VerifyDiscipline::Grouped`] slots sharing `(method, window)` batch
//! into one verify step per group (regardless of `mode`) — see PERF.md
//! §Per-slot planning for both cost models.
//!
//! [`Worker`]: crate::engine::Worker

use crate::drafter::DraftMethod;

/// How the engine executes one round's verification over the batch's plan
/// groups (SpecActor's fused scheduling vs the pre-fusion testbed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum VerifyDiscipline {
    /// **One fused ragged target step per round**, whatever the plan mix:
    /// each slot drafts its own window `w_i`, rows are padded to a single
    /// bucket window `W` (smallest lowered step window ≥ max active
    /// `w_i + 1`), vanilla slots join as width-1 rows, and acceptance is
    /// applied per row over its real `w_i` only. The verify intercept β is
    /// paid once per round, so heterogeneous per-slot plans are free.
    #[default]
    Fused,
    /// One full-bucket target step per `(method, window)` plan group plus
    /// one vanilla decode step — β per extra group. Kept behind this flag
    /// for A/B measurement (`benches/fused_verify.rs`,
    /// `serve --grouped-verify`).
    Grouped,
}

/// Verification discipline for a speculative slot (the paper's `m_r`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PlanMode {
    /// Draft-then-verify; a fully accepted window earns the bonus token.
    Coupled,
    /// Pipelined drafting discipline: no bonus token on full accept, so
    /// the drafter may run ahead without ever drafting from a token it
    /// has not proposed itself (§4.1).
    Decoupled,
}

/// One slot's speculation plan `(d_r, w_r, m_r)`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlotPlan {
    /// Draft method proposing tokens for this slot.
    pub method: DraftMethod,
    /// Draft window: tokens proposed per round. `0` = vanilla decoding
    /// (method and mode are then inert).
    pub window: usize,
    pub mode: PlanMode,
}

impl SlotPlan {
    /// Plain auto-regressive decoding (no drafter state is maintained).
    pub fn vanilla() -> SlotPlan {
        SlotPlan {
            method: DraftMethod::Model("draft_small".to_string()),
            window: 0,
            mode: PlanMode::Coupled,
        }
    }

    /// Coupled draft-`window`-verify speculation.
    pub fn coupled(method: DraftMethod, window: usize) -> SlotPlan {
        SlotPlan { method, window, mode: PlanMode::Coupled }
    }

    /// Decoupled-discipline speculation (bounded run-ahead, no bonus).
    pub fn decoupled(method: DraftMethod, window: usize) -> SlotPlan {
        SlotPlan { method, window, mode: PlanMode::Decoupled }
    }

    pub fn is_vanilla(&self) -> bool {
        self.window == 0
    }
}

/// Two plans share a round group when they run the same verify step:
/// vanilla slots all share one decode step; speculative slots group by
/// `(method, window)`. `mode` is intentionally NOT part of the key — the
/// bonus-token discipline is applied per slot when outcomes land, so
/// coupled and decoupled slots with the same drafter and window still
/// share one verify step.
pub fn same_group(a: &SlotPlan, b: &SlotPlan) -> bool {
    (a.window == 0 && b.window == 0) || (a.window == b.window && a.method == b.method)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_groups_ignore_method_and_mode() {
        let a = SlotPlan::vanilla();
        let mut b = SlotPlan::coupled(DraftMethod::Sam, 0);
        b.mode = PlanMode::Decoupled;
        assert!(same_group(&a, &b));
    }

    #[test]
    fn speculative_groups_key_on_method_and_window() {
        let a = SlotPlan::coupled(DraftMethod::Sam, 3);
        let b = SlotPlan::decoupled(DraftMethod::Sam, 3);
        let c = SlotPlan::coupled(DraftMethod::Sam, 1);
        let d = SlotPlan::coupled(DraftMethod::Ngram, 3);
        assert!(same_group(&a, &b), "mode must not split a group");
        assert!(!same_group(&a, &c), "window must split groups");
        assert!(!same_group(&a, &d), "method must split groups");
    }

    #[test]
    fn constructors() {
        assert!(SlotPlan::vanilla().is_vanilla());
        let p = SlotPlan::decoupled(DraftMethod::Ngram, 4);
        assert_eq!(p.window, 4);
        assert_eq!(p.mode, PlanMode::Decoupled);
        assert!(!p.is_vanilla());
    }
}
