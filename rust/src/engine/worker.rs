//! Batched single-worker engine: vanilla and coupled speculative rollout
//! over a **slot-dynamic** batch.
//!
//! The worker owns `bucket` sequence slots. A slot table (`Vec<Option<Request>>`)
//! replaces the old construct-and-drain request vector: requests can be
//! admitted into free slots ([`Worker::admit`], prefill-join via a staging
//! cache + row migration) and retired out of them ([`Worker::retire`])
//! while other slots keep decoding — the substrate of the continuous
//! batching serve loop (`serve/batcher.rs`). Batch-static callers are
//! unchanged: [`Worker::new`] fills slots `0..n` with one batched prefill
//! and the `rollout_*` drivers drain them.
//!
//! The decode loop is allocation-lean: all per-round token/draft buffers
//! live in a [`Scratch`] owned by the worker and are reused across rounds
//! (see PERF.md §Memory discipline), and verification borrows logits rows
//! straight out of the runtime's [`StepOut`].
//!
//! [`StepOut`]: crate::runtime::StepOut

use std::time::Instant;

use anyhow::{bail, Result};

use crate::drafter::{DraftMethod, NgramDrafter, SamDrafter, TokenDrafter};
use crate::runtime::{KvCache, Runtime};
use crate::spec::{decode_one, verify_exact, AcceptanceStats};
use crate::util::rng::{position_rng, sample_logits};

/// One rollout request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// prompt + accepted generated tokens.
    pub seq: Vec<i32>,
    /// Maximum generated tokens (response budget).
    pub budget: usize,
    pub done: bool,
    pub accept: AcceptanceStats,
    /// Tokens generated per engine iteration this request was active in
    /// (for skipped-iteration accounting, §5.2).
    pub iterations: u64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, budget: usize) -> Self {
        Request {
            id,
            seq: prompt.clone(),
            prompt,
            budget,
            done: false,
            accept: AcceptanceStats::default(),
            iterations: 0,
        }
    }

    pub fn generated(&self) -> usize {
        self.seq.len() - self.prompt.len()
    }
}

/// Speculation mode for the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecMode {
    Vanilla,
    /// Draft `window` tokens, then verify (vanilla speculative decoding).
    Coupled { window: usize },
    /// Drafter runs ahead bounded by `window` (§4.1), on its own thread.
    Decoupled { window: usize },
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: SpecMode,
    pub drafter: DraftMethod,
    pub temperature: f32,
    /// Sampling-tape seed shared by every mode (losslessness).
    pub seed: u64,
    /// Drafter's own tape seed (draft sampling is independent).
    pub draft_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: SpecMode::Vanilla,
            drafter: DraftMethod::Model("draft_small".to_string()),
            temperature: 1.0,
            seed: 7,
            draft_seed: 1007,
        }
    }
}

/// Rollout outcome + counters.
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub wall_s: f64,
    pub total_generated: u64,
    pub target_steps: u64,
    pub draft_steps: u64,
    pub drafted_tokens: u64,
    pub accepted_tokens: u64,
    pub wasted_tokens: u64,
    /// Engine iterations where a request advanced >1 token ("skipped
    /// iterations" in the paper's §5.2 metric).
    pub skipped_iterations: u64,
    pub iterations: u64,
}

impl EngineReport {
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }
}

/// Reusable decode-loop buffers. Allocated once per worker; every round
/// borrows them via `std::mem::take` and hands them back, so the steady
/// state allocates nothing (PERF.md §Memory discipline).
#[derive(Debug, Default)]
struct Scratch {
    /// Target step/verify token inputs `[bucket * w]`.
    toks: Vec<i32>,
    /// Draft-model catch-up / decode token inputs `[bucket * w]`.
    draft_toks: Vec<i32>,
    /// Per-slot draft proposals (one reused buffer per slot).
    drafts: Vec<Vec<i32>>,
    /// Last-token seed per slot for sequential draft decode.
    last: Vec<i32>,
    /// Per-slot catch-up token debt (model drafting).
    need: Vec<usize>,
    /// Indices of occupied, not-done slots (refreshed once per round).
    active: Vec<usize>,
}

/// Batched engine worker over one `Runtime`.
pub struct Worker<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: EngineConfig,
    /// Slot table: `slots[i]` is the request occupying batch slot `i`.
    slots: Vec<Option<Request>>,
    target: String,
    bucket: usize,
    cache: KvCache,
    /// Draft model cache (model-based drafting only).
    draft_cache: Option<KvCache>,
    draft_model: Option<String>,
    /// Per-slot token drafters (ngram/sam drafting only).
    token_drafters: Vec<Option<Box<dyn TokenDrafter>>>,
    /// Per-slot: number of seq tokens consumed by the draft model cache.
    draft_consumed: Vec<usize>,
    /// Reusable staging caches for per-slot admission prefill (target /
    /// draft model), built lazily on the first `admit`.
    stage: Option<KvCache>,
    stage_draft: Option<KvCache>,
    scratch: Scratch,
    eos: i32,
    pad: i32,
    /// Cache-capacity cap on a request's generation budget.
    max_new: usize,
}

impl<'rt> Worker<'rt> {
    /// Create an **empty** worker with room for `capacity` concurrent
    /// requests (rounded up to the nearest lowered batch bucket). Requests
    /// join later via [`Worker::admit`] — the serve loop's constructor.
    pub fn with_capacity(rt: &'rt Runtime, cfg: EngineConfig, capacity: usize) -> Result<Self> {
        let m = &rt.manifest;
        let bucket = m.bucket_for(capacity.max(1))?;
        let target = m.target.clone();
        let max_new = m.model(&target)?.max_seq - m.prompt_len - 2;

        let (draft_model, draft_cache) = match &cfg.drafter {
            DraftMethod::Model(name) => {
                m.model(name)?;
                (Some(name.clone()), Some(rt.new_cache(name, bucket)?))
            }
            _ => (None, None),
        };

        Ok(Worker {
            cache: rt.new_cache(&target, bucket)?,
            draft_cache,
            draft_model,
            token_drafters: (0..bucket).map(|_| None).collect(),
            draft_consumed: vec![0; bucket],
            stage: None,
            stage_draft: None,
            slots: (0..bucket).map(|_| None).collect(),
            scratch: Scratch {
                drafts: (0..bucket).map(|_| Vec::new()).collect(),
                ..Scratch::default()
            },
            eos: m.eos_id,
            pad: m.pad_id,
            rt,
            cfg,
            target,
            bucket,
            max_new,
        })
    }

    /// Create a worker for `requests` (all sharing the manifest prompt
    /// length) and run one batched prefill on both target and drafter.
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig, requests: Vec<Request>) -> Result<Self> {
        if requests.is_empty() {
            bail!("no requests");
        }
        let mut w = Self::with_capacity(rt, cfg, requests.len())?;
        for r in &requests {
            w.validate_request(r)?;
        }
        for (i, r) in requests.into_iter().enumerate() {
            w.slots[i] = Some(r);
        }
        w.prefill_all()?;
        Ok(w)
    }

    /// Check that `req` is admissible at all (prompt length matches the
    /// manifest, budget fits the cache). The serve loop screens queued
    /// requests with this so one malformed request is rejected instead of
    /// aborting the whole batch.
    pub fn validate_request(&self, r: &Request) -> Result<()> {
        let p = self.rt.manifest.prompt_len;
        if r.prompt.len() != p {
            bail!("request {} prompt len {} != manifest prompt_len {p}", r.id, r.prompt.len());
        }
        if r.budget > self.max_new {
            bail!("budget {} exceeds cache capacity {}", r.budget, self.max_new);
        }
        Ok(())
    }

    /// Fresh per-slot token drafter for the configured method (None for
    /// model-based drafting, and for pure-vanilla workers — maintaining a
    /// drafter index per generated token would be hot-path waste when no
    /// speculative round will ever consult it).
    fn fresh_token_drafter(&self) -> Option<Box<dyn TokenDrafter>> {
        if matches!(self.cfg.mode, SpecMode::Vanilla) {
            return None;
        }
        match &self.cfg.drafter {
            DraftMethod::Model(_) => None,
            DraftMethod::Ngram => Some(Box::new(NgramDrafter::new(3)) as Box<dyn TokenDrafter>),
            DraftMethod::Sam => Some(Box::new(SamDrafter::new(16)) as Box<dyn TokenDrafter>),
        }
    }

    fn prefill_all(&mut self) -> Result<()> {
        let p = self.rt.manifest.prompt_len;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * p, self.pad);
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(r) = s {
                toks[i * p..(i + 1) * p].copy_from_slice(&r.prompt);
            }
        }
        self.rt.prefill(&self.target, &toks, &mut self.cache)?;
        // Target cache now holds the prompt; by convention the engine keeps
        // cache lens = seq_len - 1 (the last token is the next step input).
        for l in self.cache.lens.iter_mut() {
            *l = (p - 1) as i32;
        }
        if let (Some(dm), Some(dc)) = (&self.draft_model, &mut self.draft_cache) {
            self.rt.prefill(dm, &toks, dc)?;
            for l in dc.lens.iter_mut() {
                *l = (p - 1) as i32;
            }
            for c in self.draft_consumed.iter_mut() {
                *c = p - 1;
            }
        }
        self.scratch.toks = toks;
        for i in 0..self.bucket {
            let td = match &self.slots[i] {
                Some(r) => {
                    let mut td = self.fresh_token_drafter();
                    if let Some(t) = td.as_mut() {
                        t.extend(&r.prompt);
                    }
                    td
                }
                None => None,
            };
            self.token_drafters[i] = td;
        }
        Ok(())
    }

    /// Admit `req` into the free slot `slot` while the batch keeps running:
    /// prefill the prompt into a small staging cache (the whole-cache reset
    /// inside `Runtime::prefill` must not touch live slots), then migrate
    /// the row in via `extract_row`/`insert_row` — the same machinery that
    /// moves straggler caches between Fastest-of-N workers. An admission is
    /// a control-plane cost: one bucket-1 prefill plus one row copy.
    pub fn admit(&mut self, slot: usize, req: Request) -> Result<()> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        if self.slots[slot].is_some() {
            bail!("slot {slot} already occupied");
        }
        self.validate_request(&req)?;
        let p = self.rt.manifest.prompt_len;
        let sb = self.rt.manifest.bucket_for(1)?;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(sb * p, self.pad);
        toks[..p].copy_from_slice(&req.prompt);

        if self.stage.is_none() {
            self.stage = Some(self.rt.new_cache(&self.target, sb)?);
        }
        let stage = self.stage.as_mut().unwrap();
        self.rt.prefill(&self.target, &toks, stage)?;
        stage.lens[0] = (p - 1) as i32;
        let row = stage.extract_row(0)?;
        self.cache.insert_row(slot, &row)?;

        if let Some(dm) = self.draft_model.clone() {
            if self.stage_draft.is_none() {
                self.stage_draft = Some(self.rt.new_cache(&dm, sb)?);
            }
            let sd = self.stage_draft.as_mut().unwrap();
            self.rt.prefill(&dm, &toks, sd)?;
            sd.lens[0] = (p - 1) as i32;
            let drow = sd.extract_row(0)?;
            self.draft_cache
                .as_mut()
                .expect("draft cache exists for model drafting")
                .insert_row(slot, &drow)?;
            self.draft_consumed[slot] = p - 1;
        }
        self.scratch.toks = toks;

        if let Some(mut td) = self.fresh_token_drafter() {
            td.extend(&req.prompt);
            self.token_drafters[slot] = Some(td);
        }
        self.slots[slot] = Some(req);
        Ok(())
    }

    /// Remove the request occupying `slot` and free its cache rows for
    /// reuse by a later admission.
    pub fn retire(&mut self, slot: usize) -> Result<Request> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        let Some(req) = self.slots[slot].take() else {
            bail!("slot {slot} is empty");
        };
        self.cache.clear_row(slot)?;
        if let Some(dc) = &mut self.draft_cache {
            dc.clear_row(slot)?;
        }
        self.draft_consumed[slot] = 0;
        self.token_drafters[slot] = None;
        Ok(req)
    }

    /// Recompute the active-slot list into scratch (no allocation in the
    /// steady state). Returns the number of active slots.
    fn refresh_active(&mut self) -> usize {
        self.scratch.active.clear();
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(r) = s {
                if !r.done {
                    self.scratch.active.push(i);
                }
            }
        }
        self.scratch.active.len()
    }

    fn finish_check(&mut self, slot: usize) {
        let r = self.slots[slot].as_mut().unwrap();
        if r.generated() >= r.budget || r.seq.last() == Some(&self.eos) {
            r.done = true;
        }
    }

    /// One engine iteration over the currently-admitted unfinished slots:
    /// `window == 0` runs a single vanilla decode step, `window >= 1` runs
    /// one coupled draft-`window`-verify round. Returns the number of slots
    /// that participated (0 = nothing to do). The serve loop's batcher
    /// calls this once per tick with the replanner's current window.
    pub fn round(&mut self, window: usize, rep: &mut EngineReport) -> Result<usize> {
        let active = self.refresh_active();
        if active == 0 {
            return Ok(0);
        }
        if window == 0 {
            self.vanilla_round(rep)?;
        } else {
            if window + 1 > *self.rt.manifest.windows.iter().max().unwrap_or(&1) {
                bail!("verify window {} not lowered", window + 1);
            }
            self.coupled_round(window, rep)?;
        }
        Ok(active)
    }

    /// One vanilla decode step for all active slots.
    fn vanilla_round(&mut self, rep: &mut EngineReport) -> Result<()> {
        // inputs: last token of each occupied slot's sequence (pad for free)
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket, self.pad);
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(r) = s {
                toks[i] = *r.seq.last().unwrap();
            }
        }
        let out = self.rt.step(&self.target, &toks, 1, &mut self.cache)?;
        self.scratch.toks = toks;
        rep.target_steps += 1;
        rep.iterations += 1;
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            let (id, seq_len) = {
                let r = self.slots[i].as_ref().unwrap();
                (r.id, r.seq.len())
            };
            let t = decode_one(id, self.cfg.seed, self.cfg.temperature, seq_len, out.at(i, 0));
            let r = self.slots[i].as_mut().unwrap();
            r.seq.push(t);
            r.iterations += 1;
            self.cache.lens[i] += 1;
            rep.total_generated += 1;
            // keep token-drafter history in sync so vanilla rounds can be
            // interleaved with speculative ones (serve-loop replanning)
            if let Some(td) = &mut self.token_drafters[i] {
                td.extend(std::slice::from_ref(&t));
            }
            self.finish_check(i);
        }
        // done slots keep their lens frozen: the pad fed to them is
        // written at lens and overwritten by any later (unused) step.
        Ok(())
    }

    /// Plain auto-regressive rollout: one target decode step per token.
    pub fn rollout_vanilla(&mut self) -> Result<EngineReport> {
        let t0 = Instant::now();
        let mut rep = EngineReport::default();
        while self.round(0, &mut rep)? > 0 {}
        rep.wall_s = t0.elapsed().as_secs_f64();
        Ok(rep)
    }

    /// Draft `k` tokens for every active slot into `drafts` (per-slot
    /// reused buffers; active slots end up with exactly `k` tokens).
    ///
    /// Model-based drafting runs `k` batched decode steps on the draft
    /// model (after a catch-up step when needed); token drafters propose
    /// from their history index straight into the slot's buffer. Slots
    /// whose drafter has no proposal fall back to a "self-draft" of the
    /// successor guess (pad), which simply gets rejected — matching how
    /// serving engines handle empty lookahead.
    fn draft_k(&mut self, k: usize, drafts: &mut [Vec<i32>], rep: &mut EngineReport) -> Result<()> {
        for d in drafts.iter_mut() {
            d.clear();
        }
        if let (Some(dm), Some(_)) = (self.draft_model.clone(), self.draft_cache.as_ref()) {
            // 1. catch-up: feed seq tokens the draft cache hasn't consumed,
            //    except the last one (which seeds the first draft step).
            let mut need = std::mem::take(&mut self.scratch.need);
            need.clear();
            need.resize(self.bucket, 0);
            let mut max_need = 0usize;
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                let want = self.slots[i].as_ref().unwrap().seq.len() - 1;
                need[i] = want.saturating_sub(self.draft_consumed[i]);
                max_need = max_need.max(need[i]);
            }
            let mut toks = std::mem::take(&mut self.scratch.draft_toks);
            while max_need > 0 {
                let w = self.rt.manifest.window_for(max_need)?;
                toks.clear();
                toks.resize(self.bucket * w, self.pad);
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let take = need[i].min(w);
                    let start = self.draft_consumed[i];
                    toks[i * w..i * w + take]
                        .copy_from_slice(&self.slots[i].as_ref().unwrap().seq[start..start + take]);
                }
                let dc = self.draft_cache.as_mut().unwrap();
                self.rt.step(&dm, &toks, w, dc)?;
                rep.draft_steps += 1;
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let take = need[i].min(w);
                    self.draft_cache.as_mut().unwrap().lens[i] += take as i32;
                    self.draft_consumed[i] += take;
                    need[i] -= take;
                }
                max_need = need.iter().copied().max().unwrap_or(0);
            }
            // 2. k sequential draft decode steps
            let mut last = std::mem::take(&mut self.scratch.last);
            last.clear();
            last.resize(self.bucket, self.pad);
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(r) = s {
                    if !r.done {
                        last[i] = *r.seq.last().unwrap();
                    }
                }
            }
            for _ in 0..k {
                let dc = self.draft_cache.as_mut().unwrap();
                let out = self.rt.step(&dm, &last, 1, dc)?;
                rep.draft_steps += 1;
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let r = self.slots[i].as_ref().unwrap();
                    let pos = r.seq.len() + drafts[i].len();
                    let mut rng = position_rng(self.cfg.draft_seed, r.id, pos as u64);
                    let t = sample_logits(out.at(i, 0), self.cfg.temperature, &mut rng) as i32;
                    drafts[i].push(t);
                    self.draft_cache.as_mut().unwrap().lens[i] += 1;
                    self.draft_consumed[i] += 1;
                    last[i] = t;
                }
            }
            self.scratch.last = last;
            self.scratch.draft_toks = toks;
            self.scratch.need = need;
            // draft_consumed now counts speculative tokens too; verification
            // rolls it back to the accepted prefix below.
        } else {
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                if let Some(td) = &mut self.token_drafters[i] {
                    td.draft_into(k, &mut drafts[i]);
                }
                drafts[i].resize(k, self.pad); // pad empty/short proposals
            }
        }
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            rep.drafted_tokens += drafts[i].len() as u64;
        }
        Ok(())
    }

    /// One coupled speculation round for all active slots: draft `k`
    /// tokens, verify with a `k+1`-window target step, apply outcomes.
    /// Assumes `refresh_active` ran since the last `done` change.
    fn coupled_round(&mut self, k: usize, rep: &mut EngineReport) -> Result<()> {
        let mut drafts = std::mem::take(&mut self.scratch.drafts);
        self.draft_k(k, &mut drafts, rep)?;
        let w = k + 1; // verify window: [last, d0..d_{k-1}]
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * w, self.pad);
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            toks[i * w] = *self.slots[i].as_ref().unwrap().seq.last().unwrap();
            toks[i * w + 1..i * w + 1 + k].copy_from_slice(&drafts[i][..k]);
        }
        let out = self.rt.step(&self.target, &toks, w, &mut self.cache)?;
        self.scratch.toks = toks;
        rep.target_steps += 1;
        rep.iterations += 1;

        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            let (id, seq_len, budget_left) = {
                let r = self.slots[i].as_ref().unwrap();
                (r.id, r.seq.len(), r.budget - r.generated())
            };
            let outcome =
                verify_exact(id, self.cfg.seed, self.cfg.temperature, seq_len, &drafts[i], |j| {
                    out.at(i, j)
                });
            let mut append = outcome.append;
            append.truncate(budget_left);
            let advanced = append.len();
            let req = self.slots[i].as_mut().unwrap();
            req.seq.extend_from_slice(&append);
            req.accept.observe(drafts[i].len(), outcome.accepted);
            req.iterations += 1;
            let new_seq_len = req.seq.len();
            // Invariant: the target cache has consumed exactly seq.len()-1
            // tokens (the last token is the next step's input). The verify
            // step wrote w entries; only the accepted prefix is valid, and
            // that is exactly seq.len()-1 (budget truncation only lowers it,
            // which is safe: stale slots are overwritten later).
            self.cache.lens[i] = (new_seq_len - 1) as i32;
            rep.total_generated += advanced as u64;
            rep.accepted_tokens += outcome.accepted as u64;
            rep.wasted_tokens += outcome.wasted as u64;
            if advanced > 1 {
                rep.skipped_iterations += 1;
            }
            // Drafter cache rollback: the draft model consumed its own
            // drafts while drafting; only those matching the accepted
            // prefix remain valid.
            if self.draft_model.is_some() {
                let rollback = (seq_len + outcome.accepted)
                    .min(new_seq_len - 1)
                    .min(self.draft_consumed[i]);
                self.draft_consumed[i] = rollback;
                if let Some(dc) = &mut self.draft_cache {
                    dc.lens[i] = rollback as i32;
                }
            }
            // token drafter resync: extend with the accepted tokens
            if let Some(td) = &mut self.token_drafters[i] {
                td.extend(&append);
            }
            self.finish_check(i);
        }
        self.scratch.drafts = drafts;
        Ok(())
    }

    /// Coupled (vanilla) speculative rollout: draft-k-then-verify.
    pub fn rollout_coupled(&mut self, k: usize) -> Result<EngineReport> {
        if k + 1 > *self.rt.manifest.windows.iter().max().unwrap_or(&1) {
            bail!("verify window {} not lowered", k + 1);
        }
        let t0 = Instant::now();
        let mut rep = EngineReport::default();
        while self.round(k, &mut rep)? > 0 {}
        rep.wall_s = t0.elapsed().as_secs_f64();
        Ok(rep)
    }

    /// The request occupying `slot`, if any.
    pub fn request(&self, slot: usize) -> Option<&Request> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Occupied slots in slot order.
    pub fn iter_requests(&self) -> impl Iterator<Item = (usize, &Request)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|r| (i, r)))
    }

    /// Number of occupied slots (live batch size).
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when the request in `slot` has finished (empty slots: false).
    pub fn is_done(&self, slot: usize) -> bool {
        self.request(slot).map(|r| r.done).unwrap_or(false)
    }

    /// Final sequences (generated part only) of occupied slots, in slot
    /// order.
    pub fn outputs(&self) -> Vec<Vec<i32>> {
        self.iter_requests().map(|(_, r)| r.seq[r.prompt.len()..].to_vec()).collect()
    }

    pub fn target_model(&self) -> &str {
        &self.target
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }
}
