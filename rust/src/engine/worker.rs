//! Batched single-worker engine over a **slot-dynamic**, **plan-driven**
//! batch.
//!
//! The worker owns `bucket` sequence slots. A slot table
//! (`Vec<Option<Request>>`) holds the live requests; requests can be
//! admitted into free slots ([`Worker::admit`], prefill-join via a staging
//! cache + row migration) and retired out of them ([`Worker::retire`])
//! while other slots keep decoding — the substrate of the continuous
//! batching serve loop (`serve/batcher.rs`).
//!
//! Speculation is configured **per slot**, not per batch: every slot owns
//! a [`SlotPlan`] `(method, window, mode)` and [`Worker::round`] runs the
//! active slots under the config's [`VerifyDiscipline`] — by default one
//! **fused ragged** target step per round (each slot drafts its own
//! window, rows are padded to one bucket window, vanilla slots join as
//! width-1 rows, acceptance applies per row over its real window), or,
//! behind the `Grouped` A/B flag, one step per `(method, window)` plan
//! group plus a vanilla decode step — the pre-fusion engine.
//! Plans are hot-swappable mid-rollout ([`Worker::set_plan`]):
//! token drafters are rebuilt from the slot's verified prefix, and a model
//! drafter's cache row is re-fed through the ordinary catch-up path — so
//! Algorithm 2 (request-level reconfiguration) and the serve replanner
//! rewrite live slots without touching the rest of the batch.
//!
//! The decode loop is allocation-lean: all per-round token/draft/group
//! buffers live in a [`Scratch`] owned by the worker and are reused across
//! rounds (see PERF.md §Memory discipline), and verification borrows
//! logits rows straight out of the runtime's [`StepOut`].
//!
//! [`StepOut`]: crate::runtime::StepOut

use std::collections::BTreeMap;
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::drafter::corpus::{CorpusHandle, CorpusSnapshot};
use crate::drafter::{DraftMethod, TokenDrafter};
use crate::obs::{Phase, Tracer};
use crate::runtime::{KvCache, KvRow, Runtime};
use crate::spec::{decode_one, verify_exact, AcceptanceStats, VerifyOutcome};
use crate::util::rng::{position_rng, sample_logits};

use super::fault::SpecError;
use super::overlap::{PrefetchChunk, Prefetcher, ResetSpec};
use super::plan::{same_group, PlanMode, SlotPlan, VerifyDiscipline};

/// One rollout request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// prompt + accepted generated tokens.
    pub seq: Vec<i32>,
    /// Maximum generated tokens (response budget).
    pub budget: usize,
    pub done: bool,
    pub accept: AcceptanceStats,
    /// Tokens generated per engine iteration this request was active in
    /// (for skipped-iteration accounting, §5.2).
    pub iterations: u64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, budget: usize) -> Self {
        Request {
            id,
            seq: prompt.clone(),
            prompt,
            budget,
            done: false,
            accept: AcceptanceStats::default(),
            iterations: 0,
        }
    }

    pub fn generated(&self) -> usize {
        self.seq.len() - self.prompt.len()
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Default plan applied to slots constructed or admitted without an
    /// explicit per-slot plan ([`Worker::new_with_plans`] /
    /// [`Worker::admit_with_plan`] override it).
    pub plan: SlotPlan,
    /// How a round's verification executes: one fused ragged step for the
    /// whole batch (default) or one step per plan group (pre-fusion
    /// engine, kept for A/B). Token output is identical either way.
    pub verify: VerifyDiscipline,
    pub temperature: f32,
    /// Sampling-tape seed shared by every mode (losslessness).
    pub seed: u64,
    /// Drafter's own tape seed (draft sampling is independent).
    pub draft_seed: u64,
    /// Overlapped execution: prefetch round R+1's token drafts behind
    /// round R's fused verify step on a mirror thread
    /// (`engine::overlap`) and split the verify into submit/await
    /// halves. Off by default — the sequential path is the A/B
    /// baseline; both produce identical tokens (drafts only propose).
    pub overlap: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            plan: SlotPlan::vanilla(),
            verify: VerifyDiscipline::Fused,
            temperature: 1.0,
            seed: 7,
            draft_seed: 1007,
            overlap: false,
        }
    }
}

/// Per-slot draft/accept counters (Algorithm 2's measurement input): the
/// serve loop's reconfigurator takes deltas of these between firings to
/// get the *recent* acceptance rate of whatever request occupies the slot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SlotAccept {
    pub drafted: u64,
    pub accepted: u64,
}

impl SlotAccept {
    /// Acceptance rate; 1.0 when nothing was drafted (optimistic prior,
    /// matching [`AcceptanceStats::rate`]).
    pub fn rate(&self) -> f64 {
        if self.drafted == 0 {
            1.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Rollout outcome + counters.
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub wall_s: f64,
    pub total_generated: u64,
    pub target_steps: u64,
    pub draft_steps: u64,
    pub drafted_tokens: u64,
    pub accepted_tokens: u64,
    pub wasted_tokens: u64,
    /// Engine iterations where a request advanced >1 token ("skipped
    /// iterations" in the paper's §5.2 metric).
    pub skipped_iterations: u64,
    pub iterations: u64,
    /// Drafter-death degradations survived: rollouts that lost their
    /// drafter mid-flight and finished on plain decode (token-identical
    /// by the sampling-tape invariant, just slower).
    pub drafter_degrades: u64,
    /// Overlap: prefetched draft chunks consumed as-is (the full-accept
    /// prediction held, so the round's drafting cost was hidden behind
    /// the previous verify step).
    pub prefetch_hits: u64,
    /// Overlap: mis-speculated predictions — the mirror rolled its
    /// drafter state back to the verified base and the stale chunk was
    /// discarded (the slot re-drafted synchronously, as without overlap).
    pub prefetch_rollbacks: u64,
    /// Overlap: prefetch-thread deaths survived by silently falling
    /// back to sequential in-round drafting (never an abort).
    pub prefetch_deaths: u64,
    /// Overlap: drafting wall time hidden behind verify steps (the sum
    /// of consumed chunks' draft times).
    pub draft_hidden_s: f64,
    /// Per-slot drafted/accepted counters, indexed by batch slot (grown on
    /// first use; cumulative across the report's lifetime — consumers
    /// wanting recent rates take deltas).
    pub per_slot: Vec<SlotAccept>,
}

impl EngineReport {
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }

    /// Mutable per-slot counter for `slot`, growing the table as needed.
    pub fn slot_accept(&mut self, slot: usize) -> &mut SlotAccept {
        if self.per_slot.len() <= slot {
            self.per_slot.resize(slot + 1, SlotAccept::default());
        }
        &mut self.per_slot[slot]
    }
}

/// Reusable decode-loop buffers. Allocated once per worker; every round
/// borrows them via `std::mem::take` and hands them back, so the steady
/// state allocates nothing (PERF.md §Memory discipline).
#[derive(Debug, Default)]
struct Scratch {
    /// Target step/verify token inputs `[bucket * w]`.
    toks: Vec<i32>,
    /// Draft-model catch-up / decode token inputs `[bucket * w]`.
    draft_toks: Vec<i32>,
    /// Per-slot draft proposals (one reused buffer per slot).
    drafts: Vec<Vec<i32>>,
    /// Last-token seed per slot for sequential draft decode.
    last: Vec<i32>,
    /// Per-slot catch-up token debt (model drafting).
    need: Vec<usize>,
    /// Indices of occupied, not-done slots (refreshed once per round).
    active: Vec<usize>,
    /// Representative slot of each plan group (rebuilt per round; keying
    /// groups by a member slot avoids cloning `SlotPlan`s on the hot path).
    group_reps: Vec<usize>,
    /// Member slots of each plan group (vec pool, reused across rounds).
    group_slots: Vec<Vec<usize>>,
    /// Per-row real widths of the fused ragged step `[bucket]`.
    widths: Vec<usize>,
    /// Member slots of one fused per-model draft chain (reused).
    model_slots: Vec<usize>,
}

/// Per-draft-model runtime state: one KV cache spanning the whole bucket
/// plus per-slot consumed counters. Created lazily the first time any
/// slot's plan names the model; rows are re-fed from the slot's verified
/// prefix through the catch-up path after a plan switch.
struct DraftModelState {
    cache: KvCache,
    /// Per-slot count of sequence tokens this model's cache has consumed.
    consumed: Vec<usize>,
    /// Staging cache for per-slot admission prefill (lazily built).
    stage: Option<KvCache>,
}

/// Batched engine worker over one `Runtime`.
pub struct Worker<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: EngineConfig,
    /// Slot table: `slots[i]` is the request occupying batch slot `i`.
    slots: Vec<Option<Request>>,
    /// Per-slot speculation plans (entries for empty slots are inert).
    plans: Vec<SlotPlan>,
    target: String,
    bucket: usize,
    cache: KvCache,
    /// Draft-model caches, keyed by model name (a batch may speculate with
    /// several model drafters at once — one bucket-wide cache each).
    draft_models: BTreeMap<String, DraftModelState>,
    /// Per-slot token drafters (ngram/sam plans only).
    token_drafters: Vec<Option<Box<dyn TokenDrafter>>>,
    /// Reusable staging cache for target-side admission prefill.
    stage: Option<KvCache>,
    scratch: Scratch,
    eos: i32,
    pad: i32,
    /// Cache-capacity cap on a request's generation budget.
    max_new: usize,
    /// Per-phase span recorder (None → zero-cost: every record site is
    /// behind an `if let`). Installed by the serve loop's observability
    /// wiring; the worker never allocates on a record.
    tracer: Option<Tracer>,
    /// Round-R+1 draft prefetcher (`Some` only under `cfg.overlap`):
    /// mirrors eligible slots' token drafters on a worker thread and
    /// drafts the next round behind the verify step (`engine::overlap`).
    prefetch: Option<Prefetcher>,
    /// Latest prefetched chunk per slot (taken or invalidated per round).
    prefetched: Vec<Option<PrefetchChunk>>,
    /// Stamp of the last `Predict` sent per slot (0 = none outstanding).
    pf_sent: Vec<u64>,
    /// Stamp whose full-accept prediction the verifier confirmed per
    /// slot (0 = no valid chunk); only a chunk echoing this stamp may be
    /// consumed.
    pf_valid: Vec<u64>,
    /// Monotonic `Predict` stamp source (shared across slots).
    pf_stamp: u64,
    /// Prefetch-thread deaths not yet surfaced into an [`EngineReport`].
    prefetch_deaths_pending: u64,
    /// Wave-global draft corpus reader (the serve loop installs it).
    /// Consulted only at slot lifecycle events — admission, fork,
    /// migration, plan swap — never per drafted token.
    corpus: Option<CorpusHandle>,
    /// Snapshot each slot's token drafter was seeded from (None = cold
    /// start). The prefetch mirror must rebuild from the SAME snapshot
    /// the worker-side drafter used, or mirror and worker diverge.
    seeded_from: Vec<Option<Arc<CorpusSnapshot>>>,
    /// Weight-update invalidations served; the serve loop polls the
    /// delta to trigger corpus decay at the drained round boundary.
    invalidations: u64,
}

impl<'rt> Worker<'rt> {
    /// Create an **empty** worker with room for `capacity` concurrent
    /// requests (rounded up to the nearest lowered batch bucket). Requests
    /// join later via [`Worker::admit`] — the serve loop's constructor.
    pub fn with_capacity(rt: &'rt Runtime, cfg: EngineConfig, capacity: usize) -> Result<Self> {
        let m = &rt.manifest;
        let bucket = m.bucket_for(capacity.max(1))?;
        let target = m.target.clone();
        // Budget cap reserves headroom for the LARGEST lowered verify
        // window, not just one decode step: a plan group's verify runs the
        // full bucket, so every row — whatever its own plan — must satisfy
        // the runtime's lens + w <= max_seq guard for any group's w.
        let max_new = m.max_new_tokens()?;

        let w = Worker {
            cache: rt.new_cache(&target, bucket)?,
            draft_models: BTreeMap::new(),
            token_drafters: (0..bucket).map(|_| None).collect(),
            stage: None,
            slots: (0..bucket).map(|_| None).collect(),
            plans: (0..bucket).map(|_| cfg.plan.clone()).collect(),
            scratch: Scratch {
                drafts: (0..bucket).map(|_| Vec::new()).collect(),
                ..Scratch::default()
            },
            eos: m.eos_id,
            pad: m.pad_id,
            prefetch: if cfg.overlap { Some(Prefetcher::new(bucket, m.pad_id)) } else { None },
            prefetched: (0..bucket).map(|_| None).collect(),
            pf_sent: vec![0; bucket],
            pf_valid: vec![0; bucket],
            pf_stamp: 0,
            prefetch_deaths_pending: 0,
            corpus: None,
            seeded_from: (0..bucket).map(|_| None).collect(),
            invalidations: 0,
            rt,
            cfg,
            target,
            bucket,
            max_new,
            tracer: None,
        };
        w.validate_plan(&w.cfg.plan)?;
        Ok(w)
    }

    /// Install a span recorder: subsequent rounds emit Draft/Verify/Apply
    /// spans plus KV-copy spans derived from [`RuntimeStats`] deltas.
    ///
    /// [`RuntimeStats`]: crate::runtime::RuntimeStats
    pub fn set_tracer(&mut self, t: Tracer) {
        self.tracer = Some(t);
    }

    /// Install the wave-global draft corpus reader. Subsequent slot
    /// lifecycle events seed token drafters from the published snapshot
    /// instead of empty state; already-live drafters are untouched.
    pub fn set_corpus(&mut self, h: CorpusHandle) {
        self.corpus = Some(h);
    }

    /// Weight-update invalidations served so far (serve-loop decay poll).
    pub fn invalidation_count(&self) -> u64 {
        self.invalidations
    }

    /// Build a token drafter for `method`, cloned out of the published
    /// corpus snapshot when one is installed and warm (cold constructor
    /// otherwise), returning the seeding snapshot as provenance so the
    /// prefetch mirror can rebuild identically. One pointer load per
    /// lifecycle event; the per-token draft path never comes here.
    fn seeded_token_drafter(
        &self,
        method: &DraftMethod,
    ) -> (Option<Box<dyn TokenDrafter>>, Option<Arc<CorpusSnapshot>>) {
        if let Some(h) = &self.corpus {
            let snap = h.load();
            if let Some(td) = snap.seed_token_drafter(method) {
                return (Some(td), Some(snap));
            }
        }
        (method.new_token_drafter(), None)
    }

    /// Create a worker for `requests` (all sharing the manifest prompt
    /// length, all on the config's default plan) and run one batched
    /// prefill on the target and every draft model the plans name.
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig, requests: Vec<Request>) -> Result<Self> {
        let plans = vec![cfg.plan.clone(); requests.len()];
        Self::new_with_plans(rt, cfg, requests, plans)
    }

    /// Create a worker with an explicit per-slot plan for each request —
    /// a mixed-plan batch from the start (e.g. Algorithm 2 output carried
    /// over from a previous rollout phase).
    pub fn new_with_plans(
        rt: &'rt Runtime,
        cfg: EngineConfig,
        requests: Vec<Request>,
        plans: Vec<SlotPlan>,
    ) -> Result<Self> {
        if requests.is_empty() {
            bail!("no requests");
        }
        if plans.len() != requests.len() {
            bail!("{} plans for {} requests", plans.len(), requests.len());
        }
        let mut w = Self::with_capacity(rt, cfg, requests.len())?;
        for r in &requests {
            w.validate_request(r)?;
        }
        for p in &plans {
            w.validate_plan(p)?;
        }
        for (i, r) in requests.into_iter().enumerate() {
            w.slots[i] = Some(r);
        }
        for (i, p) in plans.into_iter().enumerate() {
            w.plans[i] = p;
        }
        w.prefill_all()?;
        Ok(w)
    }

    /// Check that `req` is admissible at all (prompt length matches the
    /// manifest, budget fits the cache). The serve loop screens queued
    /// requests with this so one malformed request is rejected instead of
    /// aborting the whole batch.
    pub fn validate_request(&self, r: &Request) -> Result<()> {
        let p = self.rt.manifest.prompt_len;
        if r.prompt.len() != p {
            bail!("request {} prompt len {} != manifest prompt_len {p}", r.id, r.prompt.len());
        }
        if r.budget > self.max_new {
            bail!("budget {} exceeds cache capacity {}", r.budget, self.max_new);
        }
        Ok(())
    }

    /// A plan is runnable when its verify window can be served by some
    /// lowered step executable and its draft model (if any) exists.
    fn validate_plan(&self, p: &SlotPlan) -> Result<()> {
        if p.window > 0 {
            self.verify_window_for(p.window)?;
            if let Some(name) = p.method.model_name() {
                self.rt.manifest.model(name)?;
            }
        }
        Ok(())
    }

    /// Smallest lowered step window able to verify `k` drafted tokens
    /// (`k + 1` input positions: last accepted token + the drafts). A
    /// window between lowered sizes rounds UP — the surplus positions are
    /// padded and their outputs ignored, trading a little verify compute
    /// for an unrestricted Algorithm 2 window grid.
    fn verify_window_for(&self, k: usize) -> Result<usize> {
        self.rt
            .manifest
            .windows
            .iter()
            .copied()
            .filter(|&w| w >= k + 1)
            .min()
            .ok_or_else(|| anyhow!("no lowered step window can verify draft window {k}"))
    }

    /// Lazily create the bucket-wide cache for draft model `name`.
    fn ensure_draft_model(&mut self, name: &str) -> Result<()> {
        if !self.draft_models.contains_key(name) {
            self.rt.manifest.model(name)?;
            let st = DraftModelState {
                cache: self.rt.new_cache(name, self.bucket)?,
                consumed: vec![0; self.bucket],
                stage: None,
            };
            self.draft_models.insert(name.to_string(), st);
        }
        Ok(())
    }

    fn prefill_all(&mut self) -> Result<()> {
        let p = self.rt.manifest.prompt_len;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * p, self.pad);
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(r) = s {
                toks[i * p..(i + 1) * p].copy_from_slice(&r.prompt);
            }
        }
        self.rt.prefill(&self.target, &toks, &mut self.cache)?;
        // Target cache now holds the prompt; by convention the engine keeps
        // cache lens = seq_len - 1 (the last token is the next step input).
        for l in self.cache.lens.iter_mut() {
            *l = (p - 1) as i32;
        }

        // One batched prefill per draft model named by any slot's plan,
        // covering exactly the slots that use it.
        let mut models: Vec<String> = Vec::new();
        for i in 0..self.bucket {
            if self.slots[i].is_none() || self.plans[i].window == 0 {
                continue;
            }
            if let Some(name) = self.plans[i].method.model_name() {
                if !models.iter().any(|m| m == name) {
                    models.push(name.to_string());
                }
            }
        }
        for name in models {
            self.ensure_draft_model(&name)?;
            toks.clear();
            toks.resize(self.bucket * p, self.pad);
            let mut users = vec![false; self.bucket];
            for (i, s) in self.slots.iter().enumerate() {
                let uses = s.is_some()
                    && self.plans[i].window > 0
                    && self.plans[i].method.model_name() == Some(name.as_str());
                if uses {
                    toks[i * p..(i + 1) * p]
                        .copy_from_slice(&self.slots[i].as_ref().unwrap().prompt);
                    users[i] = true;
                }
            }
            let rt = self.rt;
            let st = self.draft_models.get_mut(&name).unwrap();
            rt.prefill(&name, &toks, &mut st.cache)?;
            for i in 0..st.cache.lens.len() {
                if users[i] {
                    st.cache.lens[i] = (p - 1) as i32;
                    st.consumed[i] = p - 1;
                } else {
                    // non-user rows hold prefill junk; zero their lens so
                    // the runtime's max_seq guard never trips on them and
                    // a later plan switch re-feeds from scratch
                    st.cache.lens[i] = 0;
                    st.consumed[i] = 0;
                }
            }
        }
        self.scratch.toks = toks;

        for i in 0..self.bucket {
            if self.slots[i].is_none() || self.plans[i].window == 0 {
                self.token_drafters[i] = None;
                self.seeded_from[i] = None;
                continue;
            }
            let (mut td, seed) = self.seeded_token_drafter(&self.plans[i].method);
            if let Some(t) = td.as_mut() {
                t.extend(&self.slots[i].as_ref().unwrap().seq);
            }
            self.token_drafters[i] = td;
            self.seeded_from[i] = seed;
        }
        for i in 0..self.bucket {
            self.prefetch_reset(i);
        }
        Ok(())
    }

    /// Admit `req` into the free slot `slot` on the config's default plan.
    pub fn admit(&mut self, slot: usize, req: Request) -> Result<()> {
        let plan = self.cfg.plan.clone();
        self.admit_with_plan(slot, req, plan)
    }

    /// Admit `req` into the free slot `slot` under `plan` while the batch
    /// keeps running: prefill the prompt into a small staging cache (the
    /// whole-cache reset inside `Runtime::prefill` must not touch live
    /// slots), then migrate the row in via `extract_row`/`insert_row` —
    /// the same machinery that moves straggler caches between
    /// Fastest-of-N workers. An admission is a control-plane cost: one
    /// bucket-1 prefill plus one row copy (twice with a model drafter).
    pub fn admit_with_plan(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        if self.slots[slot].is_some() {
            bail!("slot {slot} already occupied");
        }
        self.validate_request(&req)?;
        self.validate_plan(&plan)?;
        let p = self.rt.manifest.prompt_len;
        let sb = self.rt.manifest.bucket_for(1)?;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(sb * p, self.pad);
        toks[..p].copy_from_slice(&req.prompt);

        if self.stage.is_none() {
            self.stage = Some(self.rt.new_cache(&self.target, sb)?);
        }
        let stage = self.stage.as_mut().unwrap();
        self.rt.prefill(&self.target, &toks, stage)?;
        stage.lens[0] = (p - 1) as i32;
        // Quarantine re-admission: a request carrying verified output
        // beyond its prompt replays the rest of its sequence through the
        // staging cache in windowed catch-up steps, so the migrated row
        // holds exactly seq.len() - 1 consumed tokens — byte-identical to
        // a row that never faulted. Fresh requests (seq == prompt) skip
        // this loop entirely.
        let want = req.seq.len() - 1;
        let mut consumed = p - 1;
        while consumed < want {
            let w = self.rt.manifest.window_for(want - consumed)?;
            let take = (want - consumed).min(w);
            toks.clear();
            toks.resize(sb * w, self.pad);
            toks[..take].copy_from_slice(&req.seq[consumed..consumed + take]);
            self.rt.step(&self.target, &toks, w, stage)?;
            stage.lens[0] += take as i32;
            consumed += take;
        }
        let row = stage.extract_row(0)?;
        self.cache.insert_row(slot, &row)?;

        if plan.window > 0 {
            if let Some(name) = plan.method.model_name() {
                let name = name.to_string();
                self.ensure_draft_model(&name)?;
                let rt = self.rt;
                let st = self.draft_models.get_mut(&name).unwrap();
                if st.stage.is_none() {
                    st.stage = Some(rt.new_cache(&name, sb)?);
                }
                let sd = st.stage.as_mut().unwrap();
                // the target catch-up above may have repurposed `toks`;
                // lay the prompt out again for the draft prefill
                toks.clear();
                toks.resize(sb * p, self.pad);
                toks[..p].copy_from_slice(&req.prompt);
                rt.prefill(&name, &toks, sd)?;
                sd.lens[0] = (p - 1) as i32;
                let drow = sd.extract_row(0)?;
                st.cache.insert_row(slot, &drow)?;
                st.consumed[slot] = p - 1;
            }
        }
        self.scratch.toks = toks;

        self.seeded_from[slot] = None;
        self.token_drafters[slot] = if plan.window > 0 {
            let (mut td, seed) = self.seeded_token_drafter(&plan.method);
            if let Some(t) = td.as_mut() {
                // the whole verified sequence, not just the prompt: a
                // re-admitted (quarantined) request drafts from its full
                // history exactly as it did before the fault
                t.extend(&req.seq);
            }
            self.seeded_from[slot] = seed;
            td
        } else {
            None
        };
        self.plans[slot] = plan;
        self.slots[slot] = Some(req);
        self.prefetch_reset(slot);
        Ok(())
    }

    /// Fork the live request in `src` into the **free** slot `dst` under
    /// `plan` — the engine half of Fastest-of-N racing (Algorithm 3). The
    /// replica clones the request state and copies the verified-prefix KV
    /// row through the same `extract_row`/`insert_row` migration path
    /// admissions use; its drafter state is rebuilt from the verified
    /// prefix (a token drafter re-indexes `seq`, a model drafter's cache
    /// row is re-fed lazily through the next round's catch-up, exactly
    /// like [`Worker::set_plan`]). Because the sampling tape is keyed by
    /// (seed, request id, position) — never by slot — primary and replica
    /// generate IDENTICAL tokens from here on; only their round counts
    /// differ, which is what the race arbiter measures. A fork is a
    /// control-plane cost: one KV row copy, no prefill.
    pub fn fork(&mut self, src: usize, dst: usize, plan: SlotPlan) -> Result<()> {
        if src >= self.bucket || dst >= self.bucket {
            bail!("fork {src} -> {dst} out of range (bucket {})", self.bucket);
        }
        if src == dst {
            bail!("fork source and destination are both slot {src}");
        }
        let Some(req) = self.slots[src].clone() else {
            bail!("fork source slot {src} is empty");
        };
        if req.done {
            bail!("fork source request {} already finished", req.id);
        }
        if self.slots[dst].is_some() {
            bail!("fork destination slot {dst} already occupied");
        }
        self.validate_plan(&plan)?;
        let row = self.cache.extract_row(src)?;
        self.cache.insert_row(dst, &row)?;
        self.seeded_from[dst] = None;
        self.token_drafters[dst] = if plan.window > 0 {
            if let Some(name) = plan.method.model_name() {
                // consumed stays 0: the next draft round's catch-up feeds
                // the whole verified prefix in windowed steps
                self.ensure_draft_model(name)?;
                None
            } else {
                let (td, seed) = self.seeded_token_drafter(&plan.method);
                let mut td = td.expect("token method");
                td.extend(&req.seq);
                self.seeded_from[dst] = seed;
                Some(td)
            }
        } else {
            None
        };
        self.plans[dst] = plan;
        self.slots[dst] = Some(req);
        self.prefetch_reset(dst);
        Ok(())
    }

    /// Clone the slot's verified-prefix target KV row for cross-worker
    /// migration (`runtime::transport` frames it alongside the request
    /// state). Non-destructive — the slot keeps running: pair with
    /// [`Worker::retire`] to move the request, or leave it in place to
    /// stage a cross-worker race replica while the source verifies.
    pub fn migration_row(&self, slot: usize) -> Result<KvRow> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        if self.slots[slot].is_none() {
            bail!("slot {slot} is empty");
        }
        self.cache.extract_row(slot)
    }

    /// Admit a migrated request whose verified-prefix KV row travelled
    /// with it: insert the row directly — no prefill, no target catch-up
    /// — and rebuild drafter state from the verified sequence, exactly
    /// the destination half of [`Worker::fork`] but across runtimes. A
    /// model drafter's cache is re-fed lazily through the next round's
    /// catch-up (`consumed` stays 0); a token drafter re-indexes `seq`.
    pub fn admit_with_row(
        &mut self,
        slot: usize,
        req: Request,
        plan: SlotPlan,
        row: &KvRow,
    ) -> Result<()> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        if self.slots[slot].is_some() {
            bail!("slot {slot} already occupied");
        }
        self.validate_request(&req)?;
        self.validate_plan(&plan)?;
        self.cache.insert_row(slot, row)?;
        self.seeded_from[slot] = None;
        self.token_drafters[slot] = if plan.window > 0 {
            if let Some(name) = plan.method.model_name() {
                self.ensure_draft_model(name)?;
                None
            } else {
                // migrated/forked slots land on the warm corpus too: the
                // cluster replicates epochs through the shared handle
                let (td, seed) = self.seeded_token_drafter(&plan.method);
                let mut td = td.expect("token method");
                td.extend(&req.seq);
                self.seeded_from[slot] = seed;
                Some(td)
            }
        } else {
            None
        };
        self.plans[slot] = plan;
        self.slots[slot] = Some(req);
        self.prefetch_reset(slot);
        Ok(())
    }

    /// Remove the request occupying `slot` and free its cache rows (target
    /// and every draft model) for reuse by a later admission.
    pub fn retire(&mut self, slot: usize) -> Result<Request> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        let Some(req) = self.slots[slot].take() else {
            bail!("slot {slot} is empty");
        };
        self.cache.clear_row(slot)?;
        for st in self.draft_models.values_mut() {
            st.cache.clear_row(slot)?;
            st.consumed[slot] = 0;
        }
        self.token_drafters[slot] = None;
        self.seeded_from[slot] = None;
        self.plans[slot] = self.cfg.plan.clone();
        self.prefetch_reset(slot);
        Ok(req)
    }

    /// The plan the slot currently runs under.
    pub fn plan(&self, slot: usize) -> Option<&SlotPlan> {
        self.plans.get(slot)
    }

    /// Hot-swap the slot's speculation plan mid-rollout (Algorithm 2 /
    /// serve replanning). Drafter state is rebuilt from the slot's
    /// verified prefix: a token drafter re-indexes `seq`, a model
    /// drafter's cache row is invalidated and re-fed through the next
    /// round's catch-up path. Switching between plans that share a drafter
    /// keeps the live state (the common case for window-only changes).
    pub fn set_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
        if slot >= self.bucket {
            bail!("slot {slot} out of range (bucket {})", self.bucket);
        }
        self.validate_plan(&plan)?;
        if self.slots[slot].is_none() {
            // empty slot: just record the plan (admission overrides it)
            self.plans[slot] = plan;
            return Ok(());
        }
        let old = self.plans[slot].clone();
        if old == plan {
            return Ok(());
        }
        // Same live drafter carried over? (window/mode-only change)
        let keep = old.window > 0 && plan.window > 0 && old.method == plan.method;
        if !keep {
            // tear down the old drafter surface
            self.token_drafters[slot] = None;
            self.seeded_from[slot] = None;
            if old.window > 0 {
                if let Some(oname) = old.method.model_name() {
                    if let Some(st) = self.draft_models.get_mut(oname) {
                        st.cache.clear_row(slot)?;
                        st.consumed[slot] = 0;
                    }
                }
            }
            // build the new one from the verified prefix
            if plan.window > 0 {
                if let Some(name) = plan.method.model_name() {
                    // the row is re-fed lazily: consumed = 0 makes the next
                    // draft round's catch-up feed the whole verified prefix
                    // in windowed steps (an admission-style prefill would
                    // reset the staging cache mid-batch for nothing)
                    self.ensure_draft_model(name)?;
                } else {
                    let (td, seed) = self.seeded_token_drafter(&plan.method);
                    let mut td = td.expect("token method");
                    td.extend(&self.slots[slot].as_ref().unwrap().seq);
                    self.token_drafters[slot] = Some(td);
                    self.seeded_from[slot] = seed;
                }
            }
        }
        self.plans[slot] = plan;
        self.prefetch_reset(slot);
        Ok(())
    }

    /// True when `slot` can be served by the draft prefetcher: overlap
    /// is on, the thread is alive, and the slot runs a live
    /// Decoupled-mode token-drafter plan. Coupled full-accept appends a
    /// target-sampled bonus token the mirror cannot predict, and model
    /// drafters need the (thread-bound) runtime — both fall back to
    /// sequential in-round drafting, which is always correct.
    fn prefetch_eligible(&self, slot: usize) -> bool {
        if self.prefetch.is_none() {
            return false;
        }
        let Some(r) = self.slots.get(slot).and_then(|s| s.as_ref()) else {
            return false;
        };
        let p = &self.plans[slot];
        !r.done && p.window > 0 && !p.method.is_model() && p.mode == PlanMode::Decoupled
    }

    /// Rebuild (or clear) the slot's drafter mirror after any lifecycle
    /// event that invalidates its history: admission, retire, fork,
    /// plan swap, weight-update invalidation.
    fn prefetch_reset(&mut self, slot: usize) {
        if self.prefetch.is_none() {
            return;
        }
        self.prefetched[slot] = None;
        self.pf_sent[slot] = 0;
        self.pf_valid[slot] = 0;
        let spec = if self.prefetch_eligible(slot) {
            Some(ResetSpec {
                method: self.plans[slot].method.clone(),
                window: self.plans[slot].window,
                seq: self.slots[slot].as_ref().unwrap().seq.clone(),
                // mirror from the SAME snapshot the slot drafter was
                // seeded with — a cold mirror of a warm drafter (or vice
                // versa) would predict different chunks than the worker
                seed: self.seeded_from[slot].clone(),
            })
        } else {
            None
        };
        if !self.prefetch.as_ref().unwrap().reset(slot, spec) {
            self.disable_prefetch();
        }
    }

    /// The prefetch thread died: drop the handle (joins it), forget all
    /// chunks, and count the death. Rounds keep running on sequential
    /// in-round drafting — the prefetcher is an accelerator, never a
    /// correctness dependency, so this is a silent performance fallback
    /// rather than an error.
    fn disable_prefetch(&mut self) {
        self.prefetch = None;
        self.prefetch_deaths_pending += 1;
        for c in self.prefetched.iter_mut() {
            *c = None;
        }
        for s in self.pf_sent.iter_mut() {
            *s = 0;
        }
        for v in self.pf_valid.iter_mut() {
            *v = 0;
        }
    }

    /// Pull every finished chunk off the prefetch channel (non-blocking;
    /// called at round start and inside the submit/await window). A
    /// disconnected channel means the thread died → disable. Chunk
    /// spans are back-dated by their measured draft time, so in the
    /// chrome trace they land inside the verify step they hid behind.
    fn drain_prefetch(&mut self, tracer: Option<&Tracer>) {
        let mut died = false;
        if let Some(pf) = &self.prefetch {
            loop {
                match pf.try_recv() {
                    Ok(c) => {
                        if let Some(t) = tracer {
                            let now = t.now_us();
                            t.record_with_dur(
                                Phase::PrefetchDraft,
                                now.saturating_sub(c.draft_us),
                                c.draft_us.max(1),
                                c.slot as u32,
                            );
                        }
                        if c.slot < self.prefetched.len() {
                            self.prefetched[c.slot] = Some(c);
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        died = true;
                        break;
                    }
                }
            }
        }
        if died {
            self.disable_prefetch();
        }
    }

    /// Take the slot's prefetched chunk if it is consumable this round:
    /// its stamp matches the verifier-confirmed prediction, its window
    /// matches the plan, and its base equals the slot's verified
    /// history. One-shot: valid or not, the chunk and stamp are cleared.
    fn take_prefetched(&mut self, slot: usize, k: usize) -> Option<PrefetchChunk> {
        let c = self.prefetched.get_mut(slot).and_then(|s| s.take())?;
        let confirmed = std::mem::take(&mut self.pf_valid[slot]);
        let usable = confirmed != 0
            && c.stamp == confirmed
            && c.tokens.len() == k
            && self.slots[slot].as_ref().map(|r| r.seq.len()) == Some(c.base_len);
        usable.then_some(c)
    }

    /// Recompute the active-slot list into scratch (no allocation in the
    /// steady state). Returns the number of active slots.
    fn refresh_active(&mut self) -> usize {
        self.scratch.active.clear();
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(r) = s {
                if !r.done {
                    self.scratch.active.push(i);
                }
            }
        }
        self.scratch.active.len()
    }

    fn finish_check(&mut self, slot: usize) {
        let r = self.slots[slot].as_mut().unwrap();
        if r.generated() >= r.budget || r.seq.last() == Some(&self.eos) {
            r.done = true;
        }
    }

    /// One engine iteration over the currently-admitted unfinished slots,
    /// driven by their [`SlotPlan`]s and the config's [`VerifyDiscipline`]:
    ///
    /// * **Fused** (default): every active slot drafts its own window,
    ///   then the whole batch verifies in ONE ragged target step at the
    ///   bucket window (vanilla slots ride along as width-1 rows) — the
    ///   verify intercept is paid once per round whatever the plan mix;
    /// * **Grouped** (A/B flag): one target step per `(method, window)`
    ///   plan group plus a vanilla decode step, the pre-fusion engine.
    ///
    /// Returns the number of slots that participated (0 = nothing to do).
    pub fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
        let active = self.refresh_active();
        if active == 0 {
            return Ok(0);
        }
        rep.prefetch_deaths += std::mem::take(&mut self.prefetch_deaths_pending);
        match self.cfg.verify {
            VerifyDiscipline::Fused => self.round_fused(rep)?,
            VerifyDiscipline::Grouped => self.round_grouped(rep)?,
        }
        rep.iterations += 1;
        Ok(active)
    }

    /// Partition `scratch.active` into plan groups keyed by a
    /// representative member slot (comparing plans in place; no clones on
    /// the hot path). Groups land in `scratch.group_reps` /
    /// `scratch.group_slots`; returns the group count.
    fn partition_groups(&mut self) -> usize {
        let mut reps = std::mem::take(&mut self.scratch.group_reps);
        let mut groups = std::mem::take(&mut self.scratch.group_slots);
        reps.clear();
        for g in groups.iter_mut() {
            g.clear();
        }
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            let gi = reps
                .iter()
                .position(|&r| same_group(&self.plans[r], &self.plans[i]));
            let gi = match gi {
                Some(g) => g,
                None => {
                    reps.push(i);
                    if groups.len() < reps.len() {
                        groups.push(Vec::new());
                    }
                    reps.len() - 1
                }
            };
            groups[gi].push(i);
        }
        let n = reps.len();
        self.scratch.group_reps = reps;
        self.scratch.group_slots = groups;
        n
    }

    /// Grouped-discipline round: one target step per plan group.
    fn round_grouped(&mut self, rep: &mut EngineReport) -> Result<()> {
        let n_groups = self.partition_groups();
        for g in 0..n_groups {
            let slots = std::mem::take(&mut self.scratch.group_slots[g]);
            let window = self.plans[self.scratch.group_reps[g]].window;
            let r = if window == 0 {
                self.vanilla_round(&slots, rep)
            } else {
                self.coupled_round(window, &slots, rep)
            };
            self.scratch.group_slots[g] = slots;
            r?;
        }
        Ok(())
    }

    /// Fused-discipline round: draft every speculative slot (token
    /// drafters per `(method, window)` group, model drafters in one
    /// ragged chain per model — no verification happens per group), then
    /// verify the whole batch in one ragged target step.
    fn round_fused(&mut self, rep: &mut EngineReport) -> Result<()> {
        let n_groups = self.partition_groups();
        // Bucket window: smallest lowered step window covering the widest
        // active row (`w_i` drafts + the seed token). All-vanilla rounds
        // are a plain width-1 decode step.
        let mut max_k = 0usize;
        for g in 0..n_groups {
            max_k = max_k.max(self.plans[self.scratch.group_reps[g]].window);
        }
        let w = if max_k == 0 { 1 } else { self.verify_window_for(max_k)? };

        let mut drafts = std::mem::take(&mut self.scratch.drafts);
        let res = self.fused_draft_and_verify(n_groups, w, &mut drafts, rep);
        self.scratch.drafts = drafts;
        res
    }

    fn fused_draft_and_verify(
        &mut self,
        n_groups: usize,
        w: usize,
        drafts: &mut [Vec<i32>],
        rep: &mut EngineReport,
    ) -> Result<()> {
        // Rc handle so span recording can interleave with `&mut self`
        // draft calls; cloning an Option<Tracer> is a refcount bump.
        let tracer = self.tracer.clone();
        // 0. collect chunks the mirror finished during the previous
        //    round's verify (or between rounds) — the draft loop below
        //    consumes confirmed ones instead of drafting synchronously.
        self.drain_prefetch(tracer.as_ref());
        // 1. draft (no per-group verify). Token-drafter groups draft per
        //    group as usual. Model drafting is fused per MODEL, across
        //    groups: the fused round verifies only once at the end, so a
        //    second same-model chain's full-bucket step would see the
        //    first group's speculatively-advanced cache lens (the grouped
        //    discipline rolls lens back at each group's verify) and could
        //    trip the runtime's max_seq guard near a budget boundary.
        for g in 0..n_groups {
            let rep_slot = self.scratch.group_reps[g];
            let k = self.plans[rep_slot].window;
            if k == 0 {
                continue;
            }
            let t0 = tracer.as_ref().map(|t| t.now_us());
            if self.plans[rep_slot].method.is_model() {
                let name = self.plans[rep_slot].method.model_name().unwrap();
                // one chain per model: skip groups whose model an earlier
                // group already drafted (its slots were chain members)
                let drafted_already = (0..g).any(|h| {
                    let r = self.scratch.group_reps[h];
                    self.plans[r].window > 0
                        && self.plans[r].method.model_name() == Some(name)
                });
                if drafted_already {
                    continue;
                }
                self.draft_model_fused(rep_slot, drafts, rep)?;
            } else {
                let slots = std::mem::take(&mut self.scratch.group_slots[g]);
                let r = self.draft_group(k, &slots, drafts, rep);
                self.scratch.group_slots[g] = slots;
                r?;
            }
            if let (Some(t), Some(t0)) = (&tracer, t0) {
                t.record(Phase::Draft, t0, g as u32);
            }
        }

        // 1b. hand this round's drafts to the prefetcher: the mirror
        //     assumes a full accept and drafts round R+1 while the
        //     verify step below occupies the accelerator. Mis-predicted
        //     chunks are rolled back at apply time; the real drafter
        //     state is never touched by predictions (frozen-chain
        //     discipline), so overlap cannot change tokens.
        if self.prefetch.is_some() {
            let mut died = false;
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                if !self.prefetch_eligible(i) {
                    continue;
                }
                let k = self.plans[i].window;
                self.pf_stamp += 1;
                self.pf_sent[i] = self.pf_stamp;
                let pf = self.prefetch.as_ref().unwrap();
                if !pf.predict(i, self.pf_stamp, drafts[i][..k].to_vec()) {
                    died = true;
                    break;
                }
            }
            if died {
                self.disable_prefetch();
            }
        }

        // 2. ONE fused ragged verify step across every active slot: row i
        //    carries [last, d_0..d_{k_i-1}, pad...], real width k_i + 1;
        //    free/done slots are zero-width padding rows whose cache the
        //    ragged scatter never touches.
        let mut toks = std::mem::take(&mut self.scratch.toks);
        let mut widths = std::mem::take(&mut self.scratch.widths);
        toks.clear();
        toks.resize(self.bucket * w, self.pad);
        widths.clear();
        widths.resize(self.bucket, 0);
        for &i in &self.scratch.active {
            let k = self.plans[i].window;
            toks[i * w] = *self.slots[i].as_ref().unwrap().seq.last().unwrap();
            toks[i * w + 1..i * w + 1 + k].copy_from_slice(&drafts[i][..k]);
            widths[i] = k + 1;
        }
        // widths ownership rides through the StepOut and is reclaimed
        // after the outputs are read — no per-step allocation
        let (t_verify, kv0) = match &tracer {
            Some(t) => (
                Some(t.now_us()),
                Some((self.rt.stats.kv_h2d_s(), self.rt.stats.kv_d2h_s())),
            ),
            None => (None, None),
        };
        // Submit/await split: staging + dispatch, then — while the
        // accelerator executes — drain chunks the mirror finishes, then
        // block on the outputs. Without overlap the two halves run
        // back-to-back, which is exactly the old `step_ragged`.
        let step = match self.rt.submit_ragged(&self.target, &toks, w, &self.cache, widths) {
            Ok(fl) => {
                if self.prefetch.is_some() {
                    self.drain_prefetch(tracer.as_ref());
                }
                self.rt.await_step(fl, &mut self.cache)
            }
            Err(e) => Err(e),
        };
        if let (Some(t), Some(t0), Some((h0, d0))) = (&tracer, t_verify, kv0) {
            t.record(Phase::Verify, t0, w as u32);
            // KV copy time is nested inside the verify step; carve it out
            // as sub-spans from the runtime's directional copy ledger.
            let h2d = ((self.rt.stats.kv_h2d_s() - h0) * 1e6) as u64;
            let d2h = ((self.rt.stats.kv_d2h_s() - d0) * 1e6) as u64;
            if h2d > 0 {
                t.record_with_dur(Phase::KvH2d, t0, h2d, 0);
            }
            if d2h > 0 {
                t.record_with_dur(Phase::KvD2h, t0 + h2d, d2h, 0);
            }
        }
        self.scratch.toks = toks;
        let mut out = step?;
        rep.target_steps += 1;

        // 3. per-row outcomes over each row's REAL window only — the
        //    guarded accessor refuses reads into the padded tail.
        let t_apply = tracer.as_ref().map(|t| t.now_us());
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            let k = self.plans[i].window;
            let (id, seq_len) = {
                let r = self.slots[i].as_ref().unwrap();
                (r.id, r.seq.len())
            };
            if k == 0 {
                let t = decode_one(
                    id,
                    self.cfg.seed,
                    self.cfg.temperature,
                    seq_len,
                    out.logits_at(i, 0)?,
                );
                self.apply_decode(i, t, rep);
            } else {
                // Typed guard instead of a panic inside the closure: the
                // verify reads j in 0..=k and the row was stepped at
                // width k + 1, so a short row means the KV row no longer
                // matches the request — a quarantinable fault, not an
                // engine abort.
                if out.logits_at(i, k).is_err() {
                    return Err(SpecError::KvRowInvalid {
                        slot: i,
                        detail: format!("verify row narrower than its window {k}"),
                    }
                    .into());
                }
                let outcome = verify_exact(
                    id,
                    self.cfg.seed,
                    self.cfg.temperature,
                    seq_len,
                    &drafts[i],
                    |j| out.logits_at(i, j).expect("guarded above: j <= k is inside the row"),
                );
                self.apply_outcome(i, drafts[i].len(), outcome, rep);
            }
        }
        if let (Some(t), Some(t0)) = (&tracer, t_apply) {
            t.record(Phase::Apply, t0, self.scratch.active.len() as u32);
        }
        self.scratch.widths = out.widths.take().unwrap_or_default();
        Ok(())
    }

    /// One vanilla decode step for the window-0 group.
    fn vanilla_round(&mut self, slots: &[usize], rep: &mut EngineReport) -> Result<()> {
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket, self.pad);
        for &i in slots {
            toks[i] = *self.slots[i].as_ref().unwrap().seq.last().unwrap();
        }
        let out = self.rt.step(&self.target, &toks, 1, &mut self.cache)?;
        self.scratch.toks = toks;
        rep.target_steps += 1;
        for &i in slots {
            let (id, seq_len) = {
                let r = self.slots[i].as_ref().unwrap();
                (r.id, r.seq.len())
            };
            let t = decode_one(id, self.cfg.seed, self.cfg.temperature, seq_len, out.at(i, 0));
            self.apply_decode(i, t, rep);
        }
        // slots outside the group keep their lens frozen: the pad fed to
        // them is written at lens and overwritten by their own next step.
        Ok(())
    }

    /// Apply one vanilla-decoded token to `slot`: sequence push,
    /// cache-lens advance, token-drafter sync, finish check, counters.
    /// Shared by the grouped vanilla step and the fused step's width-1
    /// rows so the two disciplines cannot drift.
    fn apply_decode(&mut self, i: usize, t: i32, rep: &mut EngineReport) {
        let r = self.slots[i].as_mut().unwrap();
        r.seq.push(t);
        r.iterations += 1;
        self.cache.lens[i] += 1;
        rep.total_generated += 1;
        // keep token-drafter history in sync so vanilla rounds can be
        // interleaved with speculative ones (plan switches)
        if let Some(td) = &mut self.token_drafters[i] {
            td.extend(std::slice::from_ref(&t));
        }
        self.finish_check(i);
    }

    /// Apply one slot's verify outcome: bonus-token discipline per the
    /// slot's mode, budget truncation, target/draft cache-lens rollback,
    /// token-drafter resync, finish check and counters. Shared by the
    /// grouped and fused verify paths.
    fn apply_outcome(
        &mut self,
        i: usize,
        drafted: usize,
        outcome: VerifyOutcome,
        rep: &mut EngineReport,
    ) {
        let (seq_len, budget_left) = {
            let r = self.slots[i].as_ref().unwrap();
            (r.seq.len(), r.budget - r.generated())
        };
        let mut append = outcome.append;
        if outcome.full_accept && self.plans[i].mode == PlanMode::Decoupled {
            // Decoupled discipline takes no bonus token: the tape
            // re-samples the identical token at that position later, so
            // losslessness is unaffected (see engine::decoupled docs).
            append.pop();
        }
        append.truncate(budget_left);
        let advanced = append.len();
        let req = self.slots[i].as_mut().unwrap();
        req.seq.extend_from_slice(&append);
        req.accept.observe(drafted, outcome.accepted);
        req.iterations += 1;
        let new_seq_len = req.seq.len();
        // Invariant: the target cache has consumed exactly seq.len()-1
        // tokens (the last token is the next step's input). The verify
        // step wrote the row's real width; only the accepted prefix is
        // valid, and that is exactly seq.len()-1 (budget truncation only
        // lowers it, which is safe: stale slots are overwritten later).
        self.cache.lens[i] = (new_seq_len - 1) as i32;
        rep.total_generated += advanced as u64;
        rep.accepted_tokens += outcome.accepted as u64;
        rep.wasted_tokens += outcome.wasted as u64;
        rep.slot_accept(i).accepted += outcome.accepted as u64;
        if advanced > 1 {
            rep.skipped_iterations += 1;
        }
        // Drafter cache rollback: the draft model consumed its own
        // drafts while drafting; only those matching the accepted
        // prefix remain valid.
        if let Some(name) = self.plans[i].method.model_name() {
            if let Some(st) = self.draft_models.get_mut(name) {
                let rollback = (seq_len + outcome.accepted)
                    .min(new_seq_len - 1)
                    .min(st.consumed[i]);
                st.consumed[i] = rollback;
                st.cache.lens[i] = rollback as i32;
            }
        }
        // token drafter resync: extend with the accepted tokens
        if let Some(td) = &mut self.token_drafters[i] {
            td.extend(&append);
        }
        // Prefetch reconciliation: settle this round's prediction and
        // hand the verified outcome to the mirror. The prediction held
        // only on an untruncated decoupled full accept (the mirror
        // assumed exactly the k drafts, no bonus); anything else is a
        // mis-speculation — the chunk drafted from the wrong history is
        // condemned and the mirror rolls back to the verified base.
        if self.prefetch.is_some() && self.prefetch_eligible(i) {
            if self.pf_sent[i] != 0 {
                let held = outcome.full_accept
                    && self.plans[i].mode == PlanMode::Decoupled
                    && advanced == drafted;
                if held {
                    self.pf_valid[i] = self.pf_sent[i];
                } else {
                    self.pf_valid[i] = 0;
                    rep.prefetch_rollbacks += 1;
                }
                self.pf_sent[i] = 0;
            }
            let pf = self.prefetch.as_ref().unwrap();
            if !pf.resolve(i, seq_len, append.clone()) {
                self.disable_prefetch();
            }
        }
        self.finish_check(i);
    }

    /// Draft `k` tokens for every slot of one plan group into `drafts`
    /// (per-slot reused buffers; group slots end up with exactly `k`
    /// tokens). The group's method is read from its first member's plan.
    ///
    /// Model-based drafting runs `k` batched decode steps on the draft
    /// model (after a catch-up phase that also re-feeds rows invalidated
    /// by a plan switch); token drafters propose from their history index
    /// straight into the slot's buffer. Slots whose drafter has no
    /// proposal fall back to a "self-draft" of pad, which simply gets
    /// rejected — matching how serving engines handle empty lookahead.
    fn draft_group(
        &mut self,
        k: usize,
        slots: &[usize],
        drafts: &mut [Vec<i32>],
        rep: &mut EngineReport,
    ) -> Result<()> {
        for &i in slots {
            drafts[i].clear();
        }
        let is_model = self.plans[slots[0]].method.is_model();
        if is_model {
            // Take the model state out of the map so the runtime and slot
            // table stay borrowable; put it back whatever happens.
            let (name, mut st) = {
                let name = self.plans[slots[0]].method.model_name().unwrap();
                self.draft_models
                    .remove_entry(name)
                    .ok_or_else(|| anyhow!("draft model state missing for {name:?}"))?
            };
            let res = self.draft_group_model(&name, &mut st, slots, drafts, rep);
            self.draft_models.insert(name, st);
            res?;
        } else {
            for &i in slots {
                // A confirmed prefetched chunk replaces the synchronous
                // draft: its cost was paid behind the previous verify
                // step. The chunk is byte-identical to what draft_into
                // would produce (the mirror ran the same drafter over
                // the same confirmed history), so consuming it cannot
                // change tokens — only wall time.
                if let Some(c) = self.take_prefetched(i, k) {
                    drafts[i].extend_from_slice(&c.tokens);
                    rep.prefetch_hits += 1;
                    rep.draft_hidden_s += c.draft_us as f64 * 1e-6;
                } else if let Some(td) = &mut self.token_drafters[i] {
                    td.draft_into(k, &mut drafts[i]);
                }
                drafts[i].resize(k, self.pad); // pad empty/short proposals
            }
        }
        for &i in slots {
            rep.drafted_tokens += drafts[i].len() as u64;
            rep.slot_accept(i).drafted += drafts[i].len() as u64;
        }
        Ok(())
    }

    /// Model-drafting chain shared by the grouped path (uniform member
    /// windows — one `(method, window)` group) and the fused per-model
    /// path (mixed member windows across groups): catch-up, then up to
    /// the largest member window's sequential decode steps on draft model
    /// `name`. Each slot stops consuming at its OWN window; a full-chunk
    /// row rides the chain on its last token with its cache position
    /// frozen (the decoupled drafter thread's discipline), so mixed and
    /// uniform chains produce identical per-slot drafts.
    fn draft_group_model(
        &mut self,
        name: &str,
        st: &mut DraftModelState,
        slots: &[usize],
        drafts: &mut [Vec<i32>],
        rep: &mut EngineReport,
    ) -> Result<()> {
        // 1. catch-up: feed seq tokens the draft cache hasn't consumed,
        //    except the last one (which seeds the first draft step). A
        //    just-switched slot has consumed = 0 and is re-fed wholesale.
        let mut need = std::mem::take(&mut self.scratch.need);
        need.clear();
        need.resize(self.bucket, 0);
        let mut max_need = 0usize;
        for &i in slots {
            let want = self.slots[i].as_ref().unwrap().seq.len() - 1;
            need[i] = want.saturating_sub(st.consumed[i]);
            max_need = max_need.max(need[i]);
        }
        let mut toks = std::mem::take(&mut self.scratch.draft_toks);
        while max_need > 0 {
            let w = self.rt.manifest.window_for(max_need)?;
            toks.clear();
            toks.resize(self.bucket * w, self.pad);
            for &i in slots {
                let take = need[i].min(w);
                let start = st.consumed[i];
                toks[i * w..i * w + take]
                    .copy_from_slice(&self.slots[i].as_ref().unwrap().seq[start..start + take]);
            }
            self.rt.step(name, &toks, w, &mut st.cache)?;
            rep.draft_steps += 1;
            for &i in slots {
                let take = need[i].min(w);
                st.cache.lens[i] += take as i32;
                st.consumed[i] += take;
                need[i] -= take;
            }
            max_need = slots.iter().map(|&i| need[i]).max().unwrap_or(0);
        }
        // 2. ragged decode chain: up to the largest member window
        let k_max = slots.iter().map(|&i| self.plans[i].window).max().unwrap_or(0);
        let mut last = std::mem::take(&mut self.scratch.last);
        last.clear();
        last.resize(self.bucket, self.pad);
        for &i in slots {
            last[i] = *self.slots[i].as_ref().unwrap().seq.last().unwrap();
        }
        for _ in 0..k_max {
            let out = self.rt.step(name, &last, 1, &mut st.cache)?;
            rep.draft_steps += 1;
            for &i in slots {
                if drafts[i].len() >= self.plans[i].window {
                    // chunk full: this row was stepped with a stale token;
                    // its cache position is not advanced and the written
                    // entry is overwritten by the row's next real step
                    continue;
                }
                let r = self.slots[i].as_ref().unwrap();
                let pos = r.seq.len() + drafts[i].len();
                let mut rng = position_rng(self.cfg.draft_seed, r.id, pos as u64);
                let t = sample_logits(out.at(i, 0), self.cfg.temperature, &mut rng) as i32;
                drafts[i].push(t);
                st.cache.lens[i] += 1;
                st.consumed[i] += 1;
                last[i] = t;
            }
        }
        self.scratch.last = last;
        self.scratch.draft_toks = toks;
        self.scratch.need = need;
        // consumed now counts speculative tokens too; verification rolls
        // it back to the accepted prefix (`apply_outcome`).
        Ok(())
    }

    /// Fused-round model drafting: ONE [`Worker::draft_group_model`]
    /// chain for EVERY active slot drafting with the model named by
    /// `rep_slot`'s plan, whatever their windows. (Same-model plan groups
    /// must share a chain in the fused round: lens rollback only happens
    /// at the single end-of-round verify, so a second chain's full-bucket
    /// step would see the first's speculatively-advanced cache lens.)
    fn draft_model_fused(
        &mut self,
        rep_slot: usize,
        drafts: &mut [Vec<i32>],
        rep: &mut EngineReport,
    ) -> Result<()> {
        let (name, mut st) = {
            let name = self.plans[rep_slot].method.model_name().unwrap();
            self.draft_models
                .remove_entry(name)
                .ok_or_else(|| anyhow!("draft model state missing for {name:?}"))?
        };
        let mut members = std::mem::take(&mut self.scratch.model_slots);
        members.clear();
        for &i in &self.scratch.active {
            if self.plans[i].window > 0 && self.plans[i].method.model_name() == Some(name.as_str())
            {
                drafts[i].clear();
                members.push(i);
            }
        }
        let res = self.draft_group_model(&name, &mut st, &members, drafts, rep);
        if res.is_ok() {
            for &i in &members {
                rep.drafted_tokens += drafts[i].len() as u64;
                rep.slot_accept(i).drafted += drafts[i].len() as u64;
            }
        }
        self.scratch.model_slots = members;
        self.draft_models.insert(name, st);
        res
    }

    /// One speculation round for a `(method, window)` plan group: draft
    /// `k` tokens, verify with one target step, apply outcomes under each
    /// slot's own mode (coupled keeps the bonus token on full accept;
    /// decoupled drops it — the threaded pipeline's token dynamics).
    fn coupled_round(&mut self, k: usize, slots: &[usize], rep: &mut EngineReport) -> Result<()> {
        let mut drafts = std::mem::take(&mut self.scratch.drafts);
        let res = self.verify_group(k, slots, &mut drafts, rep);
        self.scratch.drafts = drafts;
        res
    }

    fn verify_group(
        &mut self,
        k: usize,
        slots: &[usize],
        drafts: &mut [Vec<i32>],
        rep: &mut EngineReport,
    ) -> Result<()> {
        self.draft_group(k, slots, drafts, rep)?;
        // verify window: [last, d0..d_{k-1}] (+ padding up to a lowered w)
        let w = self.verify_window_for(k)?;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * w, self.pad);
        for &i in slots {
            toks[i * w] = *self.slots[i].as_ref().unwrap().seq.last().unwrap();
            toks[i * w + 1..i * w + 1 + k].copy_from_slice(&drafts[i][..k]);
        }
        let out = self.rt.step(&self.target, &toks, w, &mut self.cache)?;
        self.scratch.toks = toks;
        rep.target_steps += 1;

        for &i in slots {
            let (id, seq_len) = {
                let r = self.slots[i].as_ref().unwrap();
                (r.id, r.seq.len())
            };
            let outcome =
                verify_exact(id, self.cfg.seed, self.cfg.temperature, seq_len, &drafts[i], |j| {
                    out.at(i, j)
                });
            self.apply_outcome(i, drafts[i].len(), outcome, rep);
        }
        Ok(())
    }

    /// Drain the batch under the current per-slot plans: the plan-driven
    /// rollout driver ([`Worker::round`] in a loop).
    pub fn rollout_planned(&mut self) -> Result<EngineReport> {
        let t0 = Instant::now();
        let mut rep = EngineReport::default();
        while self.round(&mut rep)? > 0 {}
        rep.wall_s = t0.elapsed().as_secs_f64();
        Ok(rep)
    }

    /// Plain auto-regressive rollout: forces every occupied slot onto the
    /// vanilla plan, then drains.
    pub fn rollout_vanilla(&mut self) -> Result<EngineReport> {
        for i in 0..self.bucket {
            if self.slots[i].is_some() && self.plans[i].window != 0 {
                let p = SlotPlan { window: 0, ..self.plans[i].clone() };
                self.set_plan(i, p)?;
            }
        }
        self.rollout_planned()
    }

    /// Coupled (vanilla speculative) rollout: forces every occupied slot
    /// onto `Coupled { window: k }` with its current method, then drains.
    pub fn rollout_coupled(&mut self, k: usize) -> Result<EngineReport> {
        for i in 0..self.bucket {
            if self.slots[i].is_none() {
                continue;
            }
            let p = SlotPlan {
                method: self.plans[i].method.clone(),
                window: k,
                mode: PlanMode::Coupled,
            };
            self.set_plan(i, p)?;
        }
        self.rollout_planned()
    }

    /// Weight-update invalidation hook (the serve loop's
    /// `ServeEngine::invalidate_draft_state`): the policy weights changed
    /// mid-wave, so every draft-side cache is stale. Draft-model rows are
    /// invalidated in place (`consumed = 0` — the next draft round's
    /// catch-up re-feeds each verified prefix in windowed steps, exactly
    /// like a plan switch) and token drafters are rebuilt from the
    /// verified sequences. Target-side state belongs to the new weights
    /// and is not touched here. Lossless by construction: drafts only
    /// *propose* — verification against the target decides every token.
    pub fn invalidate_draft_state(&mut self) -> Result<()> {
        self.invalidations += 1;
        for st in self.draft_models.values_mut() {
            for slot in 0..self.bucket {
                st.cache.clear_row(slot)?;
                st.consumed[slot] = 0;
            }
        }
        for slot in 0..self.bucket {
            let Some(r) = self.slots[slot].as_ref() else {
                continue;
            };
            if self.plans[slot].window == 0 || self.plans[slot].method.is_model() {
                continue;
            }
            // deliberately UNSEEDED: the published corpus indexed the OLD
            // policy's continuations, so it is stale by definition at this
            // instant — the serve loop decays/reseeds it at the next round
            // boundary, and later lifecycle events pick the fresh epoch up
            let mut td = self.plans[slot].method.new_token_drafter().ok_or_else(|| {
                anyhow!("plan method for slot {slot} names no token drafter")
            })?;
            td.extend(&r.seq);
            self.token_drafters[slot] = Some(td);
            self.seeded_from[slot] = None;
        }
        // mirrors indexed the pre-update drafts; rebuild them from the
        // verified sequences exactly like the worker-side drafters
        for slot in 0..self.bucket {
            self.prefetch_reset(slot);
        }
        Ok(())
    }

    /// The request occupying `slot`, if any.
    pub fn request(&self, slot: usize) -> Option<&Request> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Occupied slots in slot order.
    pub fn iter_requests(&self) -> impl Iterator<Item = (usize, &Request)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|r| (i, r)))
    }

    /// Number of occupied slots (live batch size).
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when the request in `slot` has finished (empty slots: false).
    pub fn is_done(&self, slot: usize) -> bool {
        self.request(slot).map(|r| r.done).unwrap_or(false)
    }

    /// Final sequences (generated part only) of occupied slots, in slot
    /// order.
    pub fn outputs(&self) -> Vec<Vec<i32>> {
        self.iter_requests().map(|(_, r)| r.seq[r.prompt.len()..].to_vec()).collect()
    }

    pub fn target_model(&self) -> &str {
        &self.target
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }
}
