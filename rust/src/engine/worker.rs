//! Batched single-worker engine: vanilla and coupled speculative rollout.
//!
//! The decode loop is allocation-lean: all per-round token/draft buffers
//! live in a [`Scratch`] owned by the worker and are reused across rounds
//! (see PERF.md §Memory discipline), and verification borrows logits rows
//! straight out of the runtime's [`StepOut`].
//!
//! [`StepOut`]: crate::runtime::StepOut

use std::time::Instant;

use anyhow::{bail, Result};

use crate::drafter::{DraftMethod, NgramDrafter, SamDrafter, TokenDrafter};
use crate::runtime::{KvCache, Runtime};
use crate::spec::{decode_one, verify_exact, AcceptanceStats};
use crate::util::rng::{position_rng, sample_logits};

/// One rollout request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// prompt + accepted generated tokens.
    pub seq: Vec<i32>,
    /// Maximum generated tokens (response budget).
    pub budget: usize,
    pub done: bool,
    pub accept: AcceptanceStats,
    /// Tokens generated per engine iteration this request was active in
    /// (for skipped-iteration accounting, §5.2).
    pub iterations: u64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, budget: usize) -> Self {
        Request {
            id,
            seq: prompt.clone(),
            prompt,
            budget,
            done: false,
            accept: AcceptanceStats::default(),
            iterations: 0,
        }
    }

    pub fn generated(&self) -> usize {
        self.seq.len() - self.prompt.len()
    }
}

/// Speculation mode for the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecMode {
    Vanilla,
    /// Draft `window` tokens, then verify (vanilla speculative decoding).
    Coupled { window: usize },
    /// Drafter runs ahead bounded by `window` (§4.1), on its own thread.
    Decoupled { window: usize },
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: SpecMode,
    pub drafter: DraftMethod,
    pub temperature: f32,
    /// Sampling-tape seed shared by every mode (losslessness).
    pub seed: u64,
    /// Drafter's own tape seed (draft sampling is independent).
    pub draft_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: SpecMode::Vanilla,
            drafter: DraftMethod::Model("draft_small".to_string()),
            temperature: 1.0,
            seed: 7,
            draft_seed: 1007,
        }
    }
}

/// Rollout outcome + counters.
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub wall_s: f64,
    pub total_generated: u64,
    pub target_steps: u64,
    pub draft_steps: u64,
    pub drafted_tokens: u64,
    pub accepted_tokens: u64,
    pub wasted_tokens: u64,
    /// Engine iterations where a request advanced >1 token ("skipped
    /// iterations" in the paper's §5.2 metric).
    pub skipped_iterations: u64,
    pub iterations: u64,
}

impl EngineReport {
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }
}

/// Reusable decode-loop buffers. Allocated once per worker; every round
/// borrows them via `std::mem::take` and hands them back, so the steady
/// state allocates nothing (PERF.md §Memory discipline).
#[derive(Debug, Default)]
struct Scratch {
    /// Target step/verify token inputs `[bucket * w]`.
    toks: Vec<i32>,
    /// Draft-model catch-up / decode token inputs `[bucket * w]`.
    draft_toks: Vec<i32>,
    /// Per-slot draft proposals (one reused buffer per slot).
    drafts: Vec<Vec<i32>>,
    /// Last-token seed per slot for sequential draft decode.
    last: Vec<i32>,
    /// Per-slot catch-up token debt (model drafting).
    need: Vec<usize>,
    /// Indices of not-done requests (refreshed once per round).
    active: Vec<usize>,
}

/// Batched engine worker over one `Runtime`.
pub struct Worker<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: EngineConfig,
    pub requests: Vec<Request>,
    target: String,
    bucket: usize,
    cache: KvCache,
    /// Draft model cache (model-based drafting only).
    draft_cache: Option<KvCache>,
    draft_model: Option<String>,
    /// Per-slot token drafters (ngram/sam drafting only).
    token_drafters: Vec<Option<Box<dyn TokenDrafter>>>,
    /// Per-slot: number of seq tokens consumed by the draft model cache.
    draft_consumed: Vec<usize>,
    scratch: Scratch,
    eos: i32,
    pad: i32,
}

impl<'rt> Worker<'rt> {
    /// Create a worker for `requests` (all sharing the manifest prompt
    /// length) and run prefill on both target and drafter.
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig, requests: Vec<Request>) -> Result<Self> {
        if requests.is_empty() {
            bail!("no requests");
        }
        let m = &rt.manifest;
        let p = m.prompt_len;
        for r in &requests {
            if r.prompt.len() != p {
                bail!("request {} prompt len {} != manifest prompt_len {p}", r.id, r.prompt.len());
            }
        }
        let bucket = m.bucket_for(requests.len())?;
        let target = m.target.clone();
        let max_new = m.model(&target)?.max_seq - p - 2;
        for r in &requests {
            if r.budget > max_new {
                bail!("budget {} exceeds cache capacity {max_new}", r.budget);
            }
        }

        let (draft_model, token_drafters): (Option<String>, Vec<Option<Box<dyn TokenDrafter>>>) =
            match &cfg.drafter {
                DraftMethod::Model(name) => {
                    m.model(name)?;
                    (Some(name.clone()), (0..bucket).map(|_| None).collect())
                }
                DraftMethod::Ngram => (
                    None,
                    (0..bucket)
                        .map(|_| Some(Box::new(NgramDrafter::new(3)) as Box<dyn TokenDrafter>))
                        .collect(),
                ),
                DraftMethod::Sam => (
                    None,
                    (0..bucket)
                        .map(|_| Some(Box::new(SamDrafter::new(16)) as Box<dyn TokenDrafter>))
                        .collect(),
                ),
            };

        let n = requests.len();
        let mut w = Worker {
            cache: rt.new_cache(&target, bucket)?,
            draft_cache: match &draft_model {
                Some(dm) => Some(rt.new_cache(dm, bucket)?),
                None => None,
            },
            draft_model,
            token_drafters,
            draft_consumed: vec![0; bucket],
            scratch: Scratch {
                drafts: (0..n).map(|_| Vec::new()).collect(),
                ..Scratch::default()
            },
            eos: m.eos_id,
            pad: m.pad_id,
            rt,
            cfg,
            requests,
            target,
            bucket,
        };
        w.prefill_all()?;
        Ok(w)
    }

    fn prefill_all(&mut self) -> Result<()> {
        let p = self.rt.manifest.prompt_len;
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * p, self.pad);
        for (i, r) in self.requests.iter().enumerate() {
            toks[i * p..(i + 1) * p].copy_from_slice(&r.prompt);
        }
        self.rt.prefill(&self.target, &toks, &mut self.cache)?;
        // Target cache now holds the prompt; by convention the engine keeps
        // cache lens = seq_len - 1 (the last token is the next step input).
        for l in self.cache.lens.iter_mut() {
            *l = (p - 1) as i32;
        }
        if let (Some(dm), Some(dc)) = (&self.draft_model, &mut self.draft_cache) {
            self.rt.prefill(dm, &toks, dc)?;
            for l in dc.lens.iter_mut() {
                *l = (p - 1) as i32;
            }
            for c in self.draft_consumed.iter_mut() {
                *c = p - 1;
            }
        }
        self.scratch.toks = toks;
        for (i, td) in self.token_drafters.iter_mut().enumerate() {
            if let Some(td) = td {
                td.reset();
                if i < self.requests.len() {
                    td.extend(&self.requests[i].prompt);
                }
            }
        }
        Ok(())
    }

    /// Recompute the active-slot list into scratch (no allocation in the
    /// steady state). Returns the number of active slots.
    fn refresh_active(&mut self) -> usize {
        self.scratch.active.clear();
        for (i, r) in self.requests.iter().enumerate() {
            if !r.done {
                self.scratch.active.push(i);
            }
        }
        self.scratch.active.len()
    }

    fn finish_check(&mut self, slot: usize) {
        let r = &mut self.requests[slot];
        if r.generated() >= r.budget || r.seq.last() == Some(&self.eos) {
            r.done = true;
        }
    }

    /// Plain auto-regressive rollout: one target decode step per token.
    pub fn rollout_vanilla(&mut self) -> Result<EngineReport> {
        let t0 = Instant::now();
        let mut rep = EngineReport::default();
        while self.refresh_active() > 0 {
            // inputs: last token of each slot's sequence (pad for done)
            let mut toks = std::mem::take(&mut self.scratch.toks);
            toks.clear();
            toks.resize(self.bucket, self.pad);
            for (i, r) in self.requests.iter().enumerate() {
                toks[i] = *r.seq.last().unwrap();
            }
            let out = self.rt.step(&self.target, &toks, 1, &mut self.cache)?;
            self.scratch.toks = toks;
            rep.target_steps += 1;
            rep.iterations += 1;
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                let r = &self.requests[i];
                let t = decode_one(r.id, self.cfg.seed, self.cfg.temperature, r.seq.len(), out.at(i, 0));
                self.requests[i].seq.push(t);
                self.requests[i].iterations += 1;
                self.cache.lens[i] += 1;
                rep.total_generated += 1;
                self.finish_check(i);
            }
            // done slots keep their lens frozen: the pad fed to them is
            // written at lens and overwritten by any later (unused) step.
        }
        rep.wall_s = t0.elapsed().as_secs_f64();
        Ok(rep)
    }

    /// Draft `k` tokens for every active slot into `drafts` (per-slot
    /// reused buffers; active slots end up with exactly `k` tokens).
    ///
    /// Model-based drafting runs `k` batched decode steps on the draft
    /// model (after a catch-up step when needed); token drafters propose
    /// from their history index straight into the slot's buffer. Slots
    /// whose drafter has no proposal fall back to a "self-draft" of the
    /// successor guess (pad), which simply gets rejected — matching how
    /// serving engines handle empty lookahead.
    fn draft_k(&mut self, k: usize, drafts: &mut [Vec<i32>], rep: &mut EngineReport) -> Result<()> {
        for d in drafts.iter_mut() {
            d.clear();
        }
        let n = self.requests.len();
        if let (Some(dm), Some(_)) = (self.draft_model.clone(), self.draft_cache.as_ref()) {
            // 1. catch-up: feed seq tokens the draft cache hasn't consumed,
            //    except the last one (which seeds the first draft step).
            let mut need = std::mem::take(&mut self.scratch.need);
            need.clear();
            need.resize(self.bucket, 0);
            let mut max_need = 0usize;
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                let want = self.requests[i].seq.len() - 1;
                need[i] = want.saturating_sub(self.draft_consumed[i]);
                max_need = max_need.max(need[i]);
            }
            let mut toks = std::mem::take(&mut self.scratch.draft_toks);
            while max_need > 0 {
                let w = self.rt.manifest.window_for(max_need)?;
                toks.clear();
                toks.resize(self.bucket * w, self.pad);
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let take = need[i].min(w);
                    let start = self.draft_consumed[i];
                    toks[i * w..i * w + take]
                        .copy_from_slice(&self.requests[i].seq[start..start + take]);
                }
                let dc = self.draft_cache.as_mut().unwrap();
                self.rt.step(&dm, &toks, w, dc)?;
                rep.draft_steps += 1;
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let take = need[i].min(w);
                    self.draft_cache.as_mut().unwrap().lens[i] += take as i32;
                    self.draft_consumed[i] += take;
                    need[i] -= take;
                }
                max_need = need.iter().copied().max().unwrap_or(0);
            }
            // 2. k sequential draft decode steps
            let mut last = std::mem::take(&mut self.scratch.last);
            last.clear();
            last.resize(self.bucket, self.pad);
            for i in 0..self.bucket {
                if i < n && !self.requests[i].done {
                    last[i] = *self.requests[i].seq.last().unwrap();
                }
            }
            for _ in 0..k {
                let dc = self.draft_cache.as_mut().unwrap();
                let out = self.rt.step(&dm, &last, 1, dc)?;
                rep.draft_steps += 1;
                for idx in 0..self.scratch.active.len() {
                    let i = self.scratch.active[idx];
                    let r = &self.requests[i];
                    let pos = r.seq.len() + drafts[i].len();
                    let mut rng = position_rng(self.cfg.draft_seed, r.id, pos as u64);
                    let t = sample_logits(out.at(i, 0), self.cfg.temperature, &mut rng) as i32;
                    drafts[i].push(t);
                    self.draft_cache.as_mut().unwrap().lens[i] += 1;
                    self.draft_consumed[i] += 1;
                    last[i] = t;
                }
            }
            self.scratch.last = last;
            self.scratch.draft_toks = toks;
            self.scratch.need = need;
            // draft_consumed now counts speculative tokens too; verification
            // rolls it back to the accepted prefix below.
        } else {
            for idx in 0..self.scratch.active.len() {
                let i = self.scratch.active[idx];
                if let Some(td) = &mut self.token_drafters[i] {
                    td.draft_into(k, &mut drafts[i]);
                }
                drafts[i].resize(k, self.pad); // pad empty/short proposals
            }
        }
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            rep.drafted_tokens += drafts[i].len() as u64;
        }
        Ok(())
    }

    /// One coupled speculation round for all active slots: draft `k`
    /// tokens, verify with a `k+1`-window target step, apply outcomes.
    /// Assumes `refresh_active` ran since the last `done` change.
    fn coupled_round(&mut self, k: usize, rep: &mut EngineReport) -> Result<()> {
        let mut drafts = std::mem::take(&mut self.scratch.drafts);
        self.draft_k(k, &mut drafts, rep)?;
        let w = k + 1; // verify window: [last, d0..d_{k-1}]
        let mut toks = std::mem::take(&mut self.scratch.toks);
        toks.clear();
        toks.resize(self.bucket * w, self.pad);
        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            toks[i * w] = *self.requests[i].seq.last().unwrap();
            toks[i * w + 1..i * w + 1 + k].copy_from_slice(&drafts[i][..k]);
        }
        let out = self.rt.step(&self.target, &toks, w, &mut self.cache)?;
        self.scratch.toks = toks;
        rep.target_steps += 1;
        rep.iterations += 1;

        for idx in 0..self.scratch.active.len() {
            let i = self.scratch.active[idx];
            let r = &self.requests[i];
            let budget_left = r.budget - r.generated();
            let seq_len = r.seq.len();
            let id = r.id;
            let outcome =
                verify_exact(id, self.cfg.seed, self.cfg.temperature, seq_len, &drafts[i], |j| {
                    out.at(i, j)
                });
            let mut append = outcome.append;
            append.truncate(budget_left);
            let advanced = append.len();
            let req = &mut self.requests[i];
            req.seq.extend_from_slice(&append);
            req.accept.observe(drafts[i].len(), outcome.accepted);
            req.iterations += 1;
            // Invariant: the target cache has consumed exactly seq.len()-1
            // tokens (the last token is the next step's input). The verify
            // step wrote w entries; only the accepted prefix is valid, and
            // that is exactly seq.len()-1 (budget truncation only lowers it,
            // which is safe: stale slots are overwritten later).
            self.cache.lens[i] = (self.requests[i].seq.len() - 1) as i32;
            rep.total_generated += advanced as u64;
            rep.accepted_tokens += outcome.accepted as u64;
            rep.wasted_tokens += outcome.wasted as u64;
            if advanced > 1 {
                rep.skipped_iterations += 1;
            }
            // Drafter cache rollback: the draft model consumed its own
            // drafts while drafting; only those matching the accepted
            // prefix remain valid.
            if self.draft_model.is_some() {
                let rollback = (seq_len + outcome.accepted)
                    .min(self.requests[i].seq.len() - 1)
                    .min(self.draft_consumed[i]);
                self.draft_consumed[i] = rollback;
                if let Some(dc) = &mut self.draft_cache {
                    dc.lens[i] = rollback as i32;
                }
            }
            // token drafter resync: extend with the accepted tokens
            if let Some(td) = &mut self.token_drafters[i] {
                td.extend(&append);
            }
            self.finish_check(i);
        }
        self.scratch.drafts = drafts;
        Ok(())
    }

    /// Coupled (vanilla) speculative rollout: draft-k-then-verify.
    pub fn rollout_coupled(&mut self, k: usize) -> Result<EngineReport> {
        if k + 1 > *self.rt.manifest.windows.iter().max().unwrap_or(&1) {
            bail!("verify window {} not lowered", k + 1);
        }
        let t0 = Instant::now();
        let mut rep = EngineReport::default();
        while self.refresh_active() > 0 {
            self.coupled_round(k, &mut rep)?;
        }
        rep.wall_s = t0.elapsed().as_secs_f64();
        Ok(rep)
    }

    /// Final sequences (generated part only), in request order.
    pub fn outputs(&self) -> Vec<Vec<i32>> {
        self.requests.iter().map(|r| r.seq[r.prompt.len()..].to_vec()).collect()
    }

    pub fn target_model(&self) -> &str {
        &self.target
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }
}
