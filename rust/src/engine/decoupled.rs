//! Decoupled speculative rollout (§4.1): drafter and verifier on separate
//! threads, linked by channels, with the drafter allowed to run ahead of
//! verification bounded by each slot's draft window.
//!
//! The paper places drafter and verifier on disjoint GPUs so verification
//! gets all the compute; here each thread owns its own PJRT CPU client
//! (`xla::PjRtClient` is not `Send`), which is the same process topology.
//! Token-level behaviour is identical to coupled speculation — and to
//! vanilla decoding — because acceptance uses the shared sampling tape
//! (`rust/tests/losslessness.rs` asserts all modes agree token-for-token).
//!
//! The rollout is **plan-driven** ([`rollout_decoupled_planned`]): every
//! slot carries its own [`SlotPlan`], so chunk size (`window`), draft
//! method and discipline vary per slot within one batch. Token drafters
//! (sam/ngram) mix freely, and the drafter thread hosts **multiple draft
//! model families at once** — one KV cache per model (mirroring the
//! worker's `draft_models` map), with one catch-up + decode chain per
//! family per round — so Fastest-of-N replicas racing different model
//! drafters share a single drafter thread. A `Coupled`-mode slot
//! runs with pipeline depth 1 and keeps the bonus token — the same token
//! dynamics as `Worker`'s coupled groups — while `Decoupled` slots run
//! ahead and forgo the bonus.
//!
//! Both loops reuse their token/proposal buffers across rounds (PERF.md
//! §Memory discipline); the only steady-state allocation is the one `Vec`
//! per [`Chunk`] that crosses the drafter→verifier channel.
//!
//! Protocol (per slot):
//! * drafter sends `Chunk { slot, base_len, tokens }` drafted from its
//!   local mirror (verified prefix + own unverified drafts);
//! * verifier batches one chunk per active slot into a single verify step,
//!   applies exact-match acceptance, and replies with
//!   `Verdict::Advance { new_tokens, accepted, full }`;
//! * a chunk whose `base_len` no longer matches the verified sequence
//!   (an earlier chunk was rejected) is *stale*: the verifier discards it
//!   as waste — this is exactly the `2w−1` worst case of Figure 9;
//! * `Verdict::Done` stops drafting for a finished request; `Shutdown`
//!   ends the drafter thread.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::drafter::corpus::{CorpusHandle, CorpusSnapshot};
use crate::drafter::{DraftMethod, TokenDrafter};
use crate::obs::{Phase, Tracer};
use crate::runtime::{KvCache, Runtime};
use crate::spec::{decode_one, verify_exact, DraftWindow};
use crate::util::rng::{position_rng, sample_logits};

use super::fault::SpecError;
use super::plan::{PlanMode, SlotPlan};
use super::worker::{EngineConfig, EngineReport, Request};

#[derive(Debug)]
struct Chunk {
    slot: usize,
    base_len: usize,
    tokens: Vec<i32>,
}

#[derive(Debug)]
enum Verdict {
    Advance { slot: usize, new_tokens: Vec<i32>, accepted: usize, full: bool },
    Done { slot: usize },
    Shutdown,
}

/// Everything the drafter thread needs to know about one slot.
struct SlotSpec {
    id: u64,
    prompt: Vec<i32>,
    /// Chunk size (the slot plan's draft window).
    k: usize,
    /// Coupled discipline: depth-1 pipeline, bonus token on full accept.
    coupled: bool,
    method: DraftMethod,
    /// Wave-global corpus snapshot to seed the slot's token drafter from
    /// (None = cold start / model method). Loaded ONCE at spawn — the
    /// drafter thread's per-token path never touches shared state.
    seed: Option<Arc<CorpusSnapshot>>,
}

/// Drafter-thread state for one slot.
struct SlotMirror {
    /// Verified sequence prefix.
    seq: Vec<i32>,
    /// Unverified tokens drafted beyond `seq`.
    ahead: Vec<i32>,
    window: DraftWindow,
    done: bool,
}

/// Token at mirror position `idx` of `seq ++ ahead`, without materialising
/// the concatenation.
fn mirror_tok(m: &SlotMirror, idx: usize) -> i32 {
    if idx < m.seq.len() {
        m.seq[idx]
    } else {
        m.ahead[idx - m.seq.len()]
    }
}

/// Apply an `Advance` verdict to the drafter's mirror of one slot.
fn mirror_advance(m: &mut SlotMirror, new_tokens: &[i32], accepted: usize, full: bool) {
    m.seq.extend_from_slice(new_tokens);
    m.window.on_verified(accepted.min(m.window.in_flight()), full);
    if full {
        // A full accept consumes exactly the chunk (plus, for coupled
        // slots, the bonus token the drafter never proposed): drop the
        // accepted prefix from `ahead`, keep the pipeline.
        let drop_n = new_tokens.len().min(m.ahead.len());
        m.ahead.drain(..drop_n);
    } else {
        // rejection: everything drafted ahead is garbage
        m.ahead.clear();
        m.window = DraftWindow::new(m.window.w, m.window.coupled);
    }
}

/// Run the drafter thread body. `art_dir` is used to open this thread's own
/// PJRT client for model-based drafting.
fn drafter_thread(
    art_dir: PathBuf,
    draft_seed: u64,
    temp: f32,
    specs: Vec<SlotSpec>,
    tx: Sender<Chunk>,
    rx: Receiver<Verdict>,
) -> Result<()> {
    let n = specs.len();
    let mut mirrors: Vec<SlotMirror> = specs
        .iter()
        .map(|s| SlotMirror {
            seq: s.prompt.clone(),
            ahead: Vec::new(),
            window: DraftWindow::new(s.k, s.coupled),
            done: false,
        })
        .collect();

    // Model-based drafting state: ONE runtime shared by the thread, one
    // KV cache + consumed counters per draft model family named by any
    // slot's plan (the worker's `draft_models` map, thread-side), plus
    // per-slot token drafters.
    struct ThreadDraftModel {
        cache: crate::runtime::KvCache,
        consumed: Vec<usize>,
    }
    let mut token_drafters: Vec<Option<Box<dyn TokenDrafter>>> = (0..n)
        .map(|i| {
            // seeded clone of the corpus snapshot when provided, cold
            // constructor otherwise — identical structure either way
            let mut td = specs[i]
                .seed
                .as_ref()
                .and_then(|s| s.seed_token_drafter(&specs[i].method))
                .or_else(|| specs[i].method.new_token_drafter());
            if let Some(t) = td.as_mut() {
                t.extend(&specs[i].prompt);
            }
            td
        })
        .collect();
    let mut model_names: Vec<String> = Vec::new();
    for s in &specs {
        if let Some(name) = s.method.model_name() {
            if !model_names.iter().any(|m| m == name) {
                model_names.push(name.to_string());
            }
        }
    }
    let mut model_rt: Option<(Runtime, BTreeMap<String, ThreadDraftModel>)> = None;
    if !model_names.is_empty() {
        let rt = Runtime::load(&art_dir)?;
        let bucket = rt.manifest.bucket_for(n)?;
        let p = rt.manifest.prompt_len;
        let pad = rt.manifest.pad_id;
        let mut models = BTreeMap::new();
        // one batched prefill per model family, covering exactly its slots
        for name in &model_names {
            let mut cache = rt.new_cache(name, bucket)?;
            let mut toks = vec![pad; bucket * p];
            let mut users = vec![false; bucket];
            for i in 0..n {
                if specs[i].method.model_name() == Some(name.as_str()) {
                    toks[i * p..(i + 1) * p].copy_from_slice(&specs[i].prompt);
                    users[i] = true;
                }
            }
            rt.prefill(name, &toks, &mut cache)?;
            let mut consumed = vec![0usize; bucket];
            for (i, l) in cache.lens.iter_mut().enumerate() {
                if users.get(i).copied().unwrap_or(false) {
                    *l = (p - 1) as i32;
                    consumed[i] = p - 1;
                } else {
                    // non-user rows hold prefill junk; zero their lens so
                    // the runtime's max_seq guard never trips on them
                    *l = 0;
                }
            }
            models.insert(name.clone(), ThreadDraftModel { cache, consumed });
        }
        model_rt = Some((rt, models));
    }

    // Round-reused buffers (allocated once; see module docs).
    let mut proposals: Vec<Vec<i32>> = (0..n).map(|_| Vec::new()).collect();
    let mut draftable: Vec<usize> = Vec::with_capacity(n);
    let mut draftable_model: Vec<usize> = Vec::with_capacity(n);
    let mut need: Vec<usize> = Vec::new();
    let mut toks: Vec<i32> = Vec::new();
    let mut last: Vec<i32> = Vec::new();

    loop {
        // 1. drain verdicts (non-blocking)
        let mut any_verdict = false;
        loop {
            match rx.try_recv() {
                Ok(Verdict::Shutdown) => return Ok(()),
                Ok(Verdict::Done { slot }) => {
                    mirrors[slot].done = true;
                    any_verdict = true;
                }
                Ok(Verdict::Advance { slot, new_tokens, accepted, full }) => {
                    mirror_advance(&mut mirrors[slot], &new_tokens, accepted, full);
                    any_verdict = true;
                }
                Err(_) => break,
            }
        }

        if mirrors.iter().all(|m| m.done) {
            // wait for shutdown so the channel does not close early
            match rx.recv() {
                Ok(Verdict::Shutdown) | Err(_) => return Ok(()),
                _ => continue,
            }
        }

        // 2. pick slots that may draft a chunk (each by its own size)
        draftable.clear();
        draftable.extend(
            (0..n).filter(|&i| !mirrors[i].done && mirrors[i].window.draft_budget() >= specs[i].k),
        );
        if draftable.is_empty() {
            if !any_verdict {
                // block for the next verdict to avoid spinning
                match rx.recv() {
                    Ok(Verdict::Shutdown) => return Ok(()),
                    Ok(Verdict::Done { slot }) => mirrors[slot].done = true,
                    Ok(Verdict::Advance { slot, new_tokens, accepted, full }) => {
                        mirror_advance(&mut mirrors[slot], &new_tokens, accepted, full);
                    }
                    Err(_) => return Ok(()),
                }
            }
            continue;
        }

        // 3. draft one chunk of `k_i` tokens per draftable slot
        for &i in &draftable {
            proposals[i].clear();
        }
        if let Some((rt, models)) = &mut model_rt {
            for (name, st) in models.iter_mut() {
                let (cache, consumed) = (&mut st.cache, &mut st.consumed);
                draftable_model.clear();
                draftable_model.extend(
                    draftable
                        .iter()
                        .copied()
                        .filter(|&i| specs[i].method.model_name() == Some(name.as_str())),
                );
                if draftable_model.is_empty() {
                    continue;
                }
                let bucket = cache.batch;
                let pad = rt.manifest.pad_id;
                // catch-up: consume mirror tokens (seq + ahead, minus the
                // final one which seeds the first decode step)
                let mirror_len = |m: &SlotMirror| m.seq.len() + m.ahead.len();
                need.clear();
                need.resize(bucket, 0);
                for &i in &draftable_model {
                    let m = &mirrors[i];
                    // the draft cache may have consumed diverged tokens:
                    // roll back to the verified prefix when behind
                    if consumed[i] > mirror_len(&mirrors[i]) - 1 {
                        consumed[i] = m.seq.len().saturating_sub(1);
                        cache.lens[i] = consumed[i] as i32;
                    }
                    need[i] = (mirror_len(m) - 1).saturating_sub(consumed[i]);
                }
                let mut max_need =
                    draftable_model.iter().map(|&i| need[i]).max().unwrap_or(0);
                while max_need > 0 {
                    let w = rt.manifest.window_for(max_need)?;
                    toks.clear();
                    toks.resize(bucket * w, pad);
                    for &i in &draftable_model {
                        let m = &mirrors[i];
                        let take = need[i].min(w);
                        for j in 0..take {
                            toks[i * w + j] = mirror_tok(m, consumed[i] + j);
                        }
                    }
                    rt.step(name, &toks, w, cache)?;
                    for &i in &draftable_model {
                        let take = need[i].min(w);
                        consumed[i] += take;
                        cache.lens[i] = consumed[i] as i32;
                        need[i] -= take;
                    }
                    max_need = draftable_model.iter().map(|&i| need[i]).max().unwrap_or(0);
                }
                // up to max(k_i) batched decode steps; each slot stops
                // consuming once its own chunk is full
                last.clear();
                last.resize(bucket, pad);
                for &i in &draftable_model {
                    let m = &mirrors[i];
                    last[i] = *m.ahead.last().or_else(|| m.seq.last()).unwrap();
                }
                let kmax = draftable_model.iter().map(|&i| specs[i].k).max().unwrap_or(0);
                for _ in 0..kmax {
                    let out = rt.step(name, &last, 1, cache)?;
                    for &i in &draftable_model {
                        if proposals[i].len() >= specs[i].k {
                            // chunk full: this row was stepped with a stale
                            // token; its cache position is not advanced and
                            // gets overwritten by the next real step
                            continue;
                        }
                        let m = &mirrors[i];
                        let pos = m.seq.len() + m.ahead.len() + proposals[i].len();
                        let mut rng = position_rng(draft_seed, specs[i].id, pos as u64);
                        let t = sample_logits(out.at(i, 0), temp, &mut rng) as i32;
                        proposals[i].push(t);
                        consumed[i] += 1;
                        cache.lens[i] = consumed[i] as i32;
                        last[i] = t;
                    }
                }
            }
        }
        for &i in &draftable {
            if specs[i].method.is_model() {
                continue;
            }
            // token drafters draft from verified + ahead history
            if let Some(td) = &mut token_drafters[i] {
                // bring the index up to the mirror state
                let m = &mirrors[i];
                let mirror_total = m.seq.len() + m.ahead.len();
                if td.len() > mirror_total {
                    // rejection rolled the mirror back: rebuild
                    td.reset();
                    td.extend(&m.seq);
                    td.extend(&m.ahead);
                } else if td.len() < mirror_total {
                    // extend with the missing mirror suffix without
                    // materialising seq ++ ahead
                    let start = td.len();
                    if start < m.seq.len() {
                        td.extend(&m.seq[start..]);
                        td.extend(&m.ahead);
                    } else {
                        td.extend(&m.ahead[start - m.seq.len()..]);
                    }
                }
                td.draft_into(specs[i].k, &mut proposals[i]);
                proposals[i].resize(specs[i].k, 0);
            }
        }

        // 4. update mirrors and send chunks
        for &i in &draftable {
            let m = &mut mirrors[i];
            let base = m.seq.len() + m.ahead.len();
            m.window.on_drafted(specs[i].k);
            m.ahead.extend_from_slice(&proposals[i]);
            // the chunk must own its tokens across the channel: hand over
            // the proposal buffer (one allocation per chunk, regrown next
            // round) instead of cloning it
            let chunk =
                Chunk { slot: i, base_len: base, tokens: std::mem::take(&mut proposals[i]) };
            if tx.send(chunk).is_err() {
                return Ok(()); // verifier gone
            }
        }
    }
}

/// Decoupled rollout of a uniform batch: every slot runs the config's
/// default plan (which must be speculative). Kept as the whole-batch
/// convenience wrapper over [`rollout_decoupled_planned`].
pub fn rollout_decoupled(
    rt: &Runtime,
    art_dir: &std::path::Path,
    cfg: &EngineConfig,
    requests: &mut Vec<Request>,
) -> Result<EngineReport> {
    if cfg.plan.mode != PlanMode::Decoupled {
        bail!("rollout_decoupled requires a Decoupled default plan");
    }
    let plans = vec![cfg.plan.clone(); requests.len()];
    rollout_decoupled_planned(rt, art_dir, cfg, requests, &plans)
}

/// Plan-driven decoupled speculative rollout over `requests`: slot `i`
/// drafts `plans[i].window`-token chunks with `plans[i].method` under
/// `plans[i].mode`'s discipline. Spawns the drafter thread, runs
/// verification on the calling thread, returns the report. Sequences end
/// up in `requests` (same layout as `Worker`).
pub fn rollout_decoupled_planned(
    rt: &Runtime,
    art_dir: &std::path::Path,
    cfg: &EngineConfig,
    requests: &mut Vec<Request>,
    plans: &[SlotPlan],
) -> Result<EngineReport> {
    rollout_decoupled_planned_traced(rt, art_dir, cfg, requests, plans, None)
}

/// [`rollout_decoupled_planned`] with verifier-side span recording. The
/// drafter runs on its own thread and [`Tracer`] is deliberately
/// single-threaded (`Rc`), so the Draft phase recorded here measures the
/// verifier's *wait* for fresh chunks — the pipeline-stall signal — while
/// Verify/Apply time the fused ragged step and the outcome application.
pub fn rollout_decoupled_planned_traced(
    rt: &Runtime,
    art_dir: &std::path::Path,
    cfg: &EngineConfig,
    requests: &mut Vec<Request>,
    plans: &[SlotPlan],
    tracer: Option<&Tracer>,
) -> Result<EngineReport> {
    rollout_decoupled_planned_corpus(rt, art_dir, cfg, requests, plans, tracer, None)
}

/// [`rollout_decoupled_planned_traced`] seeding token drafters from a
/// wave-global corpus: the published snapshot is loaded ONCE here (a
/// pointer load) and cloned into each token-method slot's drafter on the
/// drafter thread, so every slot starts warm while the per-token draft
/// path stays lock-free. Seeding changes only what drafters *propose* —
/// verification still decides every token on the shared sampling tape,
/// so output is token-identical to the unseeded rollout.
pub fn rollout_decoupled_planned_corpus(
    rt: &Runtime,
    art_dir: &std::path::Path,
    cfg: &EngineConfig,
    requests: &mut Vec<Request>,
    plans: &[SlotPlan],
    tracer: Option<&Tracer>,
    corpus: Option<&CorpusHandle>,
) -> Result<EngineReport> {
    let m = &rt.manifest;
    let n = requests.len();
    if n == 0 {
        bail!("no requests");
    }
    if plans.len() != n {
        bail!("{} plans for {n} requests", plans.len());
    }
    let mut max_k = 0usize;
    for p in plans {
        if p.window == 0 {
            bail!("vanilla slots belong in Worker::round, not the drafter thread");
        }
        max_k = max_k.max(p.window);
        if let Some(name) = p.method.model_name() {
            m.model(name)?; // fail fast before the thread spawns
        }
    }
    // verify window: smallest lowered step window covering the largest
    // chunk plus its seed token (shorter chunks are padded)
    let w = m
        .windows
        .iter()
        .copied()
        .filter(|&x| x >= max_k + 1)
        .min()
        .ok_or_else(|| anyhow!("no lowered step window can verify draft window {max_k}"))?;
    let bucket = m.bucket_for(n)?;
    let p = m.prompt_len;
    let pad = m.pad_id;
    let eos = m.eos_id;
    let target = m.target.clone();
    // every verify step spans the whole bucket at window `w`: each
    // request's budget must leave that much cache headroom
    let max_new = m.model(&target)?.max_seq - p - w;
    for r in requests.iter() {
        if r.budget > max_new {
            bail!("request {}: budget {} exceeds cache capacity {max_new}", r.id, r.budget);
        }
    }

    // target prefill
    let mut cache = rt.new_cache(&target, bucket)?;
    let mut toks = vec![pad; bucket * p];
    for (i, r) in requests.iter().enumerate() {
        toks[i * p..(i + 1) * p].copy_from_slice(&r.prompt);
    }
    rt.prefill(&target, &toks, &mut cache)?;
    for l in cache.lens.iter_mut() {
        *l = (p - 1) as i32;
    }

    let (chunk_tx, chunk_rx) = channel::<Chunk>();
    let (verdict_tx, verdict_rx) = channel::<Verdict>();
    let snap = corpus.map(|h| h.load()).filter(|s| s.is_warm());
    let specs: Vec<SlotSpec> = requests
        .iter()
        .zip(plans)
        .map(|(r, pl)| SlotSpec {
            id: r.id,
            prompt: r.prompt.clone(),
            k: pl.window,
            coupled: pl.mode == PlanMode::Coupled,
            method: pl.method.clone(),
            seed: if pl.method.is_model() { None } else { snap.clone() },
        })
        .collect();
    let art = art_dir.to_path_buf();
    let dseed = cfg.draft_seed;
    let temp = cfg.temperature;
    let handle = std::thread::Builder::new()
        .name("spec-drafter".to_string())
        .spawn(move || drafter_thread(art, dseed, temp, specs, chunk_tx, verdict_rx))
        .map_err(|e| anyhow!("spawn drafter: {e}"))?;

    let t0 = Instant::now();
    let mut rep = EngineReport::default();
    let mut pending: Vec<Option<Chunk>> = (0..n).map(|_| None).collect();
    // verify-step inputs + per-row ragged widths, reused every round
    let mut vtoks = vec![pad; bucket * w];
    let mut vwidths = vec![0usize; bucket];

    let active = |reqs: &Vec<Request>| reqs.iter().filter(|r| !r.done).count();
    let mut round = 0u64;
    'serve: while active(requests) > 0 {
        round += 1;
        if let Some(t) = tracer {
            t.begin_round(round);
        }
        let mut mark = tracer.map(|t| t.now_us());
        // Gather one fresh chunk per active slot (discard stale ones).
        loop {
            let missing = (0..n)
                .filter(|&i| !requests[i].done && pending[i].is_none())
                .count();
            if missing == 0 {
                break;
            }
            let chunk = match chunk_rx.recv() {
                Ok(c) => c,
                Err(_) => {
                    // Drafter thread died (panicked or dropped its
                    // sender). Speculation is an accelerator, never a
                    // correctness dependency: degrade instead of
                    // aborting and finish every unfinished request with
                    // plain width-1 decode on the same target cache and
                    // sampling tape — token-identical output, per the
                    // (seed, request, position) tape invariant.
                    rep.drafter_degrades += 1;
                    finish_vanilla(rt, &target, cfg, requests, &mut cache, pad, eos, &mut rep)?;
                    break 'serve;
                }
            };
            let i = chunk.slot;
            if requests[i].done {
                continue;
            }
            if chunk.base_len != requests[i].seq.len() {
                // Stale chunk from a mis-speculated pipeline: pure waste.
                // CRITICAL for liveness: the drafter's window counted this
                // chunk as in flight, so discarding it silently could leave
                // the drafter blocked with a full pipeline while we block
                // waiting for a fresh chunk — always acknowledge with an
                // empty resync verdict.
                rep.wasted_tokens += chunk.tokens.len() as u64;
                rep.drafted_tokens += chunk.tokens.len() as u64;
                rep.slot_accept(i).drafted += chunk.tokens.len() as u64;
                let _ = verdict_tx.send(Verdict::Advance {
                    slot: i,
                    new_tokens: vec![],
                    accepted: 0,
                    full: false,
                });
                continue;
            }
            pending[i] = Some(chunk);
        }
        if let (Some(t), Some(m)) = (tracer, mark) {
            t.record(Phase::Draft, m, n as u32);
            mark = Some(t.now_us());
        }

        // One fused ragged verify of all pending chunks: shorter chunks
        // are padded up to the shared step window, but each row's real
        // width is its own chunk + seed token — the ragged scatter keeps
        // padded KV out of short rows' caches and the guarded logits
        // accessor refuses reads past each row's chunk (done/free rows
        // ride along as zero-width padding).
        vtoks.fill(pad);
        vwidths.clear();
        vwidths.resize(bucket, 0);
        for i in 0..n {
            if let Some(c) = &pending[i] {
                vtoks[i * w] = *requests[i].seq.last().unwrap();
                vtoks[i * w + 1..i * w + 1 + c.tokens.len()].copy_from_slice(&c.tokens);
                vwidths[i] = c.tokens.len() + 1;
            }
        }
        // widths ownership rides through the StepOut and is reclaimed
        // below — no per-step allocation
        let mut out = rt.step_ragged(&target, &vtoks, w, &mut cache, vwidths)?;
        rep.target_steps += 1;
        rep.iterations += 1;
        if let (Some(t), Some(m)) = (tracer, mark) {
            t.record(Phase::Verify, m, w as u32);
            mark = Some(t.now_us());
        }

        for i in 0..n {
            let Some(c) = pending[i].take() else { continue };
            let seq_len = requests[i].seq.len();
            let id = requests[i].id;
            if out.logits_at(i, c.tokens.len()).is_err() {
                return Err(SpecError::KvRowInvalid {
                    slot: i,
                    detail: format!(
                        "verify row narrower than its chunk ({} tokens)",
                        c.tokens.len()
                    ),
                }
                .into());
            }
            let outcome =
                verify_exact(id, cfg.seed, cfg.temperature, seq_len, &c.tokens, |j| {
                    out.logits_at(i, j)
                        .expect("guarded above: j <= chunk len is inside the row")
                });
            let budget_left = requests[i].budget - requests[i].generated();
            let mut append = outcome.append;
            if outcome.full_accept && plans[i].mode == PlanMode::Decoupled {
                // Decoupled discipline takes no bonus token: the drafter's
                // pipelined next chunk was drafted without it, and the tape
                // re-samples the identical token at that position later —
                // losslessness is unaffected (see module docs). Coupled
                // slots (depth-1 pipeline) keep the bonus, matching
                // `Worker`'s coupled groups token-for-token.
                append.pop();
            }
            append.truncate(budget_left);
            requests[i].seq.extend_from_slice(&append);
            requests[i].accept.observe(c.tokens.len(), outcome.accepted);
            requests[i].iterations += 1;
            cache.lens[i] = (requests[i].seq.len() - 1) as i32;
            rep.total_generated += append.len() as u64;
            rep.drafted_tokens += c.tokens.len() as u64;
            rep.accepted_tokens += outcome.accepted as u64;
            rep.wasted_tokens += outcome.wasted as u64;
            let sa = rep.slot_accept(i);
            sa.drafted += c.tokens.len() as u64;
            sa.accepted += outcome.accepted as u64;
            if append.len() > 1 {
                rep.skipped_iterations += 1;
            }
            let done = requests[i].generated() >= requests[i].budget
                || requests[i].seq.last() == Some(&eos);
            if done {
                requests[i].done = true;
                let _ = verdict_tx.send(Verdict::Done { slot: i });
            } else {
                let _ = verdict_tx.send(Verdict::Advance {
                    slot: i,
                    new_tokens: append,
                    accepted: outcome.accepted,
                    full: outcome.full_accept,
                });
            }
        }
        if let (Some(t), Some(m)) = (tracer, mark) {
            t.record(Phase::Apply, m, n as u32);
        }
        vwidths = out.widths.take().unwrap_or_default();
    }
    let _ = verdict_tx.send(Verdict::Shutdown);
    let _ = handle.join();
    rep.wall_s = t0.elapsed().as_secs_f64();
    Ok(rep)
}

/// Drafter-death fallback: finish every unfinished request with plain
/// width-1 decode on the (already-consistent) target cache. The sampling
/// tape is keyed by (seed, request id, position), so the tokens emitted
/// here are identical to the ones speculation would have produced — the
/// degradation costs throughput, never correctness.
#[allow(clippy::too_many_arguments)]
fn finish_vanilla(
    rt: &Runtime,
    target: &str,
    cfg: &EngineConfig,
    requests: &mut [Request],
    cache: &mut KvCache,
    pad: i32,
    eos: i32,
    rep: &mut EngineReport,
) -> Result<()> {
    let bucket = cache.batch;
    let mut toks = vec![pad; bucket];
    loop {
        let live: Vec<usize> =
            (0..requests.len()).filter(|&i| !requests[i].done).collect();
        if live.is_empty() {
            return Ok(());
        }
        toks.fill(pad);
        for &i in &live {
            toks[i] = *requests[i].seq.last().unwrap();
        }
        // done/free rows ride along as pad: their lens stay frozen, so
        // the garbage KV written at lens is overwritten by any real step
        let out = rt.step(target, &toks, 1, cache)?;
        rep.target_steps += 1;
        rep.iterations += 1;
        for &i in &live {
            let (id, seq_len) = (requests[i].id, requests[i].seq.len());
            let t = decode_one(id, cfg.seed, cfg.temperature, seq_len, out.at(i, 0));
            let r = &mut requests[i];
            r.seq.push(t);
            r.iterations += 1;
            cache.lens[i] += 1;
            rep.total_generated += 1;
            if r.generated() >= r.budget || r.seq.last() == Some(&eos) {
                r.done = true;
            }
        }
    }
}
