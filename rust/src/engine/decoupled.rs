//! Decoupled speculative rollout (§4.1): drafter and verifier on separate
//! threads, linked by channels, with the drafter allowed to run ahead of
//! verification bounded by the draft window.
//!
//! The paper places drafter and verifier on disjoint GPUs so verification
//! gets all the compute; here each thread owns its own PJRT CPU client
//! (`xla::PjRtClient` is not `Send`), which is the same process topology.
//! Token-level behaviour is identical to coupled speculation — and to
//! vanilla decoding — because acceptance uses the shared sampling tape
//! (`rust/tests/losslessness.rs` asserts all three agree token-for-token).
//!
//! Both loops reuse their token/proposal buffers across rounds (PERF.md
//! §Memory discipline); the only steady-state allocation is the one `Vec`
//! per [`Chunk`] that crosses the drafter→verifier channel.
//!
//! Protocol (per slot):
//! * drafter sends `Chunk { slot, base_len, tokens }` drafted from its
//!   local mirror (verified prefix + own unverified drafts);
//! * verifier batches one chunk per active slot into a single verify step,
//!   applies exact-match acceptance, and replies with
//!   `Verdict::Advance { new_tokens, accepted, full }`;
//! * a chunk whose `base_len` no longer matches the verified sequence
//!   (an earlier chunk was rejected) is *stale*: the verifier discards it
//!   as waste — this is exactly the `2w−1` worst case of Figure 9;
//! * `Verdict::Done` stops drafting for a finished request; `Shutdown`
//!   ends the drafter thread.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::drafter::{DraftMethod, NgramDrafter, SamDrafter, TokenDrafter};
use crate::runtime::Runtime;
use crate::spec::{verify_exact, DraftWindow};
use crate::util::rng::{position_rng, sample_logits};

use super::worker::{EngineConfig, EngineReport, Request, SpecMode};

#[derive(Debug)]
struct Chunk {
    slot: usize,
    base_len: usize,
    tokens: Vec<i32>,
}

#[derive(Debug)]
enum Verdict {
    Advance { slot: usize, new_tokens: Vec<i32>, accepted: usize, full: bool },
    Done { slot: usize },
    Shutdown,
}

/// Drafter-thread state for one slot.
struct SlotMirror {
    /// Verified sequence prefix.
    seq: Vec<i32>,
    /// Unverified tokens drafted beyond `seq`.
    ahead: Vec<i32>,
    window: DraftWindow,
    done: bool,
}

/// Token at mirror position `idx` of `seq ++ ahead`, without materialising
/// the concatenation.
fn mirror_tok(m: &SlotMirror, idx: usize) -> i32 {
    if idx < m.seq.len() {
        m.seq[idx]
    } else {
        m.ahead[idx - m.seq.len()]
    }
}

/// Run the drafter thread body. `art_dir` is used to open this thread's own
/// PJRT client for model-based drafting.
#[allow(clippy::too_many_arguments)]
fn drafter_thread(
    art_dir: PathBuf,
    method: DraftMethod,
    draft_seed: u64,
    temp: f32,
    chunk_k: usize,
    prompts: Vec<(u64, Vec<i32>)>,
    tx: Sender<Chunk>,
    rx: Receiver<Verdict>,
) -> Result<()> {
    let n = prompts.len();
    let mut mirrors: Vec<SlotMirror> = prompts
        .iter()
        .map(|(_, p)| SlotMirror {
            seq: p.clone(),
            ahead: Vec::new(),
            window: DraftWindow::new(chunk_k, false),
            done: false,
        })
        .collect();
    let ids: Vec<u64> = prompts.iter().map(|(id, _)| *id).collect();

    // Model-based drafting state (own runtime + cache), or token drafters.
    let mut model_rt: Option<(Runtime, String, crate::runtime::KvCache, Vec<usize>)> = None;
    let mut token_drafters: Vec<Option<Box<dyn TokenDrafter>>> = (0..n).map(|_| None).collect();
    match &method {
        DraftMethod::Model(name) => {
            let rt = Runtime::load(&art_dir)?;
            let bucket = rt.manifest.bucket_for(n)?;
            let p = rt.manifest.prompt_len;
            let mut cache = rt.new_cache(name, bucket)?;
            let pad = rt.manifest.pad_id;
            let mut toks = vec![pad; bucket * p];
            for (i, (_, pr)) in prompts.iter().enumerate() {
                toks[i * p..(i + 1) * p].copy_from_slice(pr);
            }
            rt.prefill(name, &toks, &mut cache)?;
            for l in cache.lens.iter_mut() {
                *l = (p - 1) as i32;
            }
            let consumed = vec![p - 1; bucket];
            model_rt = Some((rt, name.clone(), cache, consumed));
        }
        DraftMethod::Ngram => {
            for (i, (_, pr)) in prompts.iter().enumerate() {
                let mut d = NgramDrafter::new(3);
                d.extend(pr);
                token_drafters[i] = Some(Box::new(d));
            }
        }
        DraftMethod::Sam => {
            for (i, (_, pr)) in prompts.iter().enumerate() {
                let mut d = SamDrafter::new(16);
                d.extend(pr);
                token_drafters[i] = Some(Box::new(d));
            }
        }
    }

    // Round-reused buffers (allocated once; see module docs).
    let mut proposals: Vec<Vec<i32>> = (0..n).map(|_| Vec::new()).collect();
    let mut draftable: Vec<usize> = Vec::with_capacity(n);
    let mut need: Vec<usize> = Vec::new();
    let mut toks: Vec<i32> = Vec::new();
    let mut last: Vec<i32> = Vec::new();

    loop {
        // 1. drain verdicts (non-blocking)
        let mut any_verdict = false;
        loop {
            match rx.try_recv() {
                Ok(Verdict::Shutdown) => return Ok(()),
                Ok(Verdict::Done { slot }) => {
                    mirrors[slot].done = true;
                    any_verdict = true;
                }
                Ok(Verdict::Advance { slot, new_tokens, accepted, full }) => {
                    let m = &mut mirrors[slot];
                    m.seq.extend_from_slice(&new_tokens);
                    m.window.on_verified(accepted.min(m.window.in_flight()), full);
                    if full {
                        // decoupled verification takes no bonus token, so a
                        // full accept consumes exactly the chunk: drop the
                        // accepted prefix from `ahead`, keep the pipeline.
                        let drop_n = new_tokens.len().min(m.ahead.len());
                        m.ahead.drain(..drop_n);
                    } else {
                        // rejection: everything drafted ahead is garbage
                        m.ahead.clear();
                        m.window = DraftWindow::new(m.window.w, false);
                    }
                    any_verdict = true;
                }
                Err(_) => break,
            }
        }

        if mirrors.iter().all(|m| m.done) {
            // wait for shutdown so the channel does not close early
            match rx.recv() {
                Ok(Verdict::Shutdown) | Err(_) => return Ok(()),
                _ => continue,
            }
        }

        // 2. pick slots that may draft a chunk
        draftable.clear();
        draftable
            .extend((0..n).filter(|&i| !mirrors[i].done && mirrors[i].window.draft_budget() >= chunk_k));
        if draftable.is_empty() {
            if !any_verdict {
                // block for the next verdict to avoid spinning
                match rx.recv() {
                    Ok(Verdict::Shutdown) => return Ok(()),
                    Ok(Verdict::Done { slot }) => mirrors[slot].done = true,
                    Ok(Verdict::Advance { slot, new_tokens, accepted, full }) => {
                        let m = &mut mirrors[slot];
                        m.seq.extend_from_slice(&new_tokens);
                        m.window.on_verified(accepted.min(m.window.in_flight()), full);
                        if full {
                            let drop_n = new_tokens.len().min(m.ahead.len());
                            m.ahead.drain(..drop_n);
                        } else {
                            m.ahead.clear();
                            m.window = DraftWindow::new(m.window.w, false);
                        }
                    }
                    Err(_) => return Ok(()),
                }
            }
            continue;
        }

        // 3. draft one chunk of `chunk_k` tokens per draftable slot
        for &i in &draftable {
            proposals[i].clear();
        }
        match (&method, &mut model_rt) {
            (DraftMethod::Model(_), Some((rt, name, cache, consumed))) => {
                let bucket = cache.batch;
                let pad = rt.manifest.pad_id;
                // catch-up: consume mirror tokens (seq + ahead, minus the
                // final one which seeds the first decode step)
                let mirror_len = |m: &SlotMirror| m.seq.len() + m.ahead.len();
                need.clear();
                need.resize(bucket, 0);
                for &i in &draftable {
                    let m = &mirrors[i];
                    // the draft cache may have consumed diverged tokens:
                    // roll back to the verified prefix when behind
                    if consumed[i] > mirror_len(&mirrors[i]) - 1 {
                        consumed[i] = m.seq.len().saturating_sub(1);
                        cache.lens[i] = consumed[i] as i32;
                    }
                    need[i] = (mirror_len(m) - 1).saturating_sub(consumed[i]);
                }
                let mut max_need = draftable.iter().map(|&i| need[i]).max().unwrap_or(0);
                while max_need > 0 {
                    let w = rt.manifest.window_for(max_need)?;
                    toks.clear();
                    toks.resize(bucket * w, pad);
                    for &i in &draftable {
                        let m = &mirrors[i];
                        let take = need[i].min(w);
                        for j in 0..take {
                            toks[i * w + j] = mirror_tok(m, consumed[i] + j);
                        }
                    }
                    rt.step(name, &toks, w, cache)?;
                    for &i in &draftable {
                        let take = need[i].min(w);
                        consumed[i] += take;
                        cache.lens[i] = consumed[i] as i32;
                        need[i] -= take;
                    }
                    max_need = draftable.iter().map(|&i| need[i]).max().unwrap_or(0);
                }
                // chunk_k batched decode steps
                last.clear();
                last.resize(bucket, pad);
                for &i in &draftable {
                    let m = &mirrors[i];
                    last[i] = *m.ahead.last().or_else(|| m.seq.last()).unwrap();
                }
                for _ in 0..chunk_k {
                    let out = rt.step(name, &last, 1, cache)?;
                    for &i in &draftable {
                        let m = &mirrors[i];
                        let pos = m.seq.len() + m.ahead.len() + proposals[i].len();
                        let mut rng = position_rng(draft_seed, ids[i], pos as u64);
                        let t = sample_logits(out.at(i, 0), temp, &mut rng) as i32;
                        proposals[i].push(t);
                        consumed[i] += 1;
                        cache.lens[i] = consumed[i] as i32;
                        last[i] = t;
                    }
                }
            }
            _ => {
                for &i in &draftable {
                    // token drafters draft from verified + ahead history
                    if let Some(td) = &mut token_drafters[i] {
                        // bring the index up to the mirror state
                        let m = &mirrors[i];
                        let mirror_total = m.seq.len() + m.ahead.len();
                        if td.len() > mirror_total {
                            // rejection rolled the mirror back: rebuild
                            td.reset();
                            td.extend(&m.seq);
                            td.extend(&m.ahead);
                        } else if td.len() < mirror_total {
                            // extend with the missing mirror suffix without
                            // materialising seq ++ ahead
                            let start = td.len();
                            if start < m.seq.len() {
                                td.extend(&m.seq[start..]);
                                td.extend(&m.ahead);
                            } else {
                                td.extend(&m.ahead[start - m.seq.len()..]);
                            }
                        }
                        td.draft_into(chunk_k, &mut proposals[i]);
                        proposals[i].resize(chunk_k, 0);
                    }
                }
            }
        }

        // 4. update mirrors and send chunks
        for &i in &draftable {
            let m = &mut mirrors[i];
            let base = m.seq.len() + m.ahead.len();
            m.window.on_drafted(chunk_k);
            m.ahead.extend_from_slice(&proposals[i]);
            // the chunk must own its tokens across the channel: hand over
            // the proposal buffer (one allocation per chunk, regrown next
            // round) instead of cloning it
            let chunk =
                Chunk { slot: i, base_len: base, tokens: std::mem::take(&mut proposals[i]) };
            if tx.send(chunk).is_err() {
                return Ok(()); // verifier gone
            }
        }
    }
}

/// Decoupled speculative rollout over `requests`. Spawns the drafter
/// thread, runs verification on the calling thread, returns the report.
/// Sequences end up in `requests` (same layout as `Worker`).
pub fn rollout_decoupled(
    rt: &Runtime,
    art_dir: &std::path::Path,
    cfg: &EngineConfig,
    requests: &mut Vec<Request>,
) -> Result<EngineReport> {
    let k = match cfg.mode {
        SpecMode::Decoupled { window } => window,
        _ => bail!("rollout_decoupled requires SpecMode::Decoupled"),
    };
    let m = &rt.manifest;
    if k + 1 > *m.windows.iter().max().unwrap_or(&1) {
        bail!("verify window {} not lowered", k + 1);
    }
    let n = requests.len();
    let bucket = m.bucket_for(n)?;
    let p = m.prompt_len;
    let pad = m.pad_id;
    let eos = m.eos_id;
    let target = m.target.clone();

    // target prefill
    let mut cache = rt.new_cache(&target, bucket)?;
    let mut toks = vec![pad; bucket * p];
    for (i, r) in requests.iter().enumerate() {
        toks[i * p..(i + 1) * p].copy_from_slice(&r.prompt);
    }
    rt.prefill(&target, &toks, &mut cache)?;
    for l in cache.lens.iter_mut() {
        *l = (p - 1) as i32;
    }

    let (chunk_tx, chunk_rx) = channel::<Chunk>();
    let (verdict_tx, verdict_rx) = channel::<Verdict>();
    let prompts: Vec<(u64, Vec<i32>)> =
        requests.iter().map(|r| (r.id, r.prompt.clone())).collect();
    let art = art_dir.to_path_buf();
    let method = cfg.drafter.clone();
    let dseed = cfg.draft_seed;
    let temp = cfg.temperature;
    let handle = std::thread::Builder::new()
        .name("spec-drafter".to_string())
        .spawn(move || drafter_thread(art, method, dseed, temp, k, prompts, chunk_tx, verdict_rx))
        .map_err(|e| anyhow!("spawn drafter: {e}"))?;

    let t0 = Instant::now();
    let mut rep = EngineReport::default();
    let mut pending: Vec<Option<Chunk>> = (0..n).map(|_| None).collect();
    // verify-step inputs, reused every round
    let w = k + 1;
    let mut vtoks = vec![pad; bucket * w];

    let active = |reqs: &Vec<Request>| reqs.iter().filter(|r| !r.done).count();
    while active(requests) > 0 {
        // Gather one fresh chunk per active slot (discard stale ones).
        loop {
            let missing = (0..n)
                .filter(|&i| !requests[i].done && pending[i].is_none())
                .count();
            if missing == 0 {
                break;
            }
            let chunk = chunk_rx
                .recv()
                .map_err(|_| anyhow!("drafter thread died"))?;
            let i = chunk.slot;
            if requests[i].done {
                continue;
            }
            if chunk.base_len != requests[i].seq.len() {
                // Stale chunk from a mis-speculated pipeline: pure waste.
                // CRITICAL for liveness: the drafter's window counted this
                // chunk as in flight, so discarding it silently could leave
                // the drafter blocked with a full pipeline while we block
                // waiting for a fresh chunk — always acknowledge with an
                // empty resync verdict.
                rep.wasted_tokens += chunk.tokens.len() as u64;
                rep.drafted_tokens += chunk.tokens.len() as u64;
                let _ = verdict_tx.send(Verdict::Advance {
                    slot: i,
                    new_tokens: vec![],
                    accepted: 0,
                    full: false,
                });
                continue;
            }
            pending[i] = Some(chunk);
        }

        // Batched verify of all pending chunks.
        vtoks.fill(pad);
        for i in 0..n {
            if let Some(c) = &pending[i] {
                vtoks[i * w] = *requests[i].seq.last().unwrap();
                vtoks[i * w + 1..i * w + 1 + c.tokens.len()].copy_from_slice(&c.tokens);
            }
        }
        let out = rt.step(&target, &vtoks, w, &mut cache)?;
        rep.target_steps += 1;
        rep.iterations += 1;

        for i in 0..n {
            let Some(c) = pending[i].take() else { continue };
            let seq_len = requests[i].seq.len();
            let id = requests[i].id;
            let outcome =
                verify_exact(id, cfg.seed, cfg.temperature, seq_len, &c.tokens, |j| out.at(i, j));
            let budget_left = requests[i].budget - requests[i].generated();
            let mut append = outcome.append;
            if outcome.full_accept {
                // Decoupled mode takes no bonus token: the drafter's
                // pipelined next chunk was drafted without it, and the tape
                // re-samples the identical token at that position later —
                // losslessness is unaffected (see module docs).
                append.pop();
            }
            append.truncate(budget_left);
            requests[i].seq.extend_from_slice(&append);
            requests[i].accept.observe(c.tokens.len(), outcome.accepted);
            requests[i].iterations += 1;
            cache.lens[i] = (requests[i].seq.len() - 1) as i32;
            rep.total_generated += append.len() as u64;
            rep.drafted_tokens += c.tokens.len() as u64;
            rep.accepted_tokens += outcome.accepted as u64;
            rep.wasted_tokens += outcome.wasted as u64;
            if append.len() > 1 {
                rep.skipped_iterations += 1;
            }
            let done = requests[i].generated() >= requests[i].budget
                || requests[i].seq.last() == Some(&eos);
            if done {
                requests[i].done = true;
                let _ = verdict_tx.send(Verdict::Done { slot: i });
            } else {
                let _ = verdict_tx.send(Verdict::Advance {
                    slot: i,
                    new_tokens: append,
                    accepted: outcome.accepted,
                    full: outcome.full_accept,
                });
            }
        }
    }
    let _ = verdict_tx.send(Verdict::Shutdown);
    let _ = handle.join();
    rep.wall_s = t0.elapsed().as_secs_f64();
    Ok(rep)
}
