//! Scaling helper for benches: the full paper traces (256 GPUs, 16K
//! requests, 20K-token budgets) are expensive to simulate on one CPU core;
//! benches default to a 1/4-scale configuration that preserves the
//! per-worker batch size (the quantity the paper's effects depend on) and
//! the length-distribution shape, and accept `--full` for full scale.

use crate::sim::traces::TraceConfig;

/// Scale a trace down by `f` in GPUs and global batch (per-worker batch
/// and worker-level dynamics preserved), and cap the token budget.
pub fn scaled(cfg: &TraceConfig, f: usize, budget_cap: usize) -> TraceConfig {
    let mut c = cfg.clone();
    c.gpus = (c.gpus / f).max(c.tp);
    c.global_batch = (c.global_batch / f).max(c.workers());
    c.budget = c.budget.min(budget_cap);
    // keep the lognormal median in proportion to the cap so the tail
    // structure (budget-capped stragglers) is preserved
    if budget_cap < cfg.budget {
        let shrink = (cfg.budget as f64 / budget_cap as f64).ln();
        c.len_mu0 -= shrink;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_per_worker_batch() {
        let base = TraceConfig::dapo_32b_20k();
        let s = scaled(&base, 4, 4000);
        assert_eq!(s.per_worker_batch(), base.per_worker_batch());
        assert!(s.budget <= 4000);
    }
}
