//! Cluster rollout simulator: replays one training step under a policy.
//!
//! Workers advance round-by-round on a shared event clock (binary heap of
//! worker-ready times). Round latency comes from the affine cost model
//! (§4.1) and per-request token gains from the acceptance process — the
//! same `planner::tgs` math the real planner uses, but *sampled* rather
//! than in expectation.
//!
//! Fastest-of-N across workers is modelled as adopt-and-race: a freed
//! worker adopts a straggler with the next-best ladder method (after a
//! KV-scale delay); the replica with the higher realised rate finishes
//! first, which — because generation is lossless and identical across
//! replicas — is equivalent to migrating the request to the faster
//! replica. See DESIGN.md §2 for why this preserves the paper's behaviour.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::coordinator::fon::{assign, FreeWorker, Straggler};
use crate::coordinator::reconfig::{reconfigure_batch, Mode};
use crate::ladder::Ladder;
use crate::planner::costmodel::CostModel;
use crate::planner::plan::{search, PlanInput};
use crate::sim::traces::{SimRequest, TraceConfig};
use crate::util::Rng;

/// Simulated rollout policy.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// veRL: plain auto-regressive rollout.
    Verl,
    /// veRL with doubled GPUs (RLBoost-style upper bound).
    Verl2x,
    /// RLHFuse: same rollout; prepare/learn overlapped into the tail.
    Rlhfuse,
    /// veRL + vanilla coupled speculation with one model drafter.
    ModelSpec,
    /// veRL + vanilla coupled speculation with the n-gram drafter.
    NgramSpec,
    /// SpecActor with feature flags (for the Fig 15 ablation).
    SpecActor { decoupled: bool, reconfig: bool, fon: bool },
}

impl Policy {
    pub fn specactor() -> Policy {
        Policy::SpecActor { decoupled: true, reconfig: true, fon: true }
    }

    pub fn label(&self) -> String {
        match self {
            Policy::Verl => "veRL".into(),
            Policy::Verl2x => "veRL(2x)".into(),
            Policy::Rlhfuse => "RLHFuse".into(),
            Policy::ModelSpec => "veRL+model-spec".into(),
            Policy::NgramSpec => "veRL+n-gram".into(),
            Policy::SpecActor { decoupled, reconfig, fon } => match (decoupled, reconfig, fon) {
                (true, true, true) => "SpecActor".into(),
                (true, true, false) => "SpecActor(-FoN)".into(),
                (true, false, false) => "SpecActor(decoupled-only)".into(),
                (false, false, false) => "SpecActor(vanilla-spec)".into(),
                _ => format!("SpecActor(d={decoupled},r={reconfig},f={fon})"),
            },
        }
    }
}

/// Timeline segment for Fig 16.
#[derive(Clone, Debug)]
pub struct Segment {
    pub worker: usize,
    pub start: f64,
    pub end: f64,
    /// Draft method active during the segment ("-" for vanilla, method
    /// label otherwise; "fon:<method>" for adopted straggler service).
    pub method: String,
    pub batch: usize,
}

/// Result of simulating one training step.
#[derive(Clone, Debug, Default)]
pub struct StepResult {
    pub rollout_s: f64,
    /// End-to-end step time (rollout + prepare + learn, after overlap).
    pub step_s: f64,
    pub total_tokens: u64,
    pub drafted_tokens: u64,
    pub accepted_tokens: u64,
    pub wasted_tokens: u64,
    /// Fraction of worker·time idle during rollout.
    pub idle_frac: f64,
    /// Mean TGS across the rollout (tokens per worker-second).
    pub mean_tgs: f64,
    /// Per-worker finish times.
    pub finish_times: Vec<f64>,
    /// Fraction of iterations of the LAST-finishing request that advanced
    /// more than one token (§5.2's "skipped iteration" metric).
    pub tail_skipped_iter_frac: f64,
    pub timeline: Vec<Segment>,
    /// GPUs this policy actually used (veRL 2x uses double).
    pub gpus_used: usize,
}

impl StepResult {
    pub fn tokens_per_gpu_second(&self) -> f64 {
        self.total_tokens as f64 / (self.rollout_s * self.gpus_used as f64)
    }
}

/// Per-request speculation state inside a worker.
struct SpecState {
    method_idx: usize,
    w: usize,
    coupled: bool,
    /// Decoupled pipeline staleness: after a partial accept the next
    /// in-flight chunk was drafted from a wrong prefix and verifies to
    /// nothing — the mechanism behind the paper's (a+1)/2 discount in τ_w.
    stale: bool,
    /// iterations / multi-token iterations (skipped-iteration metric)
    iters: u64,
    multi_iters: u64,
}

struct SimWorker {
    id: usize,
    /// (request index into the step's request vec, spec state)
    slots: Vec<(usize, SpecState)>,
    t: f64,
    busy: f64,
    rounds: u64,
    /// When this worker becomes a FoN host: which method it serves.
    fon_method: Option<String>,
    done: bool,
}

/// Shared per-step simulation context.
pub struct StepSim<'a> {
    pub cfg: &'a TraceConfig,
    pub m: CostModel,
    pub reqs: Vec<SimRequest>,
    pub rng: Rng,
}

const RECONFIG_PERIOD: f64 = 1000.0; // decoding iterations (paper §4.1)
const KV_SCALE_DELAY: f64 = 0.25; // seconds: KV transfer + verifier wakeup
const FON_BMAX: usize = 8;

/// Sample how many of `w` drafted tokens are accepted at rate `p`.
fn sample_accept(rng: &mut Rng, w: usize, p: f64) -> usize {
    let mut a = 0;
    while a < w && rng.bernoulli(p) {
        a += 1;
    }
    a
}

pub fn simulate_step(cfg: &TraceConfig, policy: &Policy, step: usize, seed: u64) -> StepResult {
    let mut rng = Rng::new(seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let m = cfg.cost_model();
    let mut reqs = crate::sim::traces::gen_step_requests(cfg, step, &mut rng);

    let (base_workers, gpus_used) = match policy {
        Policy::Verl2x => (cfg.workers() * 2, cfg.gpus * 2),
        _ => (cfg.workers(), cfg.gpus),
    };
    let plan_gv = cfg.tp;

    // ladder + initial method/plan for speculative policies: SpecActor
    // selects under decoupled-mode speedups (the mode it will run)
    let ladder = match policy {
        Policy::SpecActor { decoupled: true, .. } => {
            Ladder::build_decoupled(&m, cfg.per_worker_batch(), 4, &cfg.profiled_acceptance())
        }
        _ => Ladder::build(&m, cfg.per_worker_batch(), 4, &cfg.profiled_acceptance()),
    };
    let methods = m.methods();
    let pick_method = |name: &str| methods.iter().position(|x| x == name).unwrap_or(0);

    #[allow(unused_assignments)]
    let (init_method, init_w, decoupled, reconfig, fon) = match policy {
        Policy::Verl | Policy::Verl2x | Policy::Rlhfuse => (None, 0, false, false, false),
        Policy::ModelSpec => {
            // sweet-spot model drafter (paper: 0.5B for 32B)
            let name = if cfg.moe { "draft_4b" } else { "draft_small" };
            (Some(pick_method(name)), 4, false, false, false)
        }
        Policy::NgramSpec => (Some(pick_method("ngram")), 4, false, false, false),
        Policy::SpecActor { decoupled, reconfig, fon } => {
            let sel = ladder.select_initial().method.clone();
            let plan = search(
                &m,
                &PlanInput {
                    global_batch: cfg.global_batch,
                    gpus: cfg.gpus,
                    verifier_configs: vec![cfg.tp, cfg.tp * 2],
                    accept_p: cfg
                        .profiled_acceptance()
                        .iter()
                        .find(|(n, _)| *n == sel)
                        .map(|(_, p)| *p)
                        .unwrap_or(0.7),
                    method: sel.clone(),
                    max_window: 8,
                    fixed_batch: Some(cfg.per_worker_batch()),
                    fused_windows: vec![],
                },
            );
            let mut w = if *decoupled { plan.as_ref().map(|p| p.w).unwrap_or(4).clamp(1, 8) } else { 4 };
            // The planner also compares against the best *coupled* plan
            // (TGS_C, Algorithm 2's model): SpecActor never runs a mode its
            // own model predicts slower — decoupling is an option, not a
            // mandate (§4.1: switching modes only pauses aggressive
            // drafting).
            let mut run_decoupled = *decoupled;
            if *decoupled {
                let p_sel = cfg
                    .profiled_acceptance()
                    .iter()
                    .find(|(n, _)| *n == sel)
                    .map(|(_, p)| *p)
                    .unwrap_or(0.7);
                let b = cfg.per_worker_batch();
                let (mut best_c, mut best_cw) = (f64::MIN, 4usize);
                for cw in 1..=8 {
                    let t = crate::planner::tgs::tgs_coupled(&m, &sel, cfg.tp, cw, b, p_sel);
                    if t > best_c {
                        best_c = t;
                        best_cw = cw;
                    }
                }
                let t_d = crate::planner::tgs::tgs_decoupled(&m, &sel, cfg.tp, w, b, p_sel);
                // Require a clear modelled margin before decoupling: the
                // expectation model evaluates at the batch-MEAN acceptance,
                // while pipeline staleness hits below-mean requests
                // superlinearly (Jensen gap observed in simulation).
                if best_c * 1.15 > t_d {
                    run_decoupled = false;
                    w = best_cw;
                }
            }
            if std::env::var("SPECACTOR_SIM_DEBUG").is_ok() {
                eprintln!("[plan] method={sel} w={w} decoupled={run_decoupled} plan={plan:?}");
            }
            (Some(pick_method(&sel)), w, run_decoupled, *reconfig, *fon)
        }
    };

    let workers = base_workers;

    // distribute requests round-robin
    let mut sim_workers: Vec<SimWorker> = (0..workers)
        .map(|id| SimWorker {
            id,
            slots: Vec::new(),
            t: 0.0,
            busy: 0.0,
            rounds: 0,
            fon_method: None,
            done: false,
        })
        .collect();
    for (ri, _) in reqs.iter().enumerate() {
        let wid = ri % workers;
        sim_workers[wid].slots.push((
            ri,
            SpecState {
                method_idx: init_method.unwrap_or(0),
                w: init_w.max(1),
                coupled: !decoupled,
                stale: false,
                iters: 0,
                multi_iters: 0,
            },
        ));
    }

    // event loop
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let key = |t: f64, id: usize| Reverse(((t * 1e9) as u64, id));
    for w in &sim_workers {
        heap.push(key(0.0, w.id));
    }
    let mut timeline: Vec<Segment> = Vec::new();
    // request idx -> adopting worker: requests migrated by FoN; their home
    // workers drop them at their next round. HashMap: the O(n) scan here
    // was the simulator's top hot spot (see EXPERIMENTS.md §Perf).
    let mut migrations: std::collections::HashMap<usize, usize> =
        std::collections::HashMap::new();
    let mut total_tokens = 0u64;
    let mut drafted = 0u64;
    let mut accepted = 0u64;
    let mut wasted = 0u64;
    // requests adopted by FoN hosts: (request idx -> adopted method idx)
    let spec = init_method.is_some();

    while let Some(Reverse((tkey, wid))) = heap.pop() {
        let now = tkey as f64 / 1e9;
        // split borrow: take the worker out
        let w = &mut sim_workers[wid];
        if w.done {
            continue;
        }
        w.slots
            .retain(|(ri, _)| !reqs[*ri].done() && migrations.get(ri).map(|ww| *ww == wid).unwrap_or(true));
        if w.slots.is_empty() {
            w.done = true;
            w.t = now;
            // FoN: this worker is now free — adopt stragglers
            if fon {
                let mut free = vec![FreeWorker {
                    id: wid,
                    capacity: FON_BMAX,
                    method: None,
                    load: 0,
                }];
                // stragglers: active requests not already adopted by a
                // FoN host (one racing replica per request keeps the
                // migration model acyclic), worst acceptance first
                let mut stragglers: Vec<Straggler> = reqs
                    .iter()
                    .enumerate()
                    .filter(|(ri, r)| !r.done() && !migrations.contains_key(ri))
                    .map(|(ri, r)| Straggler {
                        request: ri as u64,
                        accept_rate: r.accept_for(&methods[init_method.unwrap_or(0)]),
                        methods: vec![methods[init_method.unwrap_or(0)].clone()],
                    })
                    .collect();
                if !stragglers.is_empty() {
                    let rank: Vec<String> =
                        ladder.ranked().iter().map(|e| e.method.clone()).collect();
                    let assignment = assign(&mut stragglers, &rank, &mut free, FON_BMAX);
                    if !assignment.is_empty() {
                        // reactivate this worker as a FoN host
                        let method = rank[free[0].method.unwrap()].clone();
                        let midx = pick_method(&method);
                        w.done = false;
                        w.fon_method = Some(method.clone());
                        let migrated: Vec<usize> =
                            assignment.keys().map(|(ri, _)| *ri as usize).collect();
                        for &ri in &migrated {
                            // fastest-of-N: the new (method, small-batch)
                            // replica wins the race for a straggler, so the
                            // request migrates ("removed from other
                            // workers", §4.2) after the KV-scale delay.
                            w.slots.push((
                                ri,
                                SpecState {
                                    method_idx: midx,
                                    // dedicated tail service: coupled mode
                                    // (no pipeline staleness) with a full
                                    // window — per Algorithm 2 at b = 1
                                    w: 4,
                                    coupled: true,
                                    stale: false,
                                    iters: 0,
                                    multi_iters: 0,
                                },
                            ));
                        }
                        for &ri in &migrated {
                            migrations.insert(ri, wid);
                        }
                        w.t = now + KV_SCALE_DELAY;
                        heap.push(key(w.t, wid));
                        timeline.push(Segment {
                            worker: wid,
                            start: now,
                            end: now + KV_SCALE_DELAY,
                            method: "scale".into(),
                            batch: w.slots.len(),
                        });
                        continue;
                    }
                }
            }
            continue;
        }

        let b = w.slots.len();
        // round latency + per-request advancement
        let (dt, method_label) = if !spec {
            // vanilla decode round
            for (ri, st) in w.slots.iter_mut() {
                let r = &mut reqs[*ri];
                r.progress += 1;
                st.iters += 1;
                total_tokens += 1;
            }
            (m.decode(b), "-".to_string())
        } else {
            // speculative round: per-request window/method, batched.
            // Mixed windows are fused (paper: one CUDA graph), so the
            // verifier's token load scales with the *average* window.
            let w_avg = w.slots.iter().map(|(_, st)| st.w).sum::<usize>() as f64
                / w.slots.len() as f64;
            let mut dt = 0.0f64;
            for (ri, st) in w.slots.iter_mut() {
                let r = &mut reqs[*ri];
                let method = &methods[st.method_idx];
                let p = r.accept_for(method);
                let gain = if st.stale {
                    // decoupled pipeline flush: the in-flight chunk was
                    // drafted past a rejection — it verifies to nothing
                    st.stale = false;
                    drafted += st.w as u64;
                    wasted += st.w as u64;
                    0
                } else {
                    let a = sample_accept(&mut rng, st.w, p);
                    let full = a == st.w;
                    drafted += st.w as u64;
                    accepted += a as u64;
                    wasted += (st.w - a) as u64;
                    if st.coupled {
                        a + 1 // correction or bonus token
                    } else if full {
                        a
                    } else {
                        st.stale = true; // next chunk is garbage
                        a + 1
                    }
                };
                let gain = gain.min(r.remaining());
                r.progress += gain;
                total_tokens += gain as u64;
                st.iters += 1;
                if gain > 1 {
                    st.multi_iters += 1;
                }
            }
            // round time: decoupled slots overlap drafting with the
            // verification pass; coupled slots serialize their drafting
            // (paper fuses mixed windows into one CUDA graph — the cost is
            // the verify pass plus the coupled subset's serial drafting)
            let mdix = w.slots[0].1.method_idx;
            let method = &methods[mdix];
            let b_coupled = w.slots.iter().filter(|(_, st)| st.coupled).count();
            let draft_overlap = w_avg * m.draft(method, b - b_coupled);
            let verify_t = m.verify_f(plan_gv, w_avg, b);
            let draft_serial = if b_coupled > 0 {
                w_avg * m.draft(method, b_coupled)
            } else {
                0.0
            };
            dt += if b_coupled == b {
                draft_serial + verify_t
            } else {
                draft_overlap.max(verify_t) + draft_serial
            };
            (dt, methods[mdix].clone())
        };

        let seg_method = match &w.fon_method {
            Some(fm) => format!("fon:{fm}"),
            None => method_label,
        };
        // merge contiguous same-method segments to keep Fig 16 data small
        match timeline.last_mut() {
            Some(s) if s.worker == wid && s.method == seg_method && (s.end - w.t).abs() < 1e-9 => {
                s.end = w.t + dt;
                s.batch = b;
            }
            _ => timeline.push(Segment {
                worker: wid,
                start: w.t,
                end: w.t + dt,
                method: seg_method,
                batch: b,
            }),
        }
        w.busy += dt;
        w.t += dt;
        w.rounds += 1;

        // Algorithm 2: periodic per-request reconfiguration (the paper
        // reconfigures every 1000 decoding iterations; spec rounds cover
        // several iterations each)
        if reconfig && w.rounds % (RECONFIG_PERIOD as u64 / 8).max(1) == 0 {
            let b = w.slots.len();
            let rates: Vec<f64> = w
                .slots
                .iter()
                .map(|(ri, st)| reqs[*ri].accept_for(&methods[st.method_idx]))
                .collect();
            // Algorithm 2 models each request at b = 1 — it is a *tail*
            // mechanism: while a request shares a sizeable batch, its
            // round time is set by the batch, and shrinking its window
            // only cuts its token gain. Apply the per-request plan once
            // the worker's batch has drained to tail size.
            if b <= 16 {
                let plans =
                    reconfigure_batch(&m, &methods[w.slots[0].1.method_idx], plan_gv, &rates, 8);
                for (slot_i, plan) in plans {
                    let st = &mut w.slots[slot_i].1;
                    st.w = plan.w;
                    st.coupled = plan.mode == Mode::Coupled;
                }
            }
        }

        heap.push(key(w.t, wid));
    }

    // collect results
    let finish_times: Vec<f64> = sim_workers.iter().map(|w| w.t).collect();
    let rollout_s = finish_times.iter().copied().fold(0.0, f64::max);
    let busy_total: f64 = sim_workers.iter().map(|w| w.busy).sum();
    let idle_frac = 1.0 - busy_total / (rollout_s * workers as f64);

    // skipped-iteration fraction of the last finished requests
    let tail_skipped = {
        let mut worst: Vec<(f64, f64)> = sim_workers
            .iter()
            .flat_map(|w| w.slots.iter().map(move |(_, st)| {
                let frac = if st.iters > 0 { st.multi_iters as f64 / st.iters as f64 } else { 0.0 };
                (w.t, frac)
            }))
            .collect();
        // slots were drained on completion; recompute from timeline tail if
        // empty (vanilla: zero anyway)
        if worst.is_empty() {
            0.0
        } else {
            worst.sort_by(|a, b| b.0.total_cmp(&a.0));
            worst.truncate(8);
            worst.iter().map(|(_, f)| *f).sum::<f64>() / worst.len() as f64
        }
    };

    // other phases (prepare + learn): fraction of the VANILLA rollout time
    // of this trace (so speculation does not shrink them), overlapped away
    // partially by RLHFuse.
    let vanilla_scale = estimate_vanilla_rollout(cfg, step, seed);
    let other = cfg.other_phase_frac * vanilla_scale;
    let step_s = match policy {
        Policy::Rlhfuse => rollout_s + other * 0.80,
        _ => rollout_s + other,
    };

    StepResult {
        rollout_s,
        step_s,
        total_tokens,
        drafted_tokens: drafted,
        accepted_tokens: accepted,
        wasted_tokens: wasted,
        idle_frac,
        mean_tgs: total_tokens as f64 / busy_total.max(1e-9),
        finish_times,
        tail_skipped_iter_frac: tail_skipped,
        timeline,
        gpus_used,
    }
}

/// Closed-form estimate of the vanilla rollout time (longest worker):
/// used to size the prepare/learn phases consistently across policies.
pub fn estimate_vanilla_rollout(cfg: &TraceConfig, step: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let m = cfg.cost_model();
    let reqs = crate::sim::traces::gen_step_requests(cfg, step, &mut rng);
    let workers = cfg.workers();
    let mut worst = 0.0f64;
    for wid in 0..workers {
        let mut lens: Vec<usize> =
            reqs.iter().enumerate().filter(|(i, _)| i % workers == wid).map(|(_, r)| r.length).collect();
        lens.sort_unstable();
        // decode rounds: batch shrinks as requests finish
        let mut t = 0.0;
        let mut prev = 0usize;
        let mut remaining = lens.len();
        for &l in &lens {
            t += (l - prev) as f64 * m.decode(remaining);
            prev = l;
            remaining -= 1;
        }
        worst = worst.max(t);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> TraceConfig {
        // 1/8-scale PPO trace: preserves the per-worker batch and tail
        // structure of the paper's configuration at test-friendly size
        crate::sim::scale::scaled(&TraceConfig::ppo_32b_20k(), 8, 2000)
    }

    #[test]
    fn all_policies_complete_all_requests() {
        let cfg = small_trace();
        for policy in [
            Policy::Verl,
            Policy::Verl2x,
            Policy::Rlhfuse,
            Policy::ModelSpec,
            Policy::NgramSpec,
            Policy::specactor(),
        ] {
            let r = simulate_step(&cfg, &policy, 100, 7);
            assert!(r.rollout_s > 0.0, "{policy:?}");
            assert!(r.total_tokens > 0, "{policy:?}");
            assert!(r.step_s >= r.rollout_s);
            assert!((0.0..=1.0).contains(&r.idle_frac), "{policy:?} idle {}", r.idle_frac);
        }
    }

    #[test]
    fn token_conservation() {
        // every request's full length must be generated exactly once
        let cfg = small_trace();
        let r = simulate_step(&cfg, &Policy::specactor(), 100, 3);
        let mut rng = Rng::new(3 ^ (100u64).wrapping_mul(0x9E3779B97F4A7C15));
        let reqs = crate::sim::traces::gen_step_requests(&cfg, 100, &mut rng);
        let want: u64 = reqs.iter().map(|r| r.length as u64).sum();
        assert_eq!(r.total_tokens, want, "token conservation violated");
    }

    #[test]
    fn specactor_beats_verl() {
        let cfg = small_trace();
        let verl = simulate_step(&cfg, &Policy::Verl, 100, 7);
        let sa = simulate_step(&cfg, &Policy::specactor(), 100, 7);
        let speedup = verl.rollout_s / sa.rollout_s;
        // Paper reports 2.0-2.4x; our acceptance mixture and conservative
        // staleness model land lower (EXPERIMENTS.md §Deviations) — the
        // invariant asserted here is a real, reproducible improvement.
        assert!(speedup > 1.1, "SpecActor speedup only {speedup:.2}x");
    }

    #[test]
    fn vanilla_spec_weak_at_large_batch() {
        // Fig 5b / §5.5: coupled model-spec gains little at production
        // batch sizes
        let cfg = TraceConfig::dapo_32b_20k();
        let mut c = cfg.clone();
        c.global_batch = 2048;
        c.gpus = 32; // per-worker batch 256
        c.budget = 1500;
        let verl = simulate_step(&c, &Policy::Verl, 50, 9);
        let spec = simulate_step(&c, &Policy::ModelSpec, 50, 9);
        let speedup = verl.rollout_s / spec.rollout_s;
        assert!(speedup < 1.35, "vanilla spec at b=256 gained {speedup:.2}x, too much");
        let sa = simulate_step(&c, &Policy::specactor(), 50, 9);
        assert!(
            verl.rollout_s / sa.rollout_s > speedup,
            "SpecActor must beat vanilla spec"
        );
    }

    #[test]
    fn verl2x_limited_speedup() {
        // Fig 2b: doubling GPUs buys only ~1.2-1.3x
        let cfg = small_trace();
        let verl = simulate_step(&cfg, &Policy::Verl, 100, 7);
        let v2 = simulate_step(&cfg, &Policy::Verl2x, 100, 7);
        let speedup = verl.rollout_s / v2.rollout_s;
        assert!(
            (1.0..=1.6).contains(&speedup),
            "veRL(2x) speedup {speedup:.2} out of plausible band"
        );
    }

    #[test]
    fn timeline_segments_cover_rollout() {
        let cfg = small_trace();
        let r = simulate_step(&cfg, &Policy::specactor(), 100, 7);
        assert!(!r.timeline.is_empty());
        for s in &r.timeline {
            assert!(s.end > s.start);
            assert!(s.end <= r.rollout_s + 1e-6);
        }
    }
}
