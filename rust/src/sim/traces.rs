//! Production-trace models (§5.1): GRPO/DAPO/PPO-32B-20K and the
//! Qwen3-235B MoE trace, with the paper's batch sizes, GPU counts, TP
//! degrees and response budgets.
//!
//! The paper replays checkpoints against recorded prompt batches; we have
//! neither, so each trace is a *generator*: per-request response lengths
//! follow a long-tailed lognormal whose mean grows across training steps
//! ("as the model becomes smarter it generates more tokens", §2.2), and
//! per-(request, method) acceptance rates follow a request-class mixture
//! that reproduces the Fig 7 heterogeneity and the Fig 10 stability.

use crate::planner::costmodel::CostModel;
use crate::util::Rng;

/// Request classes driving acceptance heterogeneity (Fig 7): which draft
/// method suits a request depends on its content class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqClass {
    /// Plain reasoning: model drafters do well, n-gram poorly.
    Smooth,
    /// Hard/noisy: all drafters degrade, deeper drafter degrades least.
    Hard,
    /// Repetitive structure (tables, code): n-gram shines.
    Repetitive,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub name: &'static str,
    pub algo: &'static str,
    /// Per-step sampled prompts (incl. group sampling factor).
    pub global_batch: usize,
    /// Response budget in tokens.
    pub budget: usize,
    pub gpus: usize,
    /// GPUs per rollout worker (TP/EP degree).
    pub tp: usize,
    pub steps: usize,
    /// Lognormal response-length parameters at step 0 (of the underlying
    /// normal), truncated at `budget`.
    pub len_mu0: f64,
    pub len_sigma: f64,
    /// Mean-length growth factor over the full run (smarter model → longer).
    pub len_growth: f64,
    /// Class mixture (Smooth, Hard, Repetitive).
    pub class_probs: [f64; 3],
    /// prepare+learn time as a fraction of mean *vanilla* rollout time
    /// (Fig 2a: rollout 70–80 % of a step).
    pub other_phase_frac: f64,
    /// Cost model for this trace's target model.
    pub moe: bool,
}

impl TraceConfig {
    pub fn grpo_32b_20k() -> Self {
        TraceConfig {
            name: "GRPO-32B-20K",
            algo: "GRPO",
            global_batch: 8192,
            budget: 20_000,
            gpus: 256,
            tp: 4,
            steps: 200,
            len_mu0: 5.4, // median ~220 tokens; >10K stragglers hit ~1/3 of workers
            len_sigma: 1.3,
            len_growth: 1.8,
            class_probs: [0.6, 0.25, 0.15],
            other_phase_frac: 0.33,
            moe: false,
        }
    }

    pub fn dapo_32b_20k() -> Self {
        TraceConfig {
            name: "DAPO-32B-20K",
            algo: "DAPO",
            global_batch: 16_384,
            budget: 20_000,
            gpus: 256,
            tp: 4,
            steps: 200,
            len_mu0: 5.2,
            len_sigma: 1.35,
            len_growth: 2.0,
            class_probs: [0.55, 0.3, 0.15],
            other_phase_frac: 0.30,
            moe: false,
        }
    }

    pub fn ppo_32b_20k() -> Self {
        TraceConfig {
            name: "PPO-32B-20K",
            algo: "PPO",
            global_batch: 4096,
            budget: 20_000,
            gpus: 256,
            tp: 4,
            steps: 200,
            len_mu0: 5.6,
            len_sigma: 1.25,
            len_growth: 1.6,
            class_probs: [0.65, 0.2, 0.15],
            // PPO trains a critic too: larger non-rollout share
            other_phase_frac: 0.45,
            moe: false,
        }
    }

    pub fn grpo_235b_moe() -> Self {
        TraceConfig {
            name: "GRPO-235B-MoE",
            algo: "GRPO",
            global_batch: 256,
            budget: 20_000,
            gpus: 256,
            tp: 8, // EP8
            steps: 12,
            len_mu0: 5.8,
            len_sigma: 1.3,
            len_growth: 1.9,
            class_probs: [0.6, 0.25, 0.15],
            other_phase_frac: 0.3,
            moe: true,
        }
    }

    pub fn all_dense() -> Vec<TraceConfig> {
        vec![Self::grpo_32b_20k(), Self::dapo_32b_20k(), Self::ppo_32b_20k()]
    }

    pub fn workers(&self) -> usize {
        self.gpus / self.tp
    }

    pub fn per_worker_batch(&self) -> usize {
        self.global_batch.div_ceil(self.workers())
    }

    pub fn cost_model(&self) -> CostModel {
        if self.moe {
            CostModel::paper_235b_moe()
        } else {
            CostModel::paper_32b()
        }
    }

    /// Profiled average acceptance per method (ladder input; Fig 10's
    /// stability claim makes this a constant across steps).
    pub fn profiled_acceptance(&self) -> Vec<(String, f64)> {
        if self.moe {
            vec![
                ("draft_4b".into(), 0.88),
                ("draft_1.7b".into(), 0.72),
                ("draft_0.6b".into(), 0.62),
                ("ngram".into(), 0.38),
            ]
        } else {
            vec![
                ("draft_mid".into(), 0.82),
                ("draft_small".into(), 0.74),
                ("ngram".into(), 0.40),
            ]
        }
    }
}

/// Open-loop arrival processes: request arrival times are generated
/// independently of completions (the serving regime, as opposed to the
/// closed per-training-step batches above). Shared by the cluster
/// simulator, the `serve` subsystem's CLI/demo drivers and
/// `benches/serve_throughput.rs`, all with seeded [`Rng`] determinism.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: i.i.d. exponential inter-arrival times at
    /// `rate` requests/second.
    Poisson { rate: f64 },
    /// Markov-modulated Poisson (bursty): the process alternates between a
    /// quiet state (`rate_lo`) and a burst state (`rate_hi`), with
    /// exponential state dwell times of mean `mean_dwell_s` seconds.
    Bursty { rate_lo: f64, rate_hi: f64, mean_dwell_s: f64 },
}

impl ArrivalProcess {
    /// Bursty process whose **long-run mean** equals `rate` (so
    /// poisson-vs-bursty comparisons run at the same offered load): a
    /// quiet state at `0.25·rate` and a burst state at `1.75·rate` with
    /// equal expected dwell, mean `(0.25 + 1.75)/2 · rate = rate`.
    pub fn bursty_with_mean(rate: f64) -> ArrivalProcess {
        assert!(rate > 0.0, "bursty mean rate must be positive");
        ArrivalProcess::Bursty {
            rate_lo: 0.25 * rate,
            rate_hi: 1.75 * rate,
            mean_dwell_s: 0.5,
        }
    }

    /// Sample `n` absolute arrival times (seconds, ascending from 0).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "poisson rate must be positive");
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(rate);
                    out.push(t);
                }
            }
            ArrivalProcess::Bursty { rate_lo, rate_hi, mean_dwell_s } => {
                assert!(
                    rate_lo > 0.0 && rate_hi > 0.0 && mean_dwell_s > 0.0,
                    "bursty parameters must be positive"
                );
                let mut t = 0.0;
                let mut hi = false;
                // time left in the current modulating state
                let mut dwell = rng.exponential(1.0 / mean_dwell_s);
                while out.len() < n {
                    let rate = if hi { rate_hi } else { rate_lo };
                    let inter = rng.exponential(rate);
                    if inter < dwell {
                        // next arrival lands inside the current state
                        t += inter;
                        dwell -= inter;
                        out.push(t);
                    } else {
                        // state switches before the tentative arrival; the
                        // exponential's memorylessness lets us resample
                        // from the switch point.
                        t += dwell;
                        hi = !hi;
                        dwell = rng.exponential(1.0 / mean_dwell_s);
                    }
                }
            }
        }
        out
    }

    /// Long-run mean arrival rate (requests/second).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            // equal expected dwell in each state -> average of the rates
            ArrivalProcess::Bursty { rate_lo, rate_hi, .. } => 0.5 * (rate_lo + rate_hi),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
        }
    }
}

/// One simulated rollout request.
#[derive(Clone, Debug)]
pub struct SimRequest {
    pub id: u64,
    pub class: ReqClass,
    /// Total tokens this request will generate (ground truth).
    pub length: usize,
    /// Per-method per-token acceptance probability.
    pub accept: Vec<(String, f64)>,
    /// Tokens generated so far.
    pub progress: usize,
}

impl SimRequest {
    pub fn accept_for(&self, method: &str) -> f64 {
        self.accept
            .iter()
            .find(|(m, _)| m == method)
            .map(|(_, p)| *p)
            .unwrap_or(0.0)
    }

    pub fn remaining(&self) -> usize {
        self.length - self.progress
    }

    pub fn done(&self) -> bool {
        self.progress >= self.length
    }
}

/// Per-class mean acceptance for each method (dense traces).
fn class_acceptance(class: ReqClass, method: &str, moe: bool) -> f64 {
    // (mean values; per-request Beta jitter is applied around them)
    let dense = |c: ReqClass, m: &str| -> f64 {
        match (c, m) {
            (ReqClass::Smooth, "draft_mid") => 0.88,
            (ReqClass::Smooth, "draft_small") => 0.82,
            (ReqClass::Smooth, "ngram") => 0.35,
            (ReqClass::Hard, "draft_mid") => 0.72,
            (ReqClass::Hard, "draft_small") => 0.62,
            (ReqClass::Hard, "ngram") => 0.22,
            (ReqClass::Repetitive, "draft_mid") => 0.80,
            (ReqClass::Repetitive, "draft_small") => 0.75,
            (ReqClass::Repetitive, "ngram") => 0.85,
            _ => 0.5,
        }
    };
    let moe_t = |c: ReqClass, m: &str| -> f64 {
        match (c, m) {
            // Qwen3-4B-2507 aligns closely with 235B (§5.3)
            (ReqClass::Smooth, "draft_4b") => 0.92,
            (ReqClass::Smooth, "draft_1.7b") => 0.76,
            (ReqClass::Smooth, "draft_0.6b") => 0.66,
            (ReqClass::Smooth, "ngram") => 0.33,
            (ReqClass::Hard, "draft_4b") => 0.78,
            (ReqClass::Hard, "draft_1.7b") => 0.55,
            (ReqClass::Hard, "draft_0.6b") => 0.45,
            (ReqClass::Hard, "ngram") => 0.2,
            (ReqClass::Repetitive, "draft_4b") => 0.85,
            (ReqClass::Repetitive, "draft_1.7b") => 0.72,
            (ReqClass::Repetitive, "draft_0.6b") => 0.65,
            (ReqClass::Repetitive, "ngram") => 0.86,
            _ => 0.5,
        }
    };
    if moe {
        moe_t(class, method)
    } else {
        dense(class, method)
    }
}

/// Generate the requests of one training step.
pub fn gen_step_requests(cfg: &TraceConfig, step: usize, rng: &mut Rng) -> Vec<SimRequest> {
    let m = cfg.cost_model();
    let methods = m.methods();
    // smarter model → longer responses: scale mu with training progress
    let progress = step as f64 / cfg.steps.max(1) as f64;
    let mu = cfg.len_mu0 + (cfg.len_growth * progress).ln_1p();
    (0..cfg.global_batch as u64)
        .map(|i| {
            let class = match rng.categorical(&cfg.class_probs.to_vec()) {
                0 => ReqClass::Smooth,
                1 => ReqClass::Hard,
                _ => ReqClass::Repetitive,
            };
            let raw = rng.lognormal(mu, cfg.len_sigma);
            let length = (raw as usize).clamp(64, cfg.budget);
            let accept = methods
                .iter()
                .map(|meth| {
                    let mean = class_acceptance(class, meth, cfg.moe);
                    // Beta jitter with concentration 30 around the mean
                    let k = 30.0;
                    let p = rng.beta(mean * k, (1.0 - mean) * k);
                    (meth.clone(), p.clamp(0.02, 0.98))
                })
                .collect();
            SimRequest { id: i, class, length, accept, progress: 0 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worker_batches() {
        assert_eq!(TraceConfig::grpo_32b_20k().per_worker_batch(), 128);
        assert_eq!(TraceConfig::dapo_32b_20k().per_worker_batch(), 256);
        assert_eq!(TraceConfig::ppo_32b_20k().per_worker_batch(), 64);
        assert_eq!(TraceConfig::grpo_235b_moe().workers(), 32);
    }

    #[test]
    fn lengths_are_long_tailed() {
        let cfg = TraceConfig::dapo_32b_20k();
        let mut rng = Rng::new(1);
        let reqs = gen_step_requests(&cfg, 100, &mut rng);
        let lens: Vec<f64> = reqs.iter().map(|r| r.length as f64).collect();
        let mean = crate::util::stats::mean(&lens);
        let p99 = crate::util::stats::percentile(&lens, 99.0);
        assert!(p99 > 3.0 * mean, "p99 {p99} vs mean {mean}: tail too light");
        assert!(lens.iter().any(|&l| l >= cfg.budget as f64 * 0.99), "no budget-capped requests");
    }

    #[test]
    fn lengths_grow_with_training() {
        let cfg = TraceConfig::dapo_32b_20k();
        let mean_at = |step: usize| {
            let mut rng = Rng::new(9);
            let reqs = gen_step_requests(&cfg, step, &mut rng);
            reqs.iter().map(|r| r.length as f64).sum::<f64>() / reqs.len() as f64
        };
        assert!(mean_at(190) > mean_at(5) * 1.2, "no length growth across steps");
    }

    #[test]
    fn acceptance_heterogeneity_matches_fig7() {
        // every method must be the best one for SOME requests
        let cfg = TraceConfig::dapo_32b_20k();
        let mut rng = Rng::new(4);
        let reqs = gen_step_requests(&cfg, 100, &mut rng);
        let mut winners = std::collections::BTreeMap::new();
        for r in &reqs {
            let best = r
                .accept
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
                .0
                .clone();
            *winners.entry(best).or_insert(0usize) += 1;
        }
        assert!(winners.len() >= 3, "only {winners:?} ever win");
        // and the majority still prefers a model drafter
        let ngram_share = *winners.get("ngram").unwrap_or(&0) as f64 / reqs.len() as f64;
        assert!(ngram_share > 0.02 && ngram_share < 0.5, "ngram share {ngram_share}");
    }

    fn inter_arrivals(ts: &[f64]) -> Vec<f64> {
        let mut prev = 0.0;
        ts.iter()
            .map(|&t| {
                let d = t - prev;
                prev = t;
                d
            })
            .collect()
    }

    #[test]
    fn poisson_arrivals_match_rate() {
        let p = ArrivalProcess::Poisson { rate: 20.0 };
        let mut rng = Rng::new(3);
        let ts = p.sample(20_000, &mut rng);
        assert_eq!(ts.len(), 20_000);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]), "arrival times not sorted");
        let gaps = inter_arrivals(&ts);
        let mean = crate::util::stats::mean(&gaps);
        assert!((mean - 0.05).abs() < 0.005, "mean inter-arrival {mean} != 1/rate");
        // exponential gaps: coefficient of variation ~ 1
        let cv = crate::util::stats::stddev(&gaps) / mean;
        assert!((cv - 1.0).abs() < 0.1, "poisson CV {cv}");
    }

    #[test]
    fn bursty_arrivals_are_burstier_than_poisson() {
        let b = ArrivalProcess::Bursty { rate_lo: 4.0, rate_hi: 80.0, mean_dwell_s: 0.5 };
        let mut rng = Rng::new(9);
        let ts = b.sample(20_000, &mut rng);
        let gaps = inter_arrivals(&ts);
        let mean = crate::util::stats::mean(&gaps);
        let cv = crate::util::stats::stddev(&gaps) / mean;
        assert!(cv > 1.3, "bursty CV {cv} not burstier than poisson");
        // long-run rate lands between the two state rates
        let rate = ts.len() as f64 / ts.last().unwrap();
        assert!(rate > 4.0 && rate < 80.0, "bursty rate {rate} outside state rates");
    }

    #[test]
    fn bursty_with_mean_preserves_offered_load() {
        let p = ArrivalProcess::bursty_with_mean(20.0);
        assert!((p.mean_rate() - 20.0).abs() < 1e-9);
        let mut rng = Rng::new(31);
        let ts = p.sample(40_000, &mut rng);
        let realized = ts.len() as f64 / ts.last().unwrap();
        assert!(
            (realized - 20.0).abs() / 20.0 < 0.15,
            "realized bursty rate {realized} far from requested 20"
        );
    }

    #[test]
    fn arrivals_are_seed_deterministic() {
        for p in [
            ArrivalProcess::Poisson { rate: 10.0 },
            ArrivalProcess::Bursty { rate_lo: 2.0, rate_hi: 40.0, mean_dwell_s: 1.0 },
        ] {
            let a = p.sample(64, &mut Rng::new(42));
            let b = p.sample(64, &mut Rng::new(42));
            assert_eq!(a, b, "{} not deterministic", p.label());
            assert!(p.mean_rate() > 0.0);
        }
    }

    #[test]
    fn average_acceptance_stable_across_steps() {
        // Fig 10: batch-average acceptance is statistically stable
        let cfg = TraceConfig::grpo_32b_20k();
        let avg_at = |step: usize, seed: u64| {
            let mut rng = Rng::new(seed);
            let reqs = gen_step_requests(&cfg, step, &mut rng);
            reqs.iter().map(|r| r.accept_for("draft_small")).sum::<f64>() / reqs.len() as f64
        };
        let a = avg_at(0, 1);
        let b = avg_at(150, 2);
        assert!((a - b).abs() < 0.03, "acceptance drifted: {a} vs {b}");
    }
}
