//! Cluster-scale discrete-event simulator.
//!
//! Regenerates the paper's evaluation (Figures 2, 5, 6b, 7, 10, 12–16) at
//! 256–512-GPU scale, driven by the same affine cost model and the same
//! planner / reconfiguration / FoN code as the real engine. See
//! DESIGN.md §2 for the substitution argument and §5 for the
//! experiment-to-bench mapping.

pub mod rollout;
pub mod scale;
pub mod traces;

pub use rollout::{simulate_step, Policy, Segment, StepResult};
pub use scale::scaled;
pub use traces::{gen_step_requests, ArrivalProcess, ReqClass, SimRequest, TraceConfig};
