//! SpecActor: fast LLM post-training rollout via decoupled and
//! Fastest-of-N speculation.
//!
//! Reproduction of "Fast LLM Post-training via Decoupled and Fastest-of-N
//! Speculation" (CS.DC 2025). Three-layer architecture:
//!
//! * Layer 1 (build-time python): Pallas kernels for the attention /
//!   verification hot-spot (`python/compile/kernels/`).
//! * Layer 2 (build-time python): JAX transformer model lowered AOT to HLO
//!   text artifacts (`python/compile/model.py`, `aot.py`).
//! * Layer 3 (this crate): the rust coordinator — request routing, dynamic
//!   batching, decoupled draft/verify pipelines, the decoupled-execution
//!   planner (Algorithm 1), request-level reconfiguration (Algorithm 2) and
//!   greedy Fastest-of-N assignment (Algorithm 3), plus the cluster-scale
//!   discrete-event simulator that regenerates the paper's figures.

pub mod coordinator;
pub mod drafter;
pub mod engine;
pub mod ladder;
pub mod obs;
pub mod planner;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod spec;
pub mod util;
