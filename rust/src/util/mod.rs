//! Small self-contained utilities. The offline crate set contains only the
//! `xla` dependency closure, so JSON, CLI parsing, PRNG, stats, the bench
//! harness and a mini property-testing framework are implemented in-repo
//! (see DESIGN.md §2, infrastructure substitutions).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
