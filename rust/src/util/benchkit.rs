//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a `harness = false` binary that uses
//! [`Bench`] to time closures with warmup, then prints a fixed-width table
//! plus an optional machine-readable JSON line per row. The figure benches
//! (`rust/benches/fig*.rs`) use it to print the same rows/series the paper
//! reports.

use std::time::Instant;

use crate::util::stats;

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (which should perform one full unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: self.iters,
            mean_s: stats::mean(&samples),
            median_s: stats::percentile_sorted(&samples, 50.0),
            p95_s: stats::percentile_sorted(&samples, 95.0),
            min_s: samples[0],
        };
        self.results.push(m.clone());
        m
    }

    /// Record an externally-computed scalar (e.g. simulated seconds) so all
    /// figure output flows through one table printer.
    pub fn record(&mut self, name: &str, seconds: f64) -> Measurement {
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_s: seconds,
            median_s: seconds,
            p95_s: seconds,
            min_s: seconds,
        };
        self.results.push(m.clone());
        m
    }

    pub fn print_table(&self, title: &str) {
        println!("\n== {title} ==");
        println!("{:<44} {:>12} {:>12} {:>12}", "series", "mean", "median", "p95");
        for m in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12}",
                m.name,
                fmt_s(m.mean_s),
                fmt_s(m.median_s),
                fmt_s(m.p95_s)
            );
        }
    }
}

/// Human duration formatting.
pub fn fmt_s(s: f64) -> String {
    if s >= 100.0 {
        format!("{:.1}s", s)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Print a ratio row ("A is X× faster than B").
pub fn speedup_line(label: &str, base: f64, ours: f64) {
    if ours > 0.0 {
        println!("{label}: {:.2}x (base {} -> {})", base / ours, fmt_s(base), fmt_s(ours));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders() {
        let mut b = Bench::new(0, 5);
        let m = b.run("noop", || {});
        assert!(m.min_s <= m.median_s && m.median_s <= m.p95_s);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn record_scalar() {
        let mut b = Bench::default();
        let m = b.record("sim", 1.5);
        assert_eq!(m.mean_s, 1.5);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_s(2.0), "2.00s");
        assert_eq!(fmt_s(0.002), "2.00ms");
        assert_eq!(fmt_s(2e-6), "2.00us");
        assert_eq!(fmt_s(5e-9), "5ns");
    }
}
