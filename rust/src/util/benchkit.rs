//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a `harness = false` binary that uses
//! [`Bench`] to time closures with warmup, then prints a fixed-width table
//! plus optional machine-readable JSON ([`Bench::write_json`], the
//! `BENCH_*.json` convention) so the perf trajectory can be tracked across
//! PRs. The figure benches (`rust/benches/fig*.rs`) use it to print the
//! same rows/series the paper reports.

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl Measurement {
    /// Row as a JSON object (for `BENCH_*.json` reports). Callers may
    /// merge extra per-row fields into the returned object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("median_s", Json::num(self.median_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (which should perform one full unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let m = Measurement {
            name: name.to_string(),
            iters: self.iters,
            mean_s: stats::mean(&samples),
            median_s: stats::percentile_sorted(&samples, 50.0),
            p95_s: stats::percentile_sorted(&samples, 95.0),
            min_s: samples[0],
        };
        self.results.push(m.clone());
        m
    }

    /// Record an externally-computed scalar (e.g. simulated seconds) so all
    /// figure output flows through one table printer.
    pub fn record(&mut self, name: &str, seconds: f64) -> Measurement {
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_s: seconds,
            median_s: seconds,
            p95_s: seconds,
            min_s: seconds,
        };
        self.results.push(m.clone());
        m
    }

    /// All results as a JSON report object: `{"title", "rows": [...]}`.
    /// `extra` rows are merged per-index into the corresponding result row
    /// (e.g. host-copy byte counters recorded alongside each series); an
    /// `extra` shorter than `results` leaves the tail rows untouched.
    pub fn to_json(&self, title: &str, extra: &[Vec<(&str, Json)>]) -> Json {
        let rows: Vec<Json> = self
            .results
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let mut row = m.to_json();
                if let (Some(fields), Json::Obj(map)) = (extra.get(i), &mut row) {
                    for (k, v) in fields {
                        map.insert(k.to_string(), v.clone());
                    }
                }
                row
            })
            .collect();
        Json::obj(vec![("title", Json::str(title)), ("rows", Json::arr(rows))])
    }

    /// Write the report to `path` as one JSON document (the `BENCH_*.json`
    /// convention; see PERF.md §Tracking the trajectory).
    pub fn write_json(
        &self,
        path: &Path,
        title: &str,
        extra: &[Vec<(&str, Json)>],
    ) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json(title, extra)))
    }

    pub fn print_table(&self, title: &str) {
        println!("\n== {title} ==");
        println!("{:<44} {:>12} {:>12} {:>12}", "series", "mean", "median", "p95");
        for m in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12}",
                m.name,
                fmt_s(m.mean_s),
                fmt_s(m.median_s),
                fmt_s(m.p95_s)
            );
        }
    }
}

/// Human duration formatting.
pub fn fmt_s(s: f64) -> String {
    if s >= 100.0 {
        format!("{:.1}s", s)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Print a ratio row ("A is X× faster than B").
pub fn speedup_line(label: &str, base: f64, ours: f64) {
    if ours > 0.0 {
        println!("{label}: {:.2}x (base {} -> {})", base / ours, fmt_s(base), fmt_s(ours));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders() {
        let mut b = Bench::new(0, 5);
        let m = b.run("noop", || {});
        assert!(m.min_s <= m.median_s && m.median_s <= m.p95_s);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn record_scalar() {
        let mut b = Bench::default();
        let m = b.record("sim", 1.5);
        assert_eq!(m.mean_s, 1.5);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_s(2.0), "2.00s");
        assert_eq!(fmt_s(0.002), "2.00ms");
        assert_eq!(fmt_s(2e-6), "2.00us");
        assert_eq!(fmt_s(5e-9), "5ns");
    }

    #[test]
    fn json_report_roundtrips_with_extra_fields() {
        let mut b = Bench::default();
        b.record("cfg-a", 1.5);
        b.record("cfg-b", 0.5);
        let extra = vec![vec![("kv_d2h_bytes", Json::num(4096.0))]];
        let j = b.to_json("hotpath", &extra);
        assert_eq!(j.get("title").as_str(), Some("hotpath"));
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").as_str(), Some("cfg-a"));
        assert_eq!(rows[0].get("mean_s").as_f64(), Some(1.5));
        assert_eq!(rows[0].get("kv_d2h_bytes").as_f64(), Some(4096.0));
        // extra shorter than results: tail row has no merged field
        assert_eq!(rows[1].get("kv_d2h_bytes"), &Json::Null);
        // printed document parses back
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }
}
