//! Miniature property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with
//! convenience generators). [`check`] runs it for N seeded cases; on
//! failure it reports the failing seed so the case can be replayed
//! deterministically with [`replay`]. Used for coordinator/planner/sim
//! invariants (see `spec`, `planner`, `coordinator`, `sim` test modules).

use crate::util::rng::Rng;

/// Case-local generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Case index (0..cases); properties can use it to scale sizes.
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn prob(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }
}

/// Run `prop` for `cases` seeded cases. Panics (with the failing seed) on
/// the first property violation — the violation itself should panic or
/// return Err.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one failing case by seed (for debugging).
pub fn replay<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Rng::new(seed), case: 0 };
    prop(&mut g)
}

/// Assert helper that returns Err instead of panicking, so `check` can
/// attach seed context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check("sum-commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn fails_with_seed_context() {
        check("always-fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        let _ = replay(42, |g| {
            seen.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut seen2 = Vec::new();
        let _ = replay(42, |g| {
            seen2.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(seen, seen2);
    }

    #[test]
    fn generators_in_range() {
        check("gen-ranges", 100, |g| {
            let v = g.vec_usize(10, 5, 15);
            prop_assert!(v.iter().all(|&x| (5..15).contains(&x)), "{v:?}");
            let f = g.f64_in(1.0, 2.0);
            prop_assert!((1.0..2.0).contains(&f), "{f}");
            Ok(())
        });
    }
}
