//! Deterministic PRNG (splitmix64 + xoshiro256**) used everywhere randomness
//! is needed: sampling, trace generation, the simulator, property tests.
//!
//! We hand-roll this because the offline crate set has no `rand`. Determinism
//! is a feature, not a workaround: speculative-decoding losslessness is
//! verified by comparing spec-decoded output token-for-token against vanilla
//! decoding under the *same* per-(request, position) sampling streams.

/// splitmix64 — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // Expand the seed with splitmix64 per the xoshiro authors' advice.
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *v = splitmix64(x);
        }
        Rng { s }
    }

    /// Derive an independent stream keyed by `key` (e.g. request id).
    pub fn fork(&self, key: u64) -> Rng {
        Rng::new(splitmix64(self.s[0] ^ splitmix64(key ^ 0xA076_1D64_78BD_642F)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (uses two uniforms; no caching for
    /// simplicity/determinism).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang (shape >= 0 handled by
    /// boosting for shape < 1).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x * x * x * x
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * scale;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a, 1.0);
        let y = self.gamma(b, 1.0);
        x / (x + y)
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Sample a token index from f32 logits at temperature `temp`, using the
/// provided RNG. Implements the exact categorical draw that both the vanilla
/// decode path and the verification path must share for lossless speculation.
pub fn sample_logits(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    debug_assert!(!logits.is_empty());
    if temp <= 0.0 {
        // argmax (ties broken by lowest index, deterministically)
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        return best;
    }
    // Stable softmax sampling via the Gumbel-max trick: argmax(logit/T + g).
    // Gumbel-max keeps the draw exactly categorical while avoiding an
    // explicit normalisation pass, and it is branch-free per element.
    let inv_t = 1.0 / temp as f64;
    let mut best = 0usize;
    let mut bv = f64::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        let u = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
        let g = -(-u.ln()).ln();
        let s = v as f64 * inv_t + g;
        if s > bv {
            bv = s;
            best = i;
        }
    }
    best
}

/// RNG stream for sampling position `pos` of request `req` — the shared
/// "sampling tape" that makes speculative verification exactly equal to
/// vanilla decoding (losslessness invariant, tested in `spec::tests`).
pub fn position_rng(seed: u64, req: u64, pos: u64) -> Rng {
    Rng::new(splitmix64(seed ^ splitmix64(req.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ pos)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn beta_in_unit_interval() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let x = r.beta(2.0, 5.0);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.03, "f2={f2}");
    }

    #[test]
    fn sample_logits_greedy_when_temp_zero() {
        let mut r = Rng::new(1);
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        assert_eq!(sample_logits(&logits, 0.0, &mut r), 1);
    }

    #[test]
    fn sample_logits_categorical_frequency() {
        // logits [0, ln 9] at T=1 → probabilities [0.1, 0.9].
        let logits = vec![0.0f32, (9f32).ln()];
        let mut hits = 0usize;
        for i in 0..20_000u64 {
            let mut r = position_rng(5, 1, i);
            if sample_logits(&logits, 1.0, &mut r) == 1 {
                hits += 1;
            }
        }
        let f = hits as f64 / 20_000.0;
        assert!((f - 0.9).abs() < 0.01, "f={f}");
    }

    #[test]
    fn position_rng_reproducible() {
        let mut a = position_rng(1, 2, 3);
        let mut b = position_rng(1, 2, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = position_rng(1, 2, 4);
        let _ = c; // different pos → different stream (spot check)
    }
}
