//! Descriptive statistics, histograms and least-squares fitting.
//!
//! Used by the benchmark harness (percentile reporting), the planner's
//! affine cost-model fitting (§4.1: D(b) = b·D' + α, V_w(b) = b·V' + β),
//! and the simulator's report generation.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let idx = q / 100.0 * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = idx - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Ordinary least squares fit of y = a·x + b.
/// Returns (slope a, intercept b, r²).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linfit needs >= 2 points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let intercept = my - slope * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (slope, intercept, r2)
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let frac = (x - self.lo) / (self.hi - self.lo);
        let idx = ((frac * n as f64) as isize).clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bin midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64) as u64;
        let mut seen = 0u64;
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &c) in self.bins.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }

    /// Render an ASCII sparkline of bin densities (for report output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

/// Streaming mean/var accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (a, _, r2) = linfit(&xs, &ys);
        assert!((a - 2.0).abs() < 0.05);
        assert!(r2 < 1.0);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 10.0);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 4.95).abs() < 0.01);
        let med = h.quantile(0.5);
        assert!((3.0..=7.0).contains(&med), "median {med}");
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.bins[3], 1);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - stddev(&xs)).abs() < 1e-12);
    }
}
