//! Descriptive statistics, histograms and least-squares fitting.
//!
//! Used by the benchmark harness (percentile reporting), the planner's
//! affine cost-model fitting (§4.1: D(b) = b·D' + α, V_w(b) = b·V' + β),
//! and the simulator's report generation.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let idx = q / 100.0 * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = idx - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Ordinary least squares fit of y = a·x + b.
/// Returns (slope a, intercept b, r²).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linfit needs >= 2 points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let intercept = my - slope * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (slope, intercept, r2)
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let frac = (x - self.lo) / (self.hi - self.lo);
        let idx = ((frac * n as f64) as isize).clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bin midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64) as u64;
        let mut seen = 0u64;
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &c) in self.bins.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }

    /// Render an ASCII sparkline of bin densities (for report output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

/// Streaming quantile estimator with fixed O(1) state: the P² algorithm
/// (Jain & Chlamtáč 1985). Five markers track (min, q/2, q, (1+q)/2, max)
/// positions; each observation adjusts the middle markers by a parabolic
/// (falling back to linear) interpolation, so no sample buffer is kept.
/// `serve/metrics.rs` uses one per tracked latency quantile — a serving
/// loop cannot afford an unbounded sample vector per percentile.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    /// Observations seen. Below 5 the estimator falls back to the exact
    /// percentile of the stored prefix.
    n: u64,
    heights: [f64; 5],
    pos: [f64; 5],
    desired: [f64; 5],
    incr: [f64; 5],
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0, 1]");
        P2Quantile {
            q,
            n: 0,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            incr: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn add(&mut self, x: f64) {
        if self.n < 5 {
            self.heights[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.heights.sort_by(|a, b| a.total_cmp(b));
            }
            return;
        }
        // Locate the cell k with heights[k] <= x < heights[k+1], extending
        // the extreme markers when x falls outside them.
        let h = &mut self.heights;
        let k = if x < h[0] {
            h[0] = x;
            0
        } else if x >= h[4] {
            h[4] = x;
            3
        } else {
            // x in [h[0], h[4]): find the first marker above it.
            let mut k = 0;
            for i in 1..4 {
                if x >= h[i] {
                    k = i;
                }
            }
            k
        };
        for p in self.pos[k + 1..].iter_mut() {
            *p += 1.0;
        }
        for (d, inc) in self.desired.iter_mut().zip(self.incr) {
            *d += inc;
        }
        // Adjust the three interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let right = self.pos[i + 1] - self.pos[i];
            let left = self.pos[i - 1] - self.pos[i];
            if (d >= 1.0 && right > 1.0) || (d <= -1.0 && left < -1.0) {
                let s = if d >= 1.0 { 1.0 } else { -1.0 };
                let hp = self.parabolic(i, s);
                self.heights[i] = if self.heights[i - 1] < hp && hp < self.heights[i + 1] {
                    hp
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
        self.n += 1;
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (h, p) = (&self.heights, &self.pos);
        h[i] + s / (p[i + 1] - p[i - 1])
            * ((p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
                + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + s * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current quantile estimate (0.0 before any observation).
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n < 5 {
            let mut v = self.heights[..self.n as usize].to_vec();
            v.sort_by(|a, b| a.total_cmp(b));
            return percentile_sorted(&v, self.q * 100.0);
        }
        self.heights[2]
    }
}

/// Streaming mean/var accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (a, _, r2) = linfit(&xs, &ys);
        assert!((a - 2.0).abs() < 0.05);
        assert!(r2 < 1.0);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 10.0);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 4.95).abs() < 0.01);
        let med = h.quantile(0.5);
        assert!((3.0..=7.0).contains(&med), "median {med}");
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.bins[3], 1);
    }

    #[test]
    fn p2_small_streams_are_exact() {
        let mut p = P2Quantile::new(0.5);
        assert_eq!(p.value(), 0.0);
        for x in [3.0, 1.0, 2.0] {
            p.add(x);
        }
        assert!((p.value() - 2.0).abs() < 1e-12);
        assert_eq!(p.count(), 3);
    }

    #[test]
    fn p2_median_of_uniform() {
        let mut rng = crate::util::Rng::new(5);
        let mut p = P2Quantile::new(0.5);
        for _ in 0..20_000 {
            p.add(rng.next_f64());
        }
        assert!((p.value() - 0.5).abs() < 0.02, "p50 = {}", p.value());
    }

    #[test]
    fn p2_tail_quantile_tracks_exact() {
        // heavy-tailed stream: p99 estimate within 15% of the exact value
        let mut rng = crate::util::Rng::new(11);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.lognormal(0.0, 1.0)).collect();
        let mut p = P2Quantile::new(0.99);
        for &x in &xs {
            p.add(x);
        }
        let exact = percentile(&xs, 99.0);
        let rel = (p.value() - exact).abs() / exact;
        assert!(rel < 0.15, "p99 est {} vs exact {exact}", p.value());
    }

    #[test]
    fn p2_constant_stream() {
        let mut p = P2Quantile::new(0.9);
        for _ in 0..100 {
            p.add(4.25);
        }
        assert_eq!(p.value(), 4.25);
    }

    #[test]
    fn p2_quantiles_ordered() {
        let mut rng = crate::util::Rng::new(23);
        let mut p50 = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        for _ in 0..5_000 {
            let x = rng.lognormal(1.0, 0.8);
            p50.add(x);
            p99.add(x);
        }
        assert!(p99.value() > p50.value());
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - stddev(&xs)).abs() < 1e-12);
    }
}
