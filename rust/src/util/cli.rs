//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Each binary declares its options by querying the parsed bag; unknown
//! options are an error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--": everything after is positional
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn opt(&mut self, key: &str, default: &str) -> String {
        self.known.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_maybe(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// Typed option with default; panics with a clear message on bad parse.
    pub fn opt_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> T {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean flag (present or not).
    pub fn flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list of T.
    pub fn opt_list<T: std::str::FromStr>(&mut self, key: &str, default: &str) -> Vec<T> {
        let raw = self.opt(key, default);
        raw.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{key}: cannot parse element {s:?}"))
            })
            .collect()
    }

    /// Call after all opt/flag queries: errors on unrecognised options.
    /// `--bench` is always accepted (cargo bench passes it to
    /// harness = false targets).
    pub fn finish(&self) -> Result<(), String> {
        for k in self.opts.keys() {
            if !self.known.contains(k) && k != "bench" {
                return Err(format!("unknown option --{k}"));
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) && f != "bench" {
                return Err(format!("unknown flag --{f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_kinds() {
        // note: a bare `--flag tok` would consume `tok` as its value, so
        // flags go last (documented semantics).
        let mut a = parse(&["run", "x", "--n", "5", "--mode=fast", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "x"]);
        assert_eq!(a.opt_parse("n", 0usize), 5);
        assert_eq!(a.opt("mode", "slow"), "fast");
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse(&[]);
        assert_eq!(a.opt_parse("n", 7u32), 7);
        assert_eq!(a.opt("mode", "slow"), "slow");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse(&["--bogus", "1"]);
        let _ = a.opt("known", "");
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_option() {
        let mut a = parse(&["--sizes", "1,2,8"]);
        let v: Vec<usize> = a.opt_list("sizes", "");
        assert_eq!(v, vec![1, 2, 8]);
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
