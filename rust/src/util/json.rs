//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Used for `artifacts/manifest.json` (the python→rust contract), config
//! files, and machine-readable bench reports. Supports the full JSON value
//! model; numbers are kept as f64 (manifest only uses small integers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Builder helpers for report writing.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON manifest never emits them,
                            // but handle the basic plane correctly.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("invalid utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
