//! Speculation core: exact-match verification, draft-window bookkeeping and
//! waste accounting.
//!
//! This module is pure logic (no runtime dependency) so the same code is
//! used by the real engine (`engine/`), the coordinator, and the cluster
//! simulator (`sim/`) — and can be property-tested exhaustively.
//!
//! Losslessness: the target's token at sequence position `p` of request `r`
//! is always sampled from the tape stream `position_rng(seed, r, p)`
//! regardless of whether the engine is decoding vanilla, verifying coupled
//! or verifying decoupled. Exact-match acceptance then guarantees the final
//! sequence is identical to vanilla decoding token-for-token (tested in
//! `tests` below and end-to-end in `rust/tests/losslessness.rs`).

pub mod window;

pub use window::DraftWindow;

use crate::util::rng::{position_rng, sample_logits};

/// Outcome of verifying one request's draft chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyOutcome {
    /// Number of draft tokens accepted (prefix of the chunk).
    pub accepted: usize,
    /// Tokens to append to the sequence: accepted drafts plus either the
    /// correction (on mismatch) or the bonus token (on full accept).
    pub append: Vec<i32>,
    /// Draft tokens wasted by this verification (rejected suffix).
    pub wasted: usize,
    /// True if every draft token was accepted.
    pub full_accept: bool,
}

/// Exact-match verification of `drafts` for request `req`.
///
/// `logits(j)` must return the target-model logits after consuming input
/// position `j` of the verify window, where the window inputs are
/// `[last_accepted, drafts[0], ..., drafts[w-2]]` — i.e. `logits(j)` is the
/// distribution for sequence position `seq_len + j`.
///
/// The closure returns a *borrowed* slice (typically straight out of a
/// [`StepOut`]'s logits buffer) so verification copies nothing — the
/// engines call this once per slot per round on the hot path.
///
/// `seq_len` is the request's current sequence length (prompt + accepted),
/// so the token being sampled at window offset `j` has tape position
/// `seq_len + j`.
///
/// [`StepOut`]: crate::runtime::StepOut
pub fn verify_exact<'l, F>(
    req: u64,
    seed: u64,
    temp: f32,
    seq_len: usize,
    drafts: &[i32],
    mut logits: F,
) -> VerifyOutcome
where
    F: FnMut(usize) -> &'l [f32],
{
    let w = drafts.len();
    let mut append = Vec::with_capacity(w + 1);
    for (j, &d) in drafts.iter().enumerate() {
        let lg = logits(j);
        let mut rng = position_rng(seed, req, (seq_len + j) as u64);
        let t = sample_logits(lg, temp, &mut rng) as i32;
        if t == d {
            append.push(d);
        } else {
            // Mismatch: the target's own sample is the correct token.
            append.push(t);
            return VerifyOutcome {
                accepted: j,
                append,
                wasted: w - j,
                full_accept: false,
            };
        }
    }
    // Full accept: bonus token from the last position's logits.
    let lg = logits(w);
    let mut rng = position_rng(seed, req, (seq_len + w) as u64);
    let bonus = sample_logits(lg, temp, &mut rng) as i32;
    append.push(bonus);
    VerifyOutcome { accepted: w, append, wasted: 0, full_accept: true }
}

/// Vanilla decode of one token (the `w = 0` case) — sample sequence
/// position `seq_len` from the tape.
pub fn decode_one(req: u64, seed: u64, temp: f32, seq_len: usize, logits: &[f32]) -> i32 {
    let mut rng = position_rng(seed, req, seq_len as u64);
    sample_logits(logits, temp, &mut rng) as i32
}

/// Running acceptance-rate estimate for a request (used by Algorithm 2's
/// reconfiguration and by the FoN assignment ordering).
#[derive(Clone, Debug)]
pub struct AcceptanceStats {
    pub proposed: u64,
    pub accepted: u64,
    /// Exponentially-weighted recent acceptance rate.
    pub ewma: f64,
    alpha: f64,
}

impl Default for AcceptanceStats {
    fn default() -> Self {
        AcceptanceStats { proposed: 0, accepted: 0, ewma: 0.8, alpha: 0.2 }
    }
}

impl AcceptanceStats {
    /// Rebuild stats from a serialized ledger (cross-worker migration:
    /// `runtime::transport` round-trips the three public fields; the
    /// smoothing factor is a constant, not request state).
    pub fn from_ledger(proposed: u64, accepted: u64, ewma: f64) -> Self {
        AcceptanceStats { proposed, accepted, ewma, ..Default::default() }
    }

    pub fn observe(&mut self, proposed: usize, accepted: usize) {
        self.proposed += proposed as u64;
        self.accepted += accepted as u64;
        if proposed > 0 {
            let r = accepted as f64 / proposed as f64;
            self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * r;
        }
    }

    /// Lifetime acceptance rate.
    pub fn rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    /// Synthetic target: position p of request r deterministically prefers
    /// token `(p * 7 + r) % V` with huge margin.
    fn synth_logits(req: u64, pos: usize, vocab: usize) -> Vec<f32> {
        let mut lg = vec![0.0f32; vocab];
        lg[(pos * 7 + req as usize) % vocab] = 50.0;
        lg
    }

    /// Precomputed logits rows for window offsets `0..=w` (the borrowed
    /// closure contract mirrors how engines lend `StepOut` rows).
    fn synth_rows(req: u64, seq_len: usize, w: usize, vocab: usize) -> Vec<Vec<f32>> {
        (0..=w).map(|j| synth_logits(req, seq_len + j, vocab)).collect()
    }

    #[test]
    fn all_accept_with_perfect_drafts() {
        let vocab = 64;
        let seq_len = 10;
        let drafts: Vec<i32> = (0..4).map(|j| ((seq_len + j) * 7) as i32 % vocab as i32).collect();
        let rows = synth_rows(0, seq_len, 4, vocab);
        let out = verify_exact(0, 1, 1.0, seq_len, &drafts, |j| rows[j].as_slice());
        assert!(out.full_accept);
        assert_eq!(out.accepted, 4);
        assert_eq!(out.append.len(), 5); // 4 drafts + bonus
        assert_eq!(out.wasted, 0);
        // bonus is the target's own choice for the next position
        assert_eq!(out.append[4], ((seq_len + 4) * 7) as i32 % vocab as i32);
    }

    #[test]
    fn rejects_at_first_mismatch() {
        let vocab = 64;
        let seq_len = 3;
        let req = 5u64;
        let mut drafts: Vec<i32> = (0..4)
            .map(|j| ((seq_len + j) * 7 + req as usize) as i32 % vocab as i32)
            .collect();
        drafts[2] = (drafts[2] + 1) % vocab as i32; // corrupt 3rd draft
        let rows = synth_rows(req, seq_len, 4, vocab);
        let out = verify_exact(req, 1, 1.0, seq_len, &drafts, |j| rows[j].as_slice());
        assert!(!out.full_accept);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.wasted, 2);
        assert_eq!(out.append.len(), 3); // 2 accepted + correction
        // correction equals the target's sample at that position — which is
        // the uncorrupted draft value
        assert_eq!(out.append[2], ((seq_len + 2) * 7 + 5) as i32 % vocab as i32);
        assert_ne!(out.append[2], drafts[2]);
    }

    #[test]
    fn losslessness_spec_equals_vanilla() {
        // Roll a full synthetic generation twice: once token-by-token,
        // once with (sometimes wrong) speculative chunks. The final
        // sequences must be identical.
        let vocab = 32;
        let seed = 9;
        let req = 3;
        let horizon = 40;

        // vanilla
        let mut vanilla = vec![4i32];
        while vanilla.len() < horizon {
            let lg = synth_logits(req, vanilla.len(), vocab);
            let t = decode_one(req, seed, 1.0, vanilla.len(), &lg);
            vanilla.push(t);
        }

        // speculative with a drafter that is right 70% of the time
        let mut spec = vec![4i32];
        let mut flip = crate::util::Rng::new(123);
        while spec.len() < horizon {
            let w = 4.min(horizon - spec.len());
            let drafts: Vec<i32> = (0..w)
                .map(|j| {
                    let correct = ((spec.len() + j) * 7 + req as usize) as i32 % vocab as i32;
                    if flip.bernoulli(0.7) {
                        correct
                    } else {
                        (correct + 1) % vocab as i32
                    }
                })
                .collect();
            let base = spec.len();
            let rows = synth_rows(req, base, w, vocab);
            let out = verify_exact(req, seed, 1.0, base, &drafts, |j| rows[j].as_slice());
            spec.extend_from_slice(&out.append);
        }
        spec.truncate(horizon);
        assert_eq!(spec, vanilla, "speculative output diverged from vanilla");
    }

    #[test]
    fn prop_accepted_prefix_matches_drafts() {
        check("verify-prefix", 200, |g| {
            let vocab = 16 + g.usize_in(0, 48);
            let w = 1 + g.usize_in(0, 8);
            let seq_len = g.usize_in(0, 100);
            let req = g.usize_in(0, 10) as u64;
            let drafts: Vec<i32> =
                (0..w).map(|_| g.usize_in(0, vocab) as i32).collect();
            let rows = synth_rows(req, seq_len, w, vocab);
            let out = verify_exact(req, 7, 1.0, seq_len, &drafts, |j| rows[j].as_slice());
            prop_assert!(out.accepted <= w, "accepted {} > w {}", out.accepted, w);
            prop_assert!(
                out.append.len() == out.accepted + 1,
                "append {} != accepted+1 {}",
                out.append.len(),
                out.accepted + 1
            );
            prop_assert!(
                out.wasted == w - out.accepted,
                "waste accounting broken"
            );
            prop_assert!(
                out.append[..out.accepted] == drafts[..out.accepted],
                "accepted prefix differs from drafts"
            );
            Ok(())
        });
    }

    #[test]
    fn acceptance_stats_tracks() {
        let mut s = AcceptanceStats::default();
        s.observe(4, 4);
        s.observe(4, 0);
        assert!((s.rate() - 0.5).abs() < 1e-12);
        assert!(s.ewma < 0.8);
    }
}
