//! Draft-window bookkeeping for decoupled speculation (§4.1).
//!
//! The drafter may run ahead of verification, bounded by the window `w`:
//! once `w` tokens are in flight (sent for verification), it may draft at
//! most another `w` before it must stall for feedback. Under a speculation
//! failure at the first in-flight position, everything drafted after it is
//! discarded — at most `2w − 1` tokens (Figure 9), an invariant the tests
//! check by construction.

/// State machine tracking one request's in-flight draft tokens.
#[derive(Clone, Debug)]
pub struct DraftWindow {
    /// Window size `w` (reconfigured online by Algorithm 2).
    pub w: usize,
    /// Coupled mode: the drafter stalls until each verification returns.
    pub coupled: bool,
    /// Tokens drafted and sent to the verifier, not yet resolved.
    in_flight: usize,
    /// Tokens drafted beyond the in-flight chunk (aggressive drafting).
    ahead: usize,
    /// Cumulative waste (rejected drafted tokens).
    pub wasted_tokens: u64,
    /// Cumulative drafted tokens.
    pub drafted_tokens: u64,
}

impl DraftWindow {
    pub fn new(w: usize, coupled: bool) -> Self {
        assert!(w >= 1);
        DraftWindow { w, coupled, in_flight: 0, ahead: 0, wasted_tokens: 0, drafted_tokens: 0 }
    }

    /// How many tokens the drafter may draft right now.
    pub fn draft_budget(&self) -> usize {
        if self.coupled {
            // coupled: draft only when nothing is in flight
            if self.in_flight == 0 {
                self.w
            } else {
                0
            }
        } else {
            // decoupled: one chunk in flight plus one chunk ahead
            let cap = if self.in_flight == 0 { self.w } else { self.w.saturating_sub(self.ahead) };
            cap
        }
    }

    /// Record `n` tokens drafted (n <= draft_budget()).
    pub fn on_drafted(&mut self, n: usize) {
        assert!(n <= self.draft_budget(), "drafted {n} > budget {}", self.draft_budget());
        self.drafted_tokens += n as u64;
        if self.in_flight == 0 {
            self.in_flight += n;
        } else {
            self.ahead += n;
        }
    }

    /// The verifier picked up the in-flight chunk and returned a verdict:
    /// `accepted` of the chunk's tokens were accepted (`full` = all).
    /// The `ahead` tokens move in flight if the chunk fully accepted, else
    /// they are waste.
    pub fn on_verified(&mut self, accepted: usize, full: bool) {
        debug_assert!(accepted <= self.in_flight);
        if full || accepted == self.in_flight {
            self.in_flight = self.ahead;
            self.ahead = 0;
        } else {
            // Mis-speculation: the rejected slot itself becomes the
            // verifier's correction (not waste, per Figure 9's accounting);
            // everything after it — the rest of the chunk and all `ahead`
            // tokens — is garbage. Worst case (rejection at slot 1 with a
            // full chunk ahead): (w − 1) + w = 2w − 1.
            self.wasted_tokens +=
                (self.in_flight - accepted - 1) as u64 + self.ahead as u64;
            self.in_flight = 0;
            self.ahead = 0;
        }
    }

    /// Upper bound on waste from a single failure: `2w − 1` (Figure 9).
    pub fn max_failure_waste(&self) -> usize {
        2 * self.w - 1
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn ahead(&self) -> usize {
        self.ahead
    }

    /// Switch mode / resize (Algorithm 2 reconfiguration).
    pub fn reconfigure(&mut self, w: usize, coupled: bool) {
        assert!(w >= 1);
        self.w = w;
        self.coupled = coupled;
        // In-flight tokens stay; ahead tokens beyond the new window are
        // clipped by future draft_budget() calls, not discarded here.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn coupled_blocks_until_verified() {
        let mut dw = DraftWindow::new(4, true);
        assert_eq!(dw.draft_budget(), 4);
        dw.on_drafted(4);
        assert_eq!(dw.draft_budget(), 0);
        dw.on_verified(4, true);
        // full accept moved ahead (0) into flight; nothing in flight now
        assert_eq!(dw.draft_budget(), 4);
    }

    #[test]
    fn decoupled_allows_one_chunk_ahead() {
        let mut dw = DraftWindow::new(3, false);
        dw.on_drafted(3); // in flight
        assert_eq!(dw.draft_budget(), 3); // can go ahead
        dw.on_drafted(3);
        assert_eq!(dw.draft_budget(), 0); // 2w in the pipe → stall
    }

    #[test]
    fn failure_wastes_at_most_2w_minus_1() {
        let mut dw = DraftWindow::new(4, false);
        dw.on_drafted(4);
        dw.on_drafted(4); // maximally ahead
        // worst case: first in-flight token rejected (slot 1 becomes the
        // correction; 3 in-flight + 4 ahead wasted = 2w - 1)
        dw.on_verified(0, false);
        assert_eq!(dw.wasted_tokens as usize, (4 - 1) + 4);
        assert!(dw.wasted_tokens as usize <= dw.max_failure_waste());
    }

    #[test]
    fn full_accept_promotes_ahead_chunk() {
        let mut dw = DraftWindow::new(2, false);
        dw.on_drafted(2);
        dw.on_drafted(2);
        dw.on_verified(2, true);
        assert_eq!(dw.in_flight(), 2);
        assert_eq!(dw.ahead(), 0);
        assert_eq!(dw.wasted_tokens, 0);
    }

    #[test]
    fn prop_waste_bounded_per_failure() {
        check("window-waste-bound", 300, |g| {
            let w = 1 + g.usize_in(0, 8);
            let coupled = g.bool();
            let mut dw = DraftWindow::new(w, coupled);
            let mut waste_before = 0u64;
            for _ in 0..30 {
                let budget = dw.draft_budget();
                if budget > 0 && g.bool() {
                    let n = 1 + g.usize_in(0, budget);
                    dw.on_drafted(n);
                }
                if dw.in_flight() > 0 && g.bool() {
                    let fl = dw.in_flight();
                    let acc = g.usize_in(0, fl + 1);
                    let full = acc == fl;
                    dw.on_verified(acc, full);
                    let delta = dw.wasted_tokens - waste_before;
                    prop_assert!(
                        delta as usize <= dw.max_failure_waste(),
                        "single verification wasted {delta} > 2w-1 = {}",
                        dw.max_failure_waste()
                    );
                    waste_before = dw.wasted_tokens;
                }
            }
            prop_assert!(
                dw.wasted_tokens <= dw.drafted_tokens,
                "wasted {} > drafted {}",
                dw.wasted_tokens,
                dw.drafted_tokens
            );
            Ok(())
        });
    }

    #[test]
    fn reconfigure_changes_mode() {
        let mut dw = DraftWindow::new(4, false);
        dw.on_drafted(4);
        dw.reconfigure(2, true);
        assert!(dw.coupled);
        assert_eq!(dw.w, 2);
        assert_eq!(dw.draft_budget(), 0); // coupled with chunk in flight
        dw.on_verified(4, true);
        assert_eq!(dw.draft_budget(), 2);
    }
}
