//! Affine execution-cost model (§4.1):
//!
//! ```text
//! D_{g_d}(b)   = b · D'_{g_d}   + α_{g_d}      (draft one token, batch b)
//! V_{g_v,w}(b) = b · V'_{g_v,w} + β_{g_v,w}    (verify a w-window, batch b)
//! ```
//!
//! Coefficients come from offline profiling (the paper fits them the same
//! way, citing [82, 12]). Two sources are supported: (1) the calibrated
//! defaults below, anchored to the paper's quoted numbers for Qwen2.5-32B
//! on Hopper (13 ms decode at b = 1; 1.4× latency from b 128→256; see
//! DESIGN.md §2), and (2) [`AffineCost::fit`] over measured (b, t) points
//! from the real runtime (`specactor fit`).

use crate::util::stats::linfit;

/// t(b) = slope · b + intercept, in seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AffineCost {
    pub slope: f64,
    pub intercept: f64,
}

impl AffineCost {
    pub fn new(slope: f64, intercept: f64) -> Self {
        AffineCost { slope, intercept }
    }

    pub fn eval(&self, b: usize) -> f64 {
        self.slope * b as f64 + self.intercept
    }

    /// Least-squares fit from (batch, seconds) measurements.
    pub fn fit(points: &[(usize, f64)]) -> (AffineCost, f64) {
        let xs: Vec<f64> = points.iter().map(|(b, _)| *b as f64).collect();
        let ys: Vec<f64> = points.iter().map(|(_, t)| *t).collect();
        let (slope, intercept, r2) = linfit(&xs, &ys);
        (AffineCost { slope: slope.max(0.0), intercept: intercept.max(0.0) }, r2)
    }
}

/// Per-token cost of the suffix-automaton drafter relative to the n-gram
/// drafter: automaton transition walks touch more state than a flat
/// gram-table probe, but stay in the same near-free CPU-lookup family.
/// Used by [`CostModel::install_sam_curve`].
pub const SAM_NGRAM_COST_RATIO: f64 = 1.25;

/// Relative compute scale of a draft method (vs the target model).
#[derive(Clone, Debug)]
pub struct DraftCost {
    /// Method label ("draft_small", "draft_mid", "ngram", "sam", ...).
    pub method: String,
    /// Cost of drafting ONE token at batch b on `g_d` GPUs.
    pub per_token: AffineCost,
}

/// Cluster-level cost model for one target model.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Verify-window cost: slope/intercept for `w = 1` on the *reference*
    /// GPU config (the trace's TP degree).
    pub verify1: AffineCost,
    /// Extra per-token slope factor per additional window position:
    /// `V'_w = V'_1 · (1 + w_scale · (w − 1))`. Near 1.0 when verification
    /// is compute-bound (large batch), the regime of Figure 6.
    pub w_scale: f64,
    /// Window-independent part of β growth with w (kernel launch etc.).
    pub beta_w: f64,
    /// Fraction of a REAL window position's marginal per-row slope that a
    /// PADDED position of a fused ragged verify step still costs: the
    /// position rides the lowered executable's dense compute, but its KV
    /// scatter and logits reads are skipped host-side and its output is
    /// never consumed. Used by [`CostModel::verify_fused`].
    pub pad_waste: f64,
    /// Control-plane cost (seconds) of forking one Fastest-of-N racing
    /// replica: the verified-prefix KV row copy through the
    /// `extract_row`/`insert_row` migration path plus drafter-state
    /// rebuild — no prefill, so it is far below an admission's cost. Used
    /// by the race launch gate ([`race_gain`]).
    ///
    /// [`race_gain`]: crate::coordinator::race::race_gain
    pub fork_cost: f64,
    /// Fraction of a round's serialized in-round draft time hidden by
    /// the overlapped execution path (draft prefetch behind the fused
    /// verify step's submit/await window; `EngineConfig::overlap`).
    /// 0.0 = sequential engine (the default and the A/B baseline); the
    /// serve loop sets it when serving with `--overlap`. Consumed by
    /// the FUSED iteration-latency functions in `planner::tgs` —
    /// `il_*_fused` price the draft term at `(1 − overlap_eff)` — so
    /// eff = 0 reproduces the sequential formulas exactly.
    pub overlap_eff: f64,
    /// Parallel-efficiency exponent for scaling the verifier across GPU
    /// configs: slope(g) = slope_ref · (g_ref / g)^eff.
    pub tp_eff: f64,
    /// Reference GPU count per verifier (trace TP degree).
    pub g_ref: usize,
    /// Draft methods available (the ladder pool).
    pub drafts: Vec<DraftCost>,
}

impl CostModel {
    /// Calibrated to the paper's Qwen2.5-32B numbers (see module docs):
    /// V' ≈ 67.4 µs/req, β ≈ 12.93 ms at TP4.
    pub fn paper_32b() -> CostModel {
        let vp = 13.0e-3 / 193.0; // V' from t(1)=13ms and β=192·V'
        let beta = 192.0 * vp;
        CostModel {
            verify1: AffineCost::new(vp, beta),
            w_scale: 0.30,
            beta_w: 0.1e-3,
            pad_waste: 0.6,
            fork_cost: 1.0e-3,
            overlap_eff: 0.0,
            tp_eff: 0.85,
            g_ref: 4,
            drafts: vec![
                // 0.5B: compute is ~64× smaller, but batched drafting is
                // memory-bound and GPU-underutilized (§3): its per-request
                // slope is close to the target's while its intercept is
                // small. This is exactly the Fig 5b/6b anchor — serial
                // draft+verify turns *negative* at per-worker batch ≈ 128,
                // and hiding the draft path is what decoupling buys.
                DraftCost {
                    method: "draft_small".into(),
                    per_token: AffineCost::new(vp / 1.6, beta / 6.0),
                },
                // 1.5B: better acceptance, slower drafting
                DraftCost {
                    method: "draft_mid".into(),
                    per_token: AffineCost::new(vp / 1.3, beta / 4.5),
                },
                // n-gram: CPU-side lookup, near-zero cost
                DraftCost {
                    method: "ngram".into(),
                    per_token: AffineCost::new(vp / 400.0, beta / 400.0),
                },
            ],
        }
    }

    /// MoE variant (§5.3): expert communication inflates verification,
    /// especially its batch slope [26].
    pub fn paper_235b_moe() -> CostModel {
        let mut m = CostModel::paper_32b();
        m.verify1.slope *= 3.0;
        m.verify1.intercept *= 1.8;
        m.w_scale = 0.45;
        m.g_ref = 8; // EP8
        m.drafts = vec![
            DraftCost {
                method: "draft_4b".into(),
                per_token: AffineCost::new(m.verify1.slope / 1.1, m.verify1.intercept / 4.0),
            },
            DraftCost {
                method: "draft_1.7b".into(),
                per_token: AffineCost::new(m.verify1.slope / 1.5, m.verify1.intercept / 6.0),
            },
            DraftCost {
                method: "draft_0.6b".into(),
                per_token: AffineCost::new(m.verify1.slope / 2.0, m.verify1.intercept / 8.0),
            },
            DraftCost {
                method: "ngram".into(),
                per_token: AffineCost::new(m.verify1.slope / 400.0, m.verify1.intercept / 400.0),
            },
        ];
        m
    }

    /// Price plans for the overlapped engine: `eff` of the serialized
    /// in-round draft time is hidden behind the fused verify step (see
    /// [`CostModel::overlap_eff`]). Clamped to [0, 1].
    pub fn with_overlap_eff(mut self, eff: f64) -> CostModel {
        self.overlap_eff = eff.clamp(0.0, 1.0);
        self
    }

    /// Verification cost of a `w`-token window at batch `b` on `g_v` GPUs.
    pub fn verify(&self, g_v: usize, w: usize, b: usize) -> f64 {
        self.verify_f(g_v, w as f64, b)
    }

    /// Fractional-window variant: a batch with mixed per-request windows
    /// (Algorithm 2's fused scheduling) loads the verifier with the
    /// *average* window, not the max.
    pub fn verify_f(&self, g_v: usize, w: f64, b: usize) -> f64 {
        let w1 = (w - 1.0).max(0.0);
        let scale = (self.g_ref as f64 / g_v as f64).powf(self.tp_eff);
        let slope = self.verify1.slope * (1.0 + self.w_scale * w1) * scale;
        let beta = self.verify1.intercept * scale.clamp(1.0, 1.2) + self.beta_w * w1;
        slope * b as f64 + beta
    }

    /// Cost of ONE fused ragged verify step — the engine's actual
    /// discipline: rows with mean real window `w_mean` are padded up to
    /// the lowered step window `w_step` they all share. The real load is
    /// the paper's average-window fused verify ([`CostModel::verify_f`],
    /// β paid exactly once); each padded position adds [`pad_waste`] of a
    /// real position's marginal per-row slope. `w_mean == w_step` (no
    /// padding) degenerates to `verify_f` exactly.
    ///
    /// [`pad_waste`]: CostModel::pad_waste
    pub fn verify_fused(&self, g_v: usize, w_mean: f64, w_step: usize, b: usize) -> f64 {
        let scale = (self.g_ref as f64 / g_v as f64).powf(self.tp_eff);
        let pad = (w_step as f64 - w_mean).max(0.0);
        self.verify_f(g_v, w_mean, b)
            + self.pad_waste * self.w_scale * self.verify1.slope * scale * pad * b as f64
    }

    /// Marginal cost of ONE extra racing-replica row riding every fused
    /// verify step: the batch-slope increment of the fused step at
    /// `b → b + 1` (β is already paid — a replica never adds an
    /// intercept, which is precisely why Fastest-of-N racing on freed
    /// capacity is cheap under the fused discipline).
    pub fn replica_overhead(&self, g_v: usize, w_mean: f64, w_step: usize, b: usize) -> f64 {
        self.verify_fused(g_v, w_mean, w_step, b + 1) - self.verify_fused(g_v, w_mean, w_step, b)
    }

    /// Decode (generation) cost of one token at batch `b` on the reference
    /// config — i.e. vanilla rollout's per-iteration latency.
    pub fn decode(&self, b: usize) -> f64 {
        self.verify(self.g_ref, 1, b)
    }

    /// Draft cost of ONE token at batch `b` for `method`.
    pub fn draft(&self, method: &str, b: usize) -> f64 {
        self.draft_cost(method).per_token.eval(b)
    }

    /// Give the suffix-automaton drafter its OWN cost key. Until live
    /// evidence arrives sam has no profiled curve and [`draft_cost`]
    /// borrows n-gram's; once the serve loop has measured per-method
    /// acceptance for sam ([`Reconfigurator::feed_measured`]) it installs
    /// a dedicated "sam" curve — the n-gram curve scaled by
    /// [`SAM_NGRAM_COST_RATIO`] (automaton transitions walk a larger
    /// state machine than a flat gram-table probe, same CPU-lookup
    /// family) — so `cost_method` stops falling back and Algorithm 2
    /// prices sam windows against sam's own curve. Idempotent.
    ///
    /// [`draft_cost`]: CostModel::draft_cost
    /// [`Reconfigurator::feed_measured`]: crate::coordinator::reconfig::Reconfigurator::feed_measured
    pub fn install_sam_curve(&mut self) -> bool {
        if self.drafts.iter().any(|d| d.method == "sam") {
            return false;
        }
        let Some(ng) = self.drafts.iter().find(|d| d.method == "ngram") else {
            return false;
        };
        let per_token = AffineCost::new(
            ng.per_token.slope * SAM_NGRAM_COST_RATIO,
            ng.per_token.intercept * SAM_NGRAM_COST_RATIO,
        );
        self.drafts.push(DraftCost { method: "sam".into(), per_token });
        true
    }

    /// Cost curve for `method`. The suffix-automaton drafter has no
    /// profiled curve of its own until [`CostModel::install_sam_curve`]
    /// runs and borrows n-gram's — same CPU token-lookup family,
    /// piggybacked on the worker — so ladders and replanners can be
    /// pinned to "sam" directly. Unknown MODEL drafter names stay a loud
    /// error: their real cost is orders of magnitude above any token
    /// drafter's, and pricing them as near-free lookups would silently
    /// mis-plan. ([`CostModel::methods`] enumerates only explicitly
    /// profiled curves.)
    pub fn draft_cost(&self, method: &str) -> &DraftCost {
        if let Some(d) = self.drafts.iter().find(|d| d.method == method) {
            return d;
        }
        if method == "sam" {
            if let Some(d) = self.drafts.iter().find(|d| d.method == "ngram") {
                return d;
            }
        }
        panic!("unknown draft method {method:?}")
    }

    pub fn methods(&self) -> Vec<String> {
        self.drafts.iter().map(|d| d.method.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchors_hold() {
        let m = CostModel::paper_32b();
        // 13 ms decode at b=1
        let t1 = m.decode(1);
        assert!((t1 - 13.0e-3).abs() < 0.5e-3, "t(1) = {t1}");
        // 1.4x latency from b=128 -> 256
        let r = m.decode(256) / m.decode(128);
        assert!((r - 1.4).abs() < 0.05, "128->256 ratio {r}");
    }

    #[test]
    fn verify_grows_with_window_and_batch() {
        let m = CostModel::paper_32b();
        assert!(m.verify(4, 4, 128) > m.verify(4, 1, 128));
        assert!(m.verify(4, 4, 256) > m.verify(4, 4, 64));
        // verification of w=4 at large batch is much worse than at b=1
        let small = m.verify(4, 4, 1) / m.verify(4, 1, 1);
        let large = m.verify(4, 4, 256) / m.verify(4, 1, 256);
        assert!(large > small, "window penalty must grow with batch");
    }

    #[test]
    fn more_gpus_speed_verification() {
        let m = CostModel::paper_32b();
        assert!(m.verify(8, 4, 128) < m.verify(4, 4, 128));
    }

    #[test]
    fn draft_methods_cheaper_than_target() {
        let m = CostModel::paper_32b();
        for d in &m.drafts {
            assert!(
                m.draft(&d.method, 64) < m.decode(64),
                "{} not cheaper than target",
                d.method
            );
        }
        // ngram is the cheapest
        assert!(m.draft("ngram", 64) < m.draft("draft_small", 64));
    }

    #[test]
    fn fit_recovers_affine() {
        let truth = AffineCost::new(2e-4, 5e-3);
        let pts: Vec<(usize, f64)> =
            [1, 2, 4, 8, 16, 32].iter().map(|&b| (b, truth.eval(b))).collect();
        let (fit, r2) = AffineCost::fit(&pts);
        assert!((fit.slope - truth.slope).abs() < 1e-9);
        assert!((fit.intercept - truth.intercept).abs() < 1e-9);
        assert!(r2 > 0.999);
    }

    #[test]
    fn verify_fused_anchors() {
        let m = CostModel::paper_32b();
        // no padding: degenerates to verify_f exactly
        let a = m.verify_fused(4, 4.0, 4, 128);
        let b = m.verify_f(4, 4.0, 128);
        assert!((a - b).abs() < 1e-15, "{a} vs {b}");
        // padding costs something, but less than running every row at the
        // full step window (pad_waste < 1)
        let padded = m.verify_fused(4, 2.0, 4, 128);
        assert!(padded > m.verify_f(4, 2.0, 128), "padding must not be free");
        assert!(padded < m.verify(4, 4, 128), "padded rows are cheaper than real ones");
        // ONE fused step at mixed windows beats two grouped steps (2x β)
        let grouped = m.verify(4, 1, 128) + m.verify(4, 3, 128);
        assert!(padded < grouped, "fused {padded} >= grouped {grouped}");
        // monotone in the step window (more padding, more waste)
        assert!(m.verify_fused(4, 2.0, 6, 64) > m.verify_fused(4, 2.0, 4, 64));
    }

    #[test]
    fn replica_overhead_is_marginal_and_beta_free() {
        let m = CostModel::paper_32b();
        // adding one replica row costs the batch slope, never the intercept
        let over = m.replica_overhead(4, 3.0, 4, 16);
        assert!(over > 0.0);
        assert!(
            over < m.verify_fused(4, 3.0, 4, 1),
            "replica overhead {over} must be below a whole b=1 step (β-free)"
        );
        // fork cost is a control-plane constant well under one decode step
        assert!(m.fork_cost > 0.0 && m.fork_cost < m.decode(1));
    }

    #[test]
    fn sam_curve_installs_once_and_prices_above_ngram() {
        let mut m = CostModel::paper_32b();
        // pre-install: sam borrows the n-gram curve exactly
        assert_eq!(m.draft("sam", 64), m.draft("ngram", 64));
        assert!(!m.methods().iter().any(|s| s == "sam"));
        assert!(m.install_sam_curve());
        // post-install: dedicated key, ratio-scaled, still near-free
        assert!(m.methods().iter().any(|s| s == "sam"));
        let ratio = m.draft("sam", 64) / m.draft("ngram", 64);
        assert!((ratio - SAM_NGRAM_COST_RATIO).abs() < 1e-12, "ratio {ratio}");
        assert!(m.draft("sam", 64) < m.decode(64) / 50.0);
        // idempotent
        assert!(!m.install_sam_curve());
        assert_eq!(m.drafts.iter().filter(|d| d.method == "sam").count(), 1);
    }

    #[test]
    fn moe_verification_more_expensive() {
        let dense = CostModel::paper_32b();
        let moe = CostModel::paper_235b_moe();
        assert!(moe.verify(8, 4, 64) > dense.verify(8, 4, 64));
    }
}
