//! Algorithm 1: decoupled execution plan generation at rollout start.
//!
//! Enumeration-based search with decoupled-execution-aware pruning over
//! (verifier GPU config `g_v`, drafter GPUs `g_d`, draft window `w`),
//! maximising the modelled TGS. Mirrors the paper's pseudo-code, including
//! the two prunes: `g_d ≤ g_v` (drafters need fewer GPUs) and
//! `w ≤ w_max = max(⌈V'/D'⌉, ⌈β/α⌉)` (larger windows only add waste).

use super::costmodel::CostModel;
use super::tgs::{step_up, tgs_decoupled_fused, tgs_vanilla};

/// Search output: the initial decoupled execution plan.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub method: String,
    /// GPUs allocated to one drafter replica.
    pub g_d: usize,
    /// GPUs allocated to one verifier replica.
    pub g_v: usize,
    /// Draft window.
    pub w: usize,
    /// Per-verifier-replica batch size implied by the allocation.
    pub b: usize,
    /// Modelled TGS of the plan (tokens/s per replica).
    pub tgs: f64,
    /// Modelled speedup over vanilla decoding at the same batch.
    pub speedup: f64,
}

/// Inputs to Algorithm 1.
#[derive(Clone, Debug)]
pub struct PlanInput {
    /// Initial global batch size B (requests in the step).
    pub global_batch: usize,
    /// Total GPUs in the cluster G.
    pub gpus: usize,
    /// Allowed verifier GPU configs (how one model copy may be partitioned).
    pub verifier_configs: Vec<usize>,
    /// Profiled average per-token acceptance probability for `method`.
    pub accept_p: f64,
    /// Draft method to plan for (selected by the ladder beforehand).
    pub method: String,
    /// Cap on enumerated windows (safety bound; paper prunes analytically).
    pub max_window: usize,
    /// Evaluate TGS at this per-replica batch instead of deriving it from
    /// the GPU split (used when the deployment fixes worker batch sizes,
    /// e.g. the cluster simulator's drafter-piggyback configuration).
    pub fixed_batch: Option<usize>,
    /// Verifiable draft-window grid of the FUSED engine (ascending): a
    /// candidate window between grid sizes rounds UP to the next grid
    /// window at verify time, so the search prices it with the
    /// padding-waste term ([`CostModel::verify_fused`]) — grid-aligned
    /// windows are favoured exactly as the engine runs them. Empty =
    /// every window verifies exactly (no fusion padding), the pre-fusion
    /// pricing.
    pub fused_windows: Vec<usize>,
}

/// Paper's w_max prune: beyond this window the drafter outpaces any
/// verification benefit.
pub fn w_max(m: &CostModel, method: &str, g_v: usize) -> usize {
    let d = m.draft_cost(method).per_token;
    let scale = (m.g_ref as f64 / g_v as f64).powf(m.tp_eff);
    let vp = m.verify1.slope * scale;
    let beta = m.verify1.intercept * scale.clamp(1.0, 1.2);
    let by_slope = (vp / d.slope.max(1e-12)).ceil() as usize;
    let by_intercept = (beta / d.intercept.max(1e-12)).ceil() as usize;
    by_slope.max(by_intercept).max(1)
}

/// Algorithm 1. Returns the best plan, or an effectively-vanilla plan
/// (w = 0 encoded as None) when no speculative plan beats vanilla.
pub fn search(m: &CostModel, input: &PlanInput) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for &g_v in &input.verifier_configs {
        // line 3: drafters need fewer GPUs than verifiers
        for g_d in 1..=g_v {
            // line 4: per-replica batch for this allocation granularity
            let replicas = input.gpus / (g_d + g_v);
            if replicas == 0 {
                continue;
            }
            let b = input.fixed_batch.unwrap_or_else(|| input.global_batch.div_ceil(replicas));
            // line 5: prune arbitrarily large windows
            let wm = w_max(m, &input.method, g_v).min(input.max_window);
            for w in 1..=wm {
                // per-replica TGS (drafter replica count is implied),
                // priced as the fused engine actually runs the window:
                // rounded up into the lowered grid, β once, padding waste
                let w_step = step_up(&input.fused_windows, w);
                let tgs =
                    tgs_decoupled_fused(m, &input.method, g_v, w, w_step, b, input.accept_p);
                let vanilla = tgs_vanilla(m, b);
                let cand = Plan {
                    method: input.method.clone(),
                    g_d,
                    g_v,
                    w,
                    b,
                    tgs,
                    speedup: tgs / vanilla,
                };
                if best.as_ref().map(|p| cand.tgs > p.tgs).unwrap_or(true) {
                    best = Some(cand);
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    fn input(b: usize, p: f64) -> PlanInput {
        PlanInput {
            global_batch: b,
            gpus: 256,
            verifier_configs: vec![4, 8, 16],
            accept_p: p,
            method: "draft_small".to_string(),
            max_window: 16,
            fixed_batch: None,
            fused_windows: vec![],
        }
    }

    #[test]
    fn finds_a_plan_for_paper_config() {
        // DAPO-32B-20K: B=16384, 256 GPUs, TP4 -> per-worker batch 256
        let m = CostModel::paper_32b();
        let plan = search(&m, &input(16384, 0.8)).unwrap();
        assert!(plan.w >= 1);
        assert!(plan.g_d <= plan.g_v);
        assert!(plan.tgs > 0.0);
    }

    #[test]
    fn plan_beats_vanilla_at_decent_acceptance() {
        let m = CostModel::paper_32b();
        let plan = search(&m, &input(8192, 0.85)).unwrap();
        assert!(
            plan.speedup > 1.2,
            "planned speedup {:.2} too small for p=0.85",
            plan.speedup
        );
    }

    #[test]
    fn low_acceptance_shrinks_window() {
        let m = CostModel::paper_32b();
        let hi = search(&m, &input(8192, 0.9)).unwrap();
        let lo = search(&m, &input(8192, 0.3)).unwrap();
        assert!(
            lo.w <= hi.w,
            "low-acceptance window {} should not exceed high-acceptance {}",
            lo.w,
            hi.w
        );
    }

    #[test]
    fn fused_grid_never_plans_worse_than_padded_offgrid() {
        // Under the fused engine's lowered grid, any off-grid window the
        // search might pick must still beat that window's padded TGS —
        // i.e. the winner's priced TGS dominates every candidate at its
        // own rounded step window (the plain no-grid search would compare
        // unpadded TGS and could overvalue off-grid windows).
        let m = CostModel::paper_32b();
        let mut inp = input(8192, 0.85);
        inp.fused_windows = vec![1, 3, 7];
        let plan = search(&m, &inp).unwrap();
        for w in 1..=inp.max_window {
            for &g_v in &inp.verifier_configs {
                let reps = inp.gpus / (1 + g_v);
                if reps == 0 || w > w_max(&m, &inp.method, g_v) {
                    continue;
                }
                let b = inp.global_batch.div_ceil(reps);
                let t = tgs_decoupled_fused(
                    &m,
                    &inp.method,
                    g_v,
                    w,
                    step_up(&inp.fused_windows, w),
                    b,
                    inp.accept_p,
                );
                assert!(plan.tgs >= t - 1e-12, "w={w} g_v={g_v}: {t} beats planned {}", plan.tgs);
            }
        }
    }

    #[test]
    fn w_max_prune_is_positive() {
        let m = CostModel::paper_32b();
        for method in ["draft_small", "draft_mid", "ngram"] {
            assert!(w_max(&m, method, 4) >= 1);
        }
    }

    #[test]
    fn prop_search_respects_constraints() {
        let m = CostModel::paper_32b();
        check("plan-constraints", 60, |g| {
            let inp = PlanInput {
                global_batch: 64 << g.usize_in(0, 8),
                gpus: 8 << g.usize_in(0, 6),
                verifier_configs: vec![2, 4, 8],
                accept_p: 0.2 + 0.75 * g.prob(),
                method: ["draft_small", "draft_mid", "ngram"][g.usize_in(0, 3)].to_string(),
                max_window: 1 + g.usize_in(0, 15),
                fixed_batch: None,
                fused_windows: if g.prob() < 0.5 { vec![] } else { vec![1, 3, 7] },
            };
            if let Some(p) = search(&m, &inp) {
                prop_assert!(p.g_d >= 1 && p.g_d <= p.g_v, "g_d {} g_v {}", p.g_d, p.g_v);
                prop_assert!(p.w >= 1 && p.w <= inp.max_window, "w {}", p.w);
                prop_assert!(inp.verifier_configs.contains(&p.g_v), "g_v not allowed");
                prop_assert!(p.tgs.is_finite() && p.tgs > 0.0, "tgs {}", p.tgs);
                // exhaustive check: no enumerated candidate beats the winner
                for &g_v in &inp.verifier_configs {
                    for g_d in 1..=g_v {
                        let reps = inp.gpus / (g_d + g_v);
                        if reps == 0 {
                            continue;
                        }
                        let b = inp.global_batch.div_ceil(reps);
                        let wm = w_max(&m, &inp.method, g_v).min(inp.max_window);
                        for w in 1..=wm {
                            let ws = step_up(&inp.fused_windows, w);
                            let t =
                                tgs_decoupled_fused(&m, &inp.method, g_v, w, ws, b, inp.accept_p);
                            prop_assert!(
                                t <= p.tgs + 1e-12,
                                "missed better plan g_v={g_v} g_d={g_d} w={w}: {t} > {}",
                                p.tgs
                            );
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
