//! TGS (token generation speed) expectation model (§4.1).
//!
//! Implements the paper's formulas exactly:
//!
//! ```text
//! P(a, w) = p^a (1 − p)   for 0 ≤ a ≤ w−1        (accept a, reject next)
//!         = p^w           for a = w               (full accept)
//!
//! τ_w  = Σ_{a=0}^{w−1} p^a (1−p) (a+1)/2  +  w p^w        (decoupled)
//!
//! IL_{g_d,g_v,w}(b) = max( w·D_{g_d}(b),  V_{g_v,w}(b) )  (pipelined)
//!
//! TGS_{g_d,g_v,w}(b) = τ_w / IL_{g_d,g_v,w}(b)
//! ```
//!
//! plus the coupled analogue `TGS_C,w` the paper references for
//! Algorithm 2 (sequential draft-then-verify; full accept earns the bonus
//! token; no aggressive-drafting discount, so the expected tokens per
//! round is `Σ p^a(1−p)(a+1) + (w+1)p^w`).

use super::costmodel::CostModel;

/// P(a, w): probability of accepting exactly `a` of `w` drafted tokens
/// given per-token acceptance probability `p`.
pub fn p_accept(a: usize, w: usize, p: f64) -> f64 {
    debug_assert!(a <= w);
    if a == w {
        p.powi(w as i32)
    } else {
        p.powi(a as i32) * (1.0 - p)
    }
}

/// Expected useful tokens per decoupled round of window `w` (paper's τ_w —
/// the (a+1)/2 factor discounts in-flight tokens wasted by aggressive
/// drafting when a mis-speculation lands mid-window).
pub fn tau_decoupled(w: usize, p: f64) -> f64 {
    let mut tau = 0.0;
    for a in 0..w {
        tau += p_accept(a, w, p) * (a + 1) as f64 / 2.0;
    }
    tau + w as f64 * p.powi(w as i32)
}

/// Expected useful tokens per coupled round (accepted + correction, or
/// full window + bonus).
pub fn tau_coupled(w: usize, p: f64) -> f64 {
    let mut tau = 0.0;
    for a in 0..w {
        tau += p_accept(a, w, p) * (a + 1) as f64;
    }
    tau + (w + 1) as f64 * p.powi(w as i32)
}

/// Iteration latency of one decoupled round: drafter and verifier overlap.
pub fn il_decoupled(m: &CostModel, method: &str, g_v: usize, w: usize, b: usize) -> f64 {
    let draft = w as f64 * m.draft(method, b);
    let verify = m.verify(g_v, w, b);
    draft.max(verify)
}

/// Iteration latency of one coupled round: draft then verify, serial.
pub fn il_coupled(m: &CostModel, method: &str, g_v: usize, w: usize, b: usize) -> f64 {
    w as f64 * m.draft(method, b) + m.verify(g_v, w, b)
}

/// TGS for decoupled speculation.
pub fn tgs_decoupled(m: &CostModel, method: &str, g_v: usize, w: usize, b: usize, p: f64) -> f64 {
    tau_decoupled(w, p) / il_decoupled(m, method, g_v, w, b)
}

/// TGS for coupled speculation.
pub fn tgs_coupled(m: &CostModel, method: &str, g_v: usize, w: usize, b: usize, p: f64) -> f64 {
    tau_coupled(w, p) / il_coupled(m, method, g_v, w, b)
}

/// TGS of vanilla decoding (one token per decode step).
pub fn tgs_vanilla(m: &CostModel, b: usize) -> f64 {
    1.0 / m.decode(b)
}

/// Smallest grid window ≥ `w` (ascending `grid`), or `w` itself when the
/// grid is empty or `w` exceeds it — the planner-side mirror of the fused
/// engine's round-up of an arbitrary window to the next lowered step size.
pub fn step_up(grid: &[usize], w: usize) -> usize {
    grid.iter().copied().find(|&g| g >= w).unwrap_or(w)
}

/// Iteration latency of one coupled round under the FUSED discipline:
/// draft serially, then verify in a step padded up to the shared window
/// `w_step` (≥ `w`; β once, padding-waste priced by
/// [`CostModel::verify_fused`]). `w_step == w` with
/// `overlap_eff == 0` degenerates to [`il_coupled`] exactly.
///
/// With `CostModel::overlap_eff > 0` the overlapped engine hides that
/// share of the serialized in-round draft time behind the previous
/// round's fused verify step (next-round prefetch), so only
/// `(1 − eff) · w · D(b)` stays on the critical path.
pub fn il_coupled_fused(
    m: &CostModel,
    method: &str,
    g_v: usize,
    w: usize,
    w_step: usize,
    b: usize,
) -> f64 {
    let serial = 1.0 - m.overlap_eff.clamp(0.0, 1.0);
    serial * w as f64 * m.draft(method, b) + m.verify_fused(g_v, w as f64, w_step.max(w), b)
}

/// Decoupled analogue of [`il_coupled_fused`]: drafter overlaps the fused
/// verify step; the overlap-efficiency term additionally discounts the
/// draft arm (prefetch hides part of it behind the *previous* verify),
/// tightening the max toward the verify floor.
pub fn il_decoupled_fused(
    m: &CostModel,
    method: &str,
    g_v: usize,
    w: usize,
    w_step: usize,
    b: usize,
) -> f64 {
    let serial = 1.0 - m.overlap_eff.clamp(0.0, 1.0);
    let draft = serial * w as f64 * m.draft(method, b);
    draft.max(m.verify_fused(g_v, w as f64, w_step.max(w), b))
}

/// TGS for coupled speculation under the fused ragged verify discipline.
pub fn tgs_coupled_fused(
    m: &CostModel,
    method: &str,
    g_v: usize,
    w: usize,
    w_step: usize,
    b: usize,
    p: f64,
) -> f64 {
    tau_coupled(w, p) / il_coupled_fused(m, method, g_v, w, w_step, b)
}

/// TGS for decoupled speculation under the fused ragged verify discipline.
pub fn tgs_decoupled_fused(
    m: &CostModel,
    method: &str,
    g_v: usize,
    w: usize,
    w_step: usize,
    b: usize,
    p: f64,
) -> f64 {
    tau_decoupled(w, p) / il_decoupled_fused(m, method, g_v, w, w_step, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn p_accept_is_distribution() {
        for &p in &[0.0, 0.3, 0.7, 0.95, 1.0] {
            for w in 1..=8 {
                let total: f64 = (0..=w).map(|a| p_accept(a, w, p)).sum();
                assert!((total - 1.0).abs() < 1e-12, "p={p} w={w} sums to {total}");
            }
        }
    }

    #[test]
    fn tau_monotone_in_p() {
        for w in 1..=8 {
            let lo = tau_decoupled(w, 0.3);
            let hi = tau_decoupled(w, 0.9);
            assert!(hi > lo, "w={w}");
            assert!(tau_coupled(w, 0.9) > tau_coupled(w, 0.3));
        }
    }

    #[test]
    fn tau_coupled_bounds() {
        // p=1: every round yields w+1 tokens (window + bonus)
        assert!((tau_coupled(4, 1.0) - 5.0).abs() < 1e-12);
        // p=0: every round yields exactly the correction token
        assert!((tau_coupled(4, 0.0) - 1.0).abs() < 1e-12);
        // decoupled at p=1 yields w per round (no bonus)
        assert!((tau_decoupled(4, 1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn prop_tau_le_window_bound() {
        check("tau-bounds", 200, |g| {
            let w = 1 + g.usize_in(0, 8);
            let p = g.prob();
            let td = tau_decoupled(w, p);
            let tc = tau_coupled(w, p);
            prop_assert!(td > 0.0 && td <= w as f64 + 1e-12, "tau_d={td}");
            prop_assert!(tc > 0.0 && tc <= (w + 1) as f64 + 1e-12, "tau_c={tc}");
            prop_assert!(tc >= td, "coupled tau {tc} < decoupled {td}");
            Ok(())
        });
    }

    #[test]
    fn step_up_rounds_into_the_grid() {
        assert_eq!(step_up(&[1, 3, 7], 2), 3);
        assert_eq!(step_up(&[1, 3, 7], 3), 3);
        assert_eq!(step_up(&[1, 3, 7], 4), 7);
        assert_eq!(step_up(&[1, 3, 7], 9), 9, "beyond the grid: identity");
        assert_eq!(step_up(&[], 4), 4, "empty grid: identity");
    }

    #[test]
    fn fused_tgs_degenerates_without_padding() {
        let m = crate::planner::CostModel::paper_32b();
        let (p, b) = (0.8, 64);
        for w in 1..=6 {
            let c = tgs_coupled(&m, "draft_small", 4, w, b, p);
            let cf = tgs_coupled_fused(&m, "draft_small", 4, w, w, b, p);
            assert!((c - cf).abs() < 1e-9 * c, "coupled w={w}: {c} vs {cf}");
            let d = tgs_decoupled(&m, "draft_small", 4, w, b, p);
            let df = tgs_decoupled_fused(&m, "draft_small", 4, w, w, b, p);
            assert!((d - df).abs() < 1e-9 * d, "decoupled w={w}: {d} vs {df}");
        }
        // rounding up into a larger step window costs padding waste
        assert!(
            tgs_coupled_fused(&m, "draft_small", 4, 2, 4, b, p)
                < tgs_coupled_fused(&m, "draft_small", 4, 2, 2, b, p)
        );
    }

    #[test]
    fn overlap_eff_discounts_only_the_fused_draft_term() {
        let m0 = crate::planner::CostModel::paper_32b();
        let m1 = crate::planner::CostModel::paper_32b().with_overlap_eff(0.6);
        let (p, b, w) = (0.8, 64, 4);
        // eff = 0 is the sequential engine: identical to the base model.
        assert_eq!(
            il_coupled_fused(&m0, "draft_small", 4, w, w, b),
            il_coupled(&m0, "draft_small", 4, w, b)
        );
        // eff > 0 strictly shrinks coupled fused latency (draft is serial
        // there), so TGS strictly rises.
        let c0 = tgs_coupled_fused(&m0, "draft_small", 4, w, w, b, p);
        let c1 = tgs_coupled_fused(&m1, "draft_small", 4, w, w, b, p);
        assert!(c1 > c0, "overlap_eff did not raise coupled fused TGS: {c1} <= {c0}");
        // Decoupled fused latency never rises and is floored by verify.
        let d0 = il_decoupled_fused(&m0, "draft_small", 4, w, w, b);
        let d1 = il_decoupled_fused(&m1, "draft_small", 4, w, w, b);
        assert!(d1 <= d0);
        assert!(d1 >= m0.verify_fused(4, w as f64, w, b) - 1e-12);
        // eff = 1 hides the whole draft: coupled fused collapses to the
        // bare fused verify step.
        let mfull = crate::planner::CostModel::paper_32b().with_overlap_eff(1.0);
        let full = il_coupled_fused(&mfull, "draft_small", 4, w, w, b);
        assert!((full - m0.verify_fused(4, w as f64, w, b)).abs() < 1e-12);
        // Pre-fusion (grouped) latencies are untouched by the knob.
        assert_eq!(
            il_coupled(&m1, "draft_small", 4, w, b),
            il_coupled(&m0, "draft_small", 4, w, b)
        );
        assert_eq!(
            il_decoupled(&m1, "draft_small", 4, w, b),
            il_decoupled(&m0, "draft_small", 4, w, b)
        );
    }

    #[test]
    fn decoupled_beats_coupled_at_high_acceptance_large_batch() {
        // The paper's headline: with b=128+ the serial draft+verify leaves
        // the verifier starved; decoupling overlaps them.
        let m = crate::planner::CostModel::paper_32b();
        let (p, b, w) = (0.85, 128, 4);
        let d = tgs_decoupled(&m, "draft_small", 4, w, b, p);
        let c = tgs_coupled(&m, "draft_small", 4, w, b, p);
        assert!(d > c, "decoupled {d} <= coupled {c}");
    }

    #[test]
    fn vanilla_spec_breaks_even_at_large_batch() {
        // Figure 5(b): at per-worker batch ~128 coupled speculation brings
        // no or negative gain; at small batch it wins clearly.
        let m = crate::planner::CostModel::paper_32b();
        let p = 0.8;
        let small = tgs_coupled(&m, "draft_small", 4, 4, 4, p) / tgs_vanilla(&m, 4);
        let large = tgs_coupled(&m, "draft_small", 4, 4, 192, p) / tgs_vanilla(&m, 192);
        assert!(small > 1.2, "small-batch spec speedup only {small}");
        assert!(large < 1.15, "large-batch spec speedup {large} should collapse");
    }
}
