//! Planner: the affine cost model, the TGS expectation model, and the
//! Algorithm 1 plan search (§4.1).

pub mod costmodel;
pub mod plan;
pub mod tgs;

pub use costmodel::{AffineCost, CostModel, DraftCost};
pub use plan::{search, Plan, PlanInput};
