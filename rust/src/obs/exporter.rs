//! Background-thread Prometheus scrape server (`GET /metrics`,
//! `GET /healthz`) behind `specactor serve --metrics-addr HOST:PORT`.
//!
//! Snapshot-based so the tick loop never blocks on a scraper: the batcher
//! renders a [`super::MetricRegistry`] snapshot every few ticks and
//! `publish`es the string; the listener thread serves whatever snapshot
//! is current. The only shared state is an `Arc<Mutex<String>>` swapped
//! whole — a slow or stalled scraper can at worst read a stale snapshot,
//! never hold up a round.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

pub struct MetricsExporter {
    snapshot: Arc<Mutex<String>>,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    /// Actual bound address (port 0 resolves here — tests bind ephemeral).
    pub addr: SocketAddr,
}

impl MetricsExporter {
    /// Bind `addr` (e.g. `127.0.0.1:9464`) and start the listener thread.
    pub fn bind(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind --metrics-addr {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let snapshot = Arc::new(Mutex::new(String::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let snap = Arc::clone(&snapshot);
        let stop = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("metrics-exporter".to_string())
            .spawn(move || serve_loop(listener, snap, stop))
            .context("spawn metrics-exporter")?;
        Ok(MetricsExporter { snapshot, shutdown, handle: Some(handle), addr: local })
    }

    /// Swap in a freshly rendered exposition snapshot (cheap: one String
    /// move under a lock the listener holds only to clone).
    pub fn publish(&self, rendered: String) {
        *self.snapshot.lock().unwrap() = rendered;
    }
}

impl Drop for MetricsExporter {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(listener: TcpListener, snapshot: Arc<Mutex<String>>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // per-connection failures (scraper hung up mid-request)
                // must never take the exporter down
                let _ = handle_conn(stream, &snapshot);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_conn(mut stream: TcpStream, snapshot: &Arc<Mutex<String>>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let path = req.split_whitespace().nth(1).unwrap_or("");
    let (status, ctype, body) = match path {
        "/metrics" => {
            let body = snapshot.lock().unwrap().clone();
            ("200 OK", "text/plain; version=0.0.4; charset=utf-8", body)
        }
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_published_snapshot_and_healthz() {
        let exp = MetricsExporter::bind("127.0.0.1:0").unwrap();
        exp.publish("# TYPE up gauge\nup 1\n".to_string());
        let resp = get(exp.addr, "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.contains("version=0.0.4"));
        assert!(resp.ends_with("up 1\n"));
        let health = get(exp.addr, "/healthz");
        assert!(health.contains("200 OK") && health.ends_with("ok\n"));
        let missing = get(exp.addr, "/nope");
        assert!(missing.contains("404"));
        // a later publish replaces the snapshot whole
        exp.publish("up 0\n".to_string());
        assert!(get(exp.addr, "/metrics").ends_with("up 0\n"));
    }
}
