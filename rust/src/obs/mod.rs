//! Observability: metric registry + Prometheus exposition
//! ([`registry`]), scrape server ([`exporter`]), and per-round span
//! tracing with a flight recorder for chaos post-mortems ([`trace`]).
//!
//! Design rule: the hot path only bumps counters it already owns and
//! records fixed-size spans into a preallocated ring; everything that
//! allocates (rendering, export, fault dumps) happens on scrape, on
//! error, or after the run. `Batcher::collect_registry` is the single
//! assembly point — the `/metrics` scrape and the end-of-run JSON both
//! render from it, so they cannot drift.

pub mod exporter;
pub mod registry;
pub mod trace;

pub use exporter::MetricsExporter;
pub use registry::{FixedHistogram, MetricRegistry};
pub use trace::{chrome_trace, FaultDump, Phase, SpanEvent, Tracer};
