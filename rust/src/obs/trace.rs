//! Per-round span tracing + the flight recorder.
//!
//! The serve tick and the engine's hot path record one [`SpanEvent`] per
//! phase that did work (draft per plan-group, the fused ragged verify,
//! apply, derived KV h2d/d2h copy time, replan/admit/reconfig/race
//! launch). Events land in a **preallocated ring buffer** — O(1) per
//! event, no allocation on the hot path, oldest-first overwrite — so the
//! recorder's cost is a couple of `Instant` reads per phase and its
//! memory is fixed at construction (PERF.md §Memory discipline).
//!
//! Two consumers read the ring:
//! * `--trace-out FILE` exports the whole ring as chrome://tracing JSON
//!   ([`chrome_trace`]) after the run;
//! * on any `SpecError` the batcher snapshots the last K rounds of spans
//!   plus the victim slot's plan/acceptance state into a [`FaultDump`],
//!   so a chaos failure is debuggable post-mortem even though recovery
//!   immediately rewrites the live state.
//!
//! Durations also feed per-phase [`FixedHistogram`]s, exported as
//! `specactor_phase_seconds{phase=...}` — the draft/verify/copy breakdown
//! the ROADMAP's overlapped-execution item is benchmarked against.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::util::json::Json;

use super::registry::{FixedHistogram, MetricRegistry};

/// Hot-path phase a span measures. Serve-tick phases come first, then the
/// engine-round sub-phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Tick phase 0: resolving finished Fastest-of-N races.
    Resolve,
    /// Tick phase 1: retiring finished slots.
    Retire,
    /// Tick phase 2: occupancy-bucket replanning (Algorithm 1).
    Replan,
    /// Tick phase 2: admissions (prefill-join).
    Admit,
    /// Tick phase 3b: forking replicas for a race (Algorithm 3).
    RaceLaunch,
    /// Tick phase 4: the whole engine round.
    Round,
    /// Tick phase 5: Algorithm 2 reconfiguration.
    Reconfig,
    /// Engine round: drafting one plan group.
    Draft,
    /// Engine round: the fused ragged verify step.
    Verify,
    /// Engine round: applying per-row outcomes.
    Apply,
    /// KV host→device staging time inside the verify step (derived from
    /// `RuntimeStats` deltas — the copies happen inside the runtime).
    KvH2d,
    /// KV/logits device→host readback time inside the verify step
    /// (derived from `RuntimeStats` deltas).
    KvD2h,
    /// Overlapped round: next-round drafting done by the prefetch thread
    /// while this round's verify was in flight (duration reported by the
    /// prefetcher; rendered on its own chrome track so the concurrency
    /// with [`Phase::Verify`] is visible).
    PrefetchDraft,
    /// Overlapped round: next-round h2d staging overlapped with this
    /// round's execute via the split submit/await runtime step.
    PrefetchKvH2d,
    /// Tick phase 6: folding accepted segments into the wave-global
    /// draft corpus and publishing the next snapshot epoch (round
    /// boundary — off the decode critical path by construction).
    CorpusPublish,
}

pub const N_PHASES: usize = 15;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Resolve,
        Phase::Retire,
        Phase::Replan,
        Phase::Admit,
        Phase::RaceLaunch,
        Phase::Round,
        Phase::Reconfig,
        Phase::Draft,
        Phase::Verify,
        Phase::Apply,
        Phase::KvH2d,
        Phase::KvD2h,
        Phase::PrefetchDraft,
        Phase::PrefetchKvH2d,
        Phase::CorpusPublish,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Resolve => "resolve",
            Phase::Retire => "retire",
            Phase::Replan => "replan",
            Phase::Admit => "admit",
            Phase::RaceLaunch => "race_launch",
            Phase::Round => "round",
            Phase::Reconfig => "reconfig",
            Phase::Draft => "draft",
            Phase::Verify => "verify",
            Phase::Apply => "apply",
            Phase::KvH2d => "kv_h2d",
            Phase::KvD2h => "kv_d2h",
            Phase::PrefetchDraft => "prefetch_draft",
            Phase::PrefetchKvH2d => "prefetch_kv_h2d",
            Phase::CorpusPublish => "corpus_publish",
        }
    }

    /// chrome://tracing track: main-thread phases on tid 1, prefetch
    /// phases on tid 3 (tid 2 is the fault-dump window) so overlapped
    /// spans render concurrent with the verify they hide behind.
    fn chrome_tid(self) -> f64 {
        match self {
            Phase::PrefetchDraft | Phase::PrefetchKvH2d => 3.0,
            _ => 1.0,
        }
    }

    fn index(self) -> usize {
        Phase::ALL.iter().position(|&p| p == self).unwrap()
    }
}

/// One recorded span. `Copy` and fixed-size so the ring never allocates.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub phase: Phase,
    /// Serve round (tick) the span belongs to.
    pub round: u64,
    /// Start offset from the tracer's epoch, microseconds.
    pub t_start_us: u64,
    pub dur_us: u64,
    /// Phase-specific payload: slots touched, plan group index, replicas
    /// forked — whatever the recording site finds cheap and useful.
    pub detail: u32,
}

struct TraceBuffer {
    /// Ring storage: grows (within pre-reserved capacity) until full,
    /// then `head` walks the overwrite position.
    buf: Vec<SpanEvent>,
    cap: usize,
    head: usize,
    total: u64,
    round: u64,
    epoch: Instant,
    phase_hist: Vec<FixedHistogram>,
}

/// Cloneable recording handle (single-threaded interior mutability: the
/// batcher and the engine share one buffer; the exporter thread only ever
/// sees rendered strings).
#[derive(Clone)]
pub struct Tracer(Rc<RefCell<TraceBuffer>>);

impl Tracer {
    /// `capacity` is the flight-recorder depth in events; memory is fixed
    /// here and never grows afterwards.
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(16);
        Tracer(Rc::new(RefCell::new(TraceBuffer {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            total: 0,
            round: 0,
            epoch: Instant::now(),
            phase_hist: (0..N_PHASES).map(|_| FixedHistogram::time_buckets()).collect(),
        })))
    }

    /// Microseconds since the tracer's epoch — span start timestamps.
    pub fn now_us(&self) -> u64 {
        self.0.borrow().epoch.elapsed().as_micros() as u64
    }

    /// Tag subsequent spans with serve round `r`.
    pub fn begin_round(&self, r: u64) {
        self.0.borrow_mut().round = r;
    }

    /// Record a span that started at `t0_us` and ends now.
    pub fn record(&self, phase: Phase, t0_us: u64, detail: u32) {
        let now = self.now_us();
        self.record_with_dur(phase, t0_us, now.saturating_sub(t0_us), detail);
    }

    /// Record a span with an externally measured duration (the derived KV
    /// copy spans use `RuntimeStats` deltas). O(1), allocation-free: the
    /// ring either appends into pre-reserved capacity or overwrites.
    pub fn record_with_dur(&self, phase: Phase, t0_us: u64, dur_us: u64, detail: u32) {
        let mut b = self.0.borrow_mut();
        let ev =
            SpanEvent { phase, round: b.round, t_start_us: t0_us, dur_us, detail };
        if b.buf.len() < b.cap {
            b.buf.push(ev);
        } else {
            let h = b.head;
            b.buf[h] = ev;
            b.head = (h + 1) % b.cap;
        }
        b.total += 1;
        b.phase_hist[phase.index()].observe(dur_us as f64 * 1e-6);
    }

    /// Events recorded over the tracer's lifetime (>= `len` once the ring
    /// has wrapped).
    pub fn total(&self) -> u64 {
        self.0.borrow().total
    }

    pub fn len(&self) -> usize {
        self.0.borrow().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.borrow().buf.is_empty()
    }

    /// Ring contents, oldest first (cold path: allocates the result).
    pub fn events(&self) -> Vec<SpanEvent> {
        let b = self.0.borrow();
        let mut out = Vec::with_capacity(b.buf.len());
        out.extend_from_slice(&b.buf[b.head..]);
        out.extend_from_slice(&b.buf[..b.head]);
        out
    }

    /// Spans from the last `k_rounds` serve rounds, oldest first — the
    /// fault-dump window.
    pub fn recent_spans(&self, k_rounds: u64) -> Vec<SpanEvent> {
        let current = self.0.borrow().round;
        let cutoff = current.saturating_sub(k_rounds.saturating_sub(1));
        self.events().into_iter().filter(|e| e.round >= cutoff).collect()
    }

    /// Register the per-phase duration histograms (and the recorder's own
    /// ledger) into a scrape snapshot. Phases that never fired are
    /// skipped so an untraced path exports no empty series.
    pub fn register_metrics(&self, reg: &mut MetricRegistry) {
        let b = self.0.borrow();
        for p in Phase::ALL {
            let h = &b.phase_hist[p.index()];
            if h.is_empty() {
                continue;
            }
            reg.histogram_l(
                "specactor_phase_seconds",
                "Time spent per hot-path phase, per span",
                &[("phase", p.label())],
                h,
            );
        }
        reg.counter(
            "specactor_trace_events_total",
            "Spans recorded by the flight recorder (ring overwrites included)",
            b.total as f64,
        );
    }
}

/// Post-mortem snapshot taken by the batcher when a `SpecError` surfaces:
/// the error, the victim slot's plan/acceptance state at fault time, and
/// the last K rounds of spans from the flight recorder.
#[derive(Clone, Debug)]
pub struct FaultDump {
    pub round: u64,
    pub error: String,
    /// `SpecError::severity()` label (degradable / slot_fatal / worker_fatal).
    pub severity: String,
    pub slot: Option<usize>,
    /// Victim slot's plan label (`method:window`), when a slot is named.
    pub plan: String,
    /// Victim slot's cumulative drafted/accepted counters at fault time.
    pub drafted: u64,
    pub accepted: u64,
    pub spans: Vec<SpanEvent>,
}

impl FaultDump {
    fn args_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("error", Json::str(&self.error)),
            ("severity", Json::str(&self.severity)),
            (
                "slot",
                match self.slot {
                    Some(s) => Json::num(s as f64),
                    None => Json::Null,
                },
            ),
            ("plan", Json::str(&self.plan)),
            ("drafted", Json::num(self.drafted as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("spans_captured", Json::num(self.spans.len() as f64)),
        ])
    }
}

fn span_json(e: &SpanEvent) -> Json {
    Json::obj(vec![
        ("name", Json::str(e.phase.label())),
        ("cat", Json::str("specactor")),
        ("ph", Json::str("X")),
        ("ts", Json::num(e.t_start_us as f64)),
        ("dur", Json::num(e.dur_us as f64)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(e.phase.chrome_tid())),
        (
            "args",
            Json::obj(vec![
                ("round", Json::num(e.round as f64)),
                ("detail", Json::num(e.detail as f64)),
            ]),
        ),
    ])
}

/// chrome://tracing JSON object format: complete (`"ph":"X"`) events for
/// every span, global instant events (`"ph":"i"`) for fault dumps. Load
/// the written file in `chrome://tracing` or Perfetto.
pub fn chrome_trace(events: &[SpanEvent], dumps: &[FaultDump]) -> Json {
    let mut items: Vec<Json> = events.iter().map(span_json).collect();
    for d in dumps {
        let ts = d.spans.last().map(|s| s.t_start_us + s.dur_us).unwrap_or(0);
        items.push(Json::obj(vec![
            ("name", Json::str(&format!("fault: {}", d.severity))),
            ("cat", Json::str("fault")),
            ("ph", Json::str("i")),
            ("s", Json::str("g")),
            ("ts", Json::num(ts as f64)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(1.0)),
            ("args", d.args_json()),
        ]));
        // the dump's span window rides along on its own track so the
        // pre-fault timeline survives even after the main ring wraps
        for s in &d.spans {
            let mut j = span_json(s);
            if let Json::Obj(o) = &mut j {
                o.insert("tid".to_string(), Json::num(2.0));
                o.insert("cat".to_string(), Json::str("fault_window"));
            }
            items.push(j);
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(items)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_first() {
        let t = Tracer::new(16);
        for i in 0..40u32 {
            t.record_with_dur(Phase::Round, i as u64, 1, i);
        }
        assert_eq!(t.len(), 16);
        assert_eq!(t.total(), 40);
        let evs = t.events();
        let details: Vec<u32> = evs.iter().map(|e| e.detail).collect();
        let expect: Vec<u32> = (24..40).collect();
        assert_eq!(details, expect, "ring must keep the newest events, oldest first");
    }

    #[test]
    fn recent_spans_window_by_round() {
        let t = Tracer::new(64);
        for r in 0..10u64 {
            t.begin_round(r);
            t.record_with_dur(Phase::Round, 0, 1, 0);
            t.record_with_dur(Phase::Verify, 0, 1, 0);
        }
        let recent = t.recent_spans(3);
        assert_eq!(recent.len(), 6);
        assert!(recent.iter().all(|e| e.round >= 7));
    }

    #[test]
    fn phase_histograms_register_only_fired_phases() {
        let t = Tracer::new(16);
        t.record_with_dur(Phase::Verify, 0, 1500, 0);
        let mut reg = MetricRegistry::new();
        t.register_metrics(&mut reg);
        let rendered = reg.render();
        assert!(rendered.contains("phase=\"verify\""));
        assert!(!rendered.contains("phase=\"draft\""));
        assert!(rendered.contains("specactor_trace_events_total 1"));
    }

    #[test]
    fn prefetch_spans_render_on_their_own_track() {
        // The acceptance criterion for the overlapped round: prefetch
        // draft/h2d spans must land on a separate chrome tid so their
        // concurrency with the in-flight verify is visible in the trace.
        let t = Tracer::new(16);
        t.record_with_dur(Phase::Verify, 0, 10, 0);
        t.record_with_dur(Phase::PrefetchDraft, 2, 5, 0);
        t.record_with_dur(Phase::PrefetchKvH2d, 4, 3, 0);
        let j = chrome_trace(&t.events(), &[]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        let tid_of = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").as_str() == Some(name))
                .and_then(|e| e.get("tid").as_f64())
                .unwrap()
        };
        assert_eq!(tid_of("verify"), 1.0);
        assert_eq!(tid_of("prefetch_draft"), 3.0);
        assert_eq!(tid_of("prefetch_kv_h2d"), 3.0);
    }

    #[test]
    fn chrome_trace_roundtrips_through_the_json_parser() {
        let t = Tracer::new(16);
        t.begin_round(3);
        t.record_with_dur(Phase::Draft, 10, 5, 1);
        t.record_with_dur(Phase::Verify, 15, 7, 0);
        let dump = FaultDump {
            round: 3,
            error: "kv row invalid".into(),
            severity: "slot_fatal".into(),
            slot: Some(2),
            plan: "sam:3".into(),
            drafted: 12,
            accepted: 9,
            spans: t.recent_spans(2),
        };
        let j = chrome_trace(&t.events(), &[dump]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        // 2 ring spans + 1 instant + 2 fault-window spans
        assert_eq!(evs.len(), 5);
        assert!(evs.iter().any(|e| e.get("ph").as_str() == Some("i")));
        assert!(evs.iter().all(|e| e.get("ts").as_f64().is_some()));
    }
}
