//! Std-only metric registry with Prometheus text exposition.
//!
//! The registry is a **snapshot builder**: every scrape (and every
//! end-of-run JSON summary) rebuilds it from the live owners of the
//! numbers — `ServeMetrics`, the queue's rejection ledger, the
//! `RaceArbiter` ledger, `RuntimeStats`, the chaos fault ledger and the
//! tracer's phase histograms — so the scrape and `to_json` render from
//! one source of truth instead of two drifting copies. Building a
//! snapshot is a cold-path cost (it allocates); the hot path only bumps
//! the plain counters it already owned.
//!
//! Exposition follows the Prometheus text format v0.0.4: one `# HELP` +
//! `# TYPE` header per family (in registration order), label values
//! escaped (`\\`, `\"`, `\n`), histograms rendered as monotone
//! cumulative `_bucket{le="..."}` series ending in `+Inf` == `_count`,
//! plus `_sum`.

use std::fmt::Write as _;

/// Label sets per family are bounded (the ladder has finitely many draft
/// methods); beyond this a family silently keeps its first sets so a
/// label-cardinality bug cannot grow the scrape without bound.
pub const MAX_SERIES_PER_FAMILY: usize = 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Fixed-bucket histogram with O(1), allocation-free `observe` — the
/// live accumulator behind per-phase round-time series. `bounds` are
/// ascending upper bounds; the implicit last bucket is `+Inf`.
#[derive(Clone, Debug)]
pub struct FixedHistogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl FixedHistogram {
    pub fn new(mut bounds: Vec<f64>) -> Self {
        bounds.retain(|b| b.is_finite());
        bounds.sort_by(|a, b| a.total_cmp(b));
        bounds.dedup();
        let n = bounds.len();
        FixedHistogram { bounds, counts: vec![0; n + 1], sum: 0.0, count: 0 }
    }

    /// Default buckets for round-phase durations in seconds: 1-2-5 decades
    /// from 1 µs to 1 s (engine rounds on this CPU runtime span µs for the
    /// synthetic engine to tens of ms for PJRT steps).
    pub fn time_buckets() -> Self {
        let mut bounds = Vec::with_capacity(19);
        for exp in -6i32..=-1 {
            let base = 10f64.powi(exp);
            bounds.extend([base, 2.0 * base, 5.0 * base]);
        }
        bounds.push(1.0);
        Self::new(bounds)
    }

    /// O(1) per event, no allocation (PERF.md hot-path rule): a binary
    /// search over the fixed bounds plus two adds.
    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

#[derive(Clone, Debug)]
enum Value {
    Scalar(f64),
    Hist { bounds: Vec<f64>, cumulative: Vec<u64>, sum: f64, count: u64 },
}

#[derive(Clone, Debug)]
struct Series {
    labels: Vec<(String, String)>,
    value: Value,
}

#[derive(Clone, Debug)]
struct Family {
    name: String,
    help: String,
    kind: Kind,
    series: Vec<Series>,
}

/// A rendered-on-demand snapshot of every registered metric family.
#[derive(Clone, Debug, Default)]
pub struct MetricRegistry {
    families: Vec<Family>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Monotone cumulative counter (unlabeled).
    pub fn counter(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, Kind::Counter, &[], Value::Scalar(v));
    }

    /// Counter series under `labels`; same-name calls join one family.
    pub fn counter_l(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.push(name, help, Kind::Counter, labels, Value::Scalar(v));
    }

    /// Point-in-time gauge (unlabeled).
    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, Kind::Gauge, &[], Value::Scalar(v));
    }

    pub fn gauge_l(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.push(name, help, Kind::Gauge, labels, Value::Scalar(v));
    }

    /// Snapshot a [`FixedHistogram`] (unlabeled).
    pub fn histogram(&mut self, name: &str, help: &str, h: &FixedHistogram) {
        self.histogram_l(name, help, &[], h);
    }

    /// Snapshot a [`FixedHistogram`] under `labels` (e.g. `phase="draft"`);
    /// buckets are converted to the cumulative form the text format wants.
    pub fn histogram_l(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &FixedHistogram,
    ) {
        let mut cumulative = Vec::with_capacity(h.counts.len());
        let mut acc = 0u64;
        for &c in &h.counts {
            acc += c;
            cumulative.push(acc);
        }
        let v = Value::Hist { bounds: h.bounds.clone(), cumulative, sum: h.sum, count: h.count };
        self.push(name, help, Kind::Histogram, labels, v);
    }

    fn push(&mut self, name: &str, help: &str, kind: Kind, labels: &[(&str, &str)], v: Value) {
        let series = Series {
            labels: labels.iter().map(|(k, val)| (k.to_string(), val.to_string())).collect(),
            value: v,
        };
        if let Some(f) = self.families.iter_mut().find(|f| f.name == name) {
            // a family's kind is fixed by its first registration; a
            // mismatched re-registration is a programming error we keep
            // visible in tests but must not corrupt a production scrape
            debug_assert_eq!(f.kind, kind, "metric family {name} re-registered as {kind:?}");
            if f.kind == kind && f.series.len() < MAX_SERIES_PER_FAMILY {
                f.series.push(series);
            }
            return;
        }
        self.families.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            series: vec![series],
        });
    }

    /// Total number of exposed series (histograms count every bucket line
    /// plus `_sum` and `_count`) — the scrape-size figure the acceptance
    /// criteria and the CI checker bound.
    pub fn series_count(&self) -> usize {
        self.families
            .iter()
            .flat_map(|f| &f.series)
            .map(|s| match &s.value {
                Value::Scalar(_) => 1,
                Value::Hist { cumulative, .. } => cumulative.len() + 2,
            })
            .sum()
    }

    /// Scalar lookup for tests and the JSON-reconciliation check.
    pub fn find(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let f = self.families.iter().find(|f| f.name == name)?;
        f.series
            .iter()
            .find(|s| {
                s.labels.len() == labels.len()
                    && labels
                        .iter()
                        .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
            })
            .and_then(|s| match &s.value {
                Value::Scalar(v) => Some(*v),
                Value::Hist { .. } => None,
            })
    }

    /// Prometheus text exposition (format version 0.0.4).
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096 + 128 * self.series_count());
        for f in &self.families {
            let _ = writeln!(out, "# HELP {} {}", f.name, escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind.name());
            for s in &f.series {
                match &s.value {
                    Value::Scalar(v) => {
                        out.push_str(&f.name);
                        write_labels(&mut out, &s.labels, None);
                        let _ = writeln!(out, " {}", fmt_value(*v));
                    }
                    Value::Hist { bounds, cumulative, sum, count } => {
                        for (i, cum) in cumulative.iter().enumerate() {
                            let le = bounds.get(i).map(|b| fmt_value(*b));
                            out.push_str(&f.name);
                            out.push_str("_bucket");
                            let le = le.as_deref().unwrap_or("+Inf");
                            write_labels(&mut out, &s.labels, Some(le));
                            let _ = writeln!(out, " {cum}");
                        }
                        out.push_str(&f.name);
                        out.push_str("_sum");
                        write_labels(&mut out, &s.labels, None);
                        let _ = writeln!(out, " {}", fmt_value(*sum));
                        out.push_str(&f.name);
                        out.push_str("_count");
                        write_labels(&mut out, &s.labels, None);
                        let _ = writeln!(out, " {count}");
                    }
                }
            }
        }
        out
    }
}

/// `{k="v",...}` with escaped values; `le` (when given) renders last so
/// bucket lines read naturally. Empty label sets emit no braces.
fn write_labels(out: &mut String, labels: &[(String, String)], le: Option<&str>) {
    if labels.is_empty() && le.is_none() {
        return;
    }
    out.push('{');
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    if let Some(le) = le {
        if !first {
            out.push(',');
        }
        let _ = write!(out, "le=\"{le}\"");
    }
    out.push('}');
}

/// Label-value escaping per the exposition format: `\` `"` and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// HELP text escapes only `\` and newline (quotes are legal there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Integers render without a fraction (matching `util::json`), floats with
/// Rust's shortest roundtrip form — both are valid exposition floats.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_partition() {
        let mut h = FixedHistogram::new(vec![1.0, 2.0, 5.0]);
        for v in [0.5, 1.0, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        // le=1: 0.5 and the exact 1.0 boundary; +Inf overflow holds 100.0
        assert_eq!(h.counts, vec![2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 106.0).abs() < 1e-12);
    }

    #[test]
    fn families_merge_and_cap() {
        let mut r = MetricRegistry::new();
        for i in 0..(MAX_SERIES_PER_FAMILY + 10) {
            let v = i.to_string();
            r.counter_l("m", "h", &[("i", v.as_str())], 1.0);
        }
        assert_eq!(r.series_count(), MAX_SERIES_PER_FAMILY);
        let rendered = r.render();
        assert_eq!(rendered.matches("# TYPE m counter").count(), 1);
    }

    #[test]
    fn find_matches_labels_exactly() {
        let mut r = MetricRegistry::new();
        r.counter_l("x", "h", &[("a", "1")], 3.0);
        r.counter_l("x", "h", &[("a", "2")], 4.0);
        assert_eq!(r.find("x", &[("a", "2")]), Some(4.0));
        assert_eq!(r.find("x", &[]), None);
        assert_eq!(r.find("y", &[]), None);
    }

    #[test]
    fn time_buckets_are_strictly_ascending() {
        let h = FixedHistogram::time_buckets();
        assert!(h.bounds.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(h.counts.len(), h.bounds.len() + 1);
    }
}
