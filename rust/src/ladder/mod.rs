//! Draft ladder (§4.2, Figure 11): maps acceptance rate → modelled speedup
//! for every draft method, built by offline profiling, and the selection
//! mechanism that picks the estimated-fastest method for a batch.
//!
//! The ladder is constructed *without the trained model* — exactly as the
//! paper argues is possible: drafter execution cost is independent of the
//! target, and speedup can be simulated by accepting tokens at a given
//! rate. `build` uses the analytic TGS model; `build_simulated` Monte-Carlo
//! simulates random acceptances (closer to the paper's offline profiler)
//! and the tests check the two agree.

use crate::planner::costmodel::CostModel;
use crate::planner::tgs::{tgs_coupled, tgs_decoupled, tgs_vanilla};
use crate::util::Rng;

/// Pseudo-count weight of a profiled prior when blending in measured
/// acceptance: the prior counts as this many drafted tokens of evidence.
pub const PRIOR_PSEUDO_COUNT: f64 = 32.0;

/// Blend a profiled prior acceptance rate with a measured rate backed by
/// `n` drafted tokens of evidence (Beta-mean style shrinkage): with
/// little evidence the result stays near the prior, with a wave of
/// evidence it converges to the measured rate. This is the
/// prior-feedback rule the serve replanner applies so Algorithm 1/2
/// start from measured rates instead of static profiles (PERF.md
/// §Online draft learning).
pub fn blend_measured(prior: f64, measured: f64, n: u64) -> f64 {
    let n = n as f64;
    ((prior * PRIOR_PSEUDO_COUNT + measured * n) / (PRIOR_PSEUDO_COUNT + n)).clamp(0.0, 1.0)
}

/// One method's speedup curve over the acceptance-rate grid.
#[derive(Clone, Debug)]
pub struct LadderEntry {
    pub method: String,
    /// Profiled average acceptance rate for this method (from history).
    pub profiled_p: f64,
    /// speedup[i] at acceptance grid point `grid[i]`.
    pub speedup: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct Ladder {
    /// Acceptance-rate grid (shared by all entries).
    pub grid: Vec<f64>,
    pub entries: Vec<LadderEntry>,
    /// Batch size and window the ladder was profiled at.
    pub batch: usize,
    pub window: usize,
}

impl Ladder {
    /// Build analytically from the cost model (offline profiling).
    /// `profiled_p` gives each method's historical average acceptance.
    /// Coupled-mode curves (the baseline regime).
    pub fn build(
        m: &CostModel,
        batch: usize,
        window: usize,
        profiled_p: &[(String, f64)],
    ) -> Ladder {
        Self::build_mode(m, batch, window, profiled_p, false)
    }

    /// Ladder for the execution mode SpecActor will actually run
    /// (decoupled): the selection must rank methods under decoupled TGS.
    pub fn build_decoupled(
        m: &CostModel,
        batch: usize,
        window: usize,
        profiled_p: &[(String, f64)],
    ) -> Ladder {
        Self::build_mode(m, batch, window, profiled_p, true)
    }

    fn build_mode(
        m: &CostModel,
        batch: usize,
        window: usize,
        profiled_p: &[(String, f64)],
        decoupled: bool,
    ) -> Ladder {
        let grid: Vec<f64> = (1..=19).map(|i| i as f64 * 0.05).collect();
        let vanilla = tgs_vanilla(m, batch);
        let entries = profiled_p
            .iter()
            .map(|(method, p)| LadderEntry {
                method: method.clone(),
                profiled_p: *p,
                speedup: grid
                    .iter()
                    .map(|&gp| {
                        let t = if decoupled {
                            tgs_decoupled(m, method, m.g_ref, window, batch, gp)
                        } else {
                            tgs_coupled(m, method, m.g_ref, window, batch, gp)
                        };
                        t / vanilla
                    })
                    .collect(),
            })
            .collect();
        Ladder { grid, entries, batch, window }
    }

    /// Monte-Carlo construction: simulate speculative rounds with random
    /// acceptance at each grid rate (the paper's "randomly accepting
    /// tokens according to a given acceptance rate").
    pub fn build_simulated(
        m: &CostModel,
        batch: usize,
        window: usize,
        profiled_p: &[(String, f64)],
        rounds: usize,
        seed: u64,
    ) -> Ladder {
        let grid: Vec<f64> = (1..=19).map(|i| i as f64 * 0.05).collect();
        let vanilla = tgs_vanilla(m, batch);
        let mut rng = Rng::new(seed);
        let entries = profiled_p
            .iter()
            .map(|(method, p)| {
                let speedup = grid
                    .iter()
                    .map(|&gp| {
                        let mut tokens = 0.0f64;
                        let mut time = 0.0f64;
                        for _ in 0..rounds {
                            // draft `window` tokens, accept each with prob gp
                            let mut acc = 0;
                            while acc < window && rng.bernoulli(gp) {
                                acc += 1;
                            }
                            let full = acc == window;
                            tokens += acc as f64 + 1.0; // + correction/bonus
                            let _ = full;
                            time += window as f64 * m.draft(method, batch)
                                + m.verify(m.g_ref, window, batch);
                        }
                        (tokens / time) / vanilla
                    })
                    .collect();
                LadderEntry { method: method.clone(), profiled_p: *p, speedup }
            })
            .collect();
        Ladder { grid, entries, batch, window }
    }

    fn speedup_at(&self, e: &LadderEntry, p: f64) -> f64 {
        // linear interpolation over the grid
        let p = p.clamp(self.grid[0], *self.grid.last().unwrap());
        let idx = self
            .grid
            .iter()
            .position(|&g| g >= p)
            .unwrap_or(self.grid.len() - 1);
        if idx == 0 {
            return e.speedup[0];
        }
        let (g0, g1) = (self.grid[idx - 1], self.grid[idx]);
        let f = (p - g0) / (g1 - g0);
        e.speedup[idx - 1] * (1.0 - f) + e.speedup[idx] * f
    }

    /// Figure 11 selection: estimated speedup of each method at its own
    /// profiled acceptance rate (①), pick the fastest (②).
    pub fn select_initial(&self) -> &LadderEntry {
        self.entries
            .iter()
            .max_by(|a, b| {
                self.speedup_at(a, a.profiled_p)
                    .total_cmp(&self.speedup_at(b, b.profiled_p))
            })
            .expect("empty ladder")
    }

    /// Ladder rank for Algorithm 3 (ascending = best first).
    pub fn ranked(&self) -> Vec<&LadderEntry> {
        let mut v: Vec<&LadderEntry> = self.entries.iter().collect();
        v.sort_by(|a, b| {
            self.speedup_at(b, b.profiled_p)
                .total_cmp(&self.speedup_at(a, a.profiled_p))
        });
        v
    }

    pub fn rank_of(&self, method: &str) -> usize {
        self.ranked()
            .iter()
            .position(|e| e.method == method)
            .unwrap_or(usize::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiled() -> Vec<(String, f64)> {
        vec![
            ("draft_small".to_string(), 0.75),
            ("draft_mid".to_string(), 0.85),
            ("ngram".to_string(), 0.35),
        ]
    }

    #[test]
    fn speedup_monotone_in_acceptance() {
        let m = CostModel::paper_32b();
        let l = Ladder::build(&m, 8, 4, &profiled());
        for e in &l.entries {
            for win in e.speedup.windows(2) {
                assert!(win[1] >= win[0] - 1e-9, "{}: non-monotone", e.method);
            }
        }
    }

    #[test]
    fn selection_picks_plausible_method() {
        let m = CostModel::paper_32b();
        let l = Ladder::build(&m, 8, 4, &profiled());
        let sel = l.select_initial();
        // 0.5B at 0.75 vs 1.5B at 0.85 vs ngram at 0.35: a model drafter
        // must win over low-acceptance ngram
        assert_ne!(sel.method, "ngram");
    }

    #[test]
    fn ngram_wins_when_its_acceptance_is_high() {
        let m = CostModel::paper_32b();
        let l = Ladder::build(
            &m,
            8,
            4,
            &[
                ("draft_small".to_string(), 0.6),
                ("ngram".to_string(), 0.9),
            ],
        );
        assert_eq!(l.select_initial().method, "ngram");
    }

    #[test]
    fn ranked_is_total_order() {
        let m = CostModel::paper_32b();
        let l = Ladder::build(&m, 8, 4, &profiled());
        let r = l.ranked();
        assert_eq!(r.len(), 3);
        assert_eq!(l.rank_of(&r[0].method), 0);
        assert_eq!(l.rank_of("nonexistent"), usize::MAX);
    }

    #[test]
    fn simulated_ladder_agrees_with_analytic() {
        let m = CostModel::paper_32b();
        let a = Ladder::build(&m, 8, 4, &profiled());
        let s = Ladder::build_simulated(&m, 8, 4, &profiled(), 4000, 42);
        for (ea, es) in a.entries.iter().zip(&s.entries) {
            // compare at a mid-grid acceptance point
            let ga = ea.speedup[9];
            let gs = es.speedup[9];
            let rel = (ga - gs).abs() / ga;
            assert!(rel < 0.25, "{}: analytic {ga:.2} vs simulated {gs:.2}", ea.method);
        }
    }

    #[test]
    fn blend_measured_shrinks_toward_evidence() {
        // no evidence: the prior stands
        assert!((blend_measured(0.4, 0.9, 0) - 0.4).abs() < 1e-12);
        // evidence equal to the pseudo-count: halfway
        let half = blend_measured(0.4, 0.9, PRIOR_PSEUDO_COUNT as u64);
        assert!((half - 0.65).abs() < 1e-12);
        // overwhelming evidence: converges to the measured rate
        assert!((blend_measured(0.4, 0.9, 1_000_000) - 0.9).abs() < 1e-3);
        // monotone in n
        assert!(blend_measured(0.4, 0.9, 100) > blend_measured(0.4, 0.9, 10));
    }

    #[test]
    fn interpolation_within_bounds() {
        let m = CostModel::paper_32b();
        let l = Ladder::build(&m, 8, 4, &profiled());
        let e = &l.entries[0];
        let lo = l.speedup_at(e, 0.0);
        let hi = l.speedup_at(e, 1.0);
        assert!((lo - e.speedup[0]).abs() < 1e-9);
        assert!((hi - *e.speedup.last().unwrap()).abs() < 1e-9);
        let mid = l.speedup_at(e, 0.52);
        assert!(mid >= lo && mid <= hi);
    }
}
