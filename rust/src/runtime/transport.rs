//! `RowTransport`: framed serialization of a slot's migration payload
//! for **cross-runtime** movement.
//!
//! In-process slot migration (admission catch-up, Fastest-of-N forks,
//! quarantine re-prefill) moves a request plus its verified-prefix KV
//! row through `KvCache::extract_row` / `insert_row` directly. A
//! multi-worker [`Cluster`](crate::serve::cluster::Cluster) moves the
//! same payload between *engines*, so it must survive a wire: this
//! module frames a [`MigrationPayload`] into a length-prefixed,
//! checksummed, versioned byte frame and decodes it back **byte-exact**
//! (floats round-trip through their bit patterns, never through text).
//!
//! Frame layout (all little-endian):
//!
//! ```text
//! offset 0   magic        u32   0x5350_5254 ("SPRT")
//! offset 4   version      u16   TRANSPORT_VERSION
//! offset 6   flags        u16   bit 0 = KV row present
//! offset 8   payload_len  u64
//! offset 16  payload      [payload_len bytes]
//! ...        checksum     u64   FNV-1a over bytes [0, 16 + payload_len)
//! ```
//!
//! Every integrity failure — bad magic, version mismatch, truncation,
//! length overrun, checksum mismatch, or a payload that does not parse
//! exactly — is a typed [`SpecError::TransportCorrupt`] (Degradable:
//! the payload still exists at the source, so the cluster retries the
//! transfer under [`RowTransport`]'s exponential-backoff budget before
//! escalating to the quarantine-style re-prefill path). Decoding never
//! panics on hostile bytes: every read is bounds-checked, exactly what
//! the seeded `transport=p` chaos site exercises with random bit flips.

use anyhow::Result;

use crate::engine::{Request, SpecError};
use crate::spec::AcceptanceStats;

use super::kv::KvRow;

/// Frame format version; bumped on any layout change. A frame with a
/// different version is typed corrupt (never mis-parsed).
pub const TRANSPORT_VERSION: u16 = 1;

/// Frame magic ("SPRT").
const MAGIC: u32 = 0x5350_5254;

/// Fixed header bytes ahead of the payload (magic, version, flags, len).
const HEADER: usize = 16;

/// Trailing checksum bytes.
const TRAILER: usize = 8;

/// Flag bit: the optional KV row is present.
const FLAG_ROW: u16 = 1;

/// Everything a slot needs to resume on another worker: the request
/// (id, prompt, verified sequence, budget, acceptance stats) and — when
/// the source engine exposes one — its verified-prefix KV row. Engines
/// without an extractable row (or a row lost to the fault being
/// recovered from) ship `row: None`; the destination re-materializes
/// the row through the ordinary prefill + catch-up replay, which is
/// byte-identical by construction.
#[derive(Clone, Debug)]
pub struct MigrationPayload {
    pub req: Request,
    pub row: Option<KvRow>,
}

impl MigrationPayload {
    /// A row-less payload (re-prefill on the destination).
    pub fn new(req: Request) -> Self {
        MigrationPayload { req, row: None }
    }

    /// The sampling-tape position the payload resumes from: generated
    /// tokens so far. The tape is keyed by (seed, request id, position)
    /// — never by slot or worker — which is why migration is lossless.
    pub fn tape_pos(&self) -> u64 {
        self.req.seq.len().saturating_sub(self.req.prompt.len()) as u64
    }
}

/// FNV-1a 64-bit over `data`.
fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn corrupt(detail: impl Into<String>) -> anyhow::Error {
    SpecError::TransportCorrupt { detail: detail.into() }.into()
}

/// Bounds-checked little-endian reader over a frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| corrupt(format!("payload truncated at byte {}", self.pos)))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| corrupt("i32 vec overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| corrupt("f32 vec overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32s(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Serialize/deserialize migration frames and account the retry ledger
/// for transfers that hit corruption in flight. One transport instance
/// serves a whole cluster; its counters feed `specactor_cluster_*`.
#[derive(Clone, Debug)]
pub struct RowTransport {
    /// Re-transmissions allowed per transfer beyond the first attempt;
    /// exhaustion escalates the typed `TransportCorrupt` to the caller
    /// (which falls back to re-prefill — still lossless).
    pub retry_budget: u32,
    /// Frames encoded and put on the wire (one per attempt).
    pub frames: u64,
    /// Frames that failed integrity checks on receive.
    pub corruptions: u64,
    /// Re-transmissions performed after a corrupt receive.
    pub retries: u64,
    /// Transfers abandoned after the retry budget (caller re-prefills).
    pub escalations: u64,
    /// Virtual backoff ticks accrued across retries (1, 2, 4, ... per
    /// attempt, capped at 32) — the cluster's recovery-cost ledger.
    pub backoff_ticks: u64,
}

impl Default for RowTransport {
    fn default() -> Self {
        RowTransport {
            retry_budget: 3,
            frames: 0,
            corruptions: 0,
            retries: 0,
            escalations: 0,
            backoff_ticks: 0,
        }
    }
}

impl RowTransport {
    pub fn new(retry_budget: u32) -> Self {
        RowTransport { retry_budget, ..Default::default() }
    }

    /// Frame `p` for the wire.
    pub fn encode(&self, p: &MigrationPayload) -> Vec<u8> {
        let mut payload = Vec::with_capacity(
            64 + 4 * (p.req.prompt.len() + p.req.seq.len())
                + p.row.as_ref().map(|r| 8 * r.k.len() + 32).unwrap_or(0),
        );
        put_u64(&mut payload, p.req.id);
        put_u64(&mut payload, p.req.budget as u64);
        payload.push(p.req.done as u8);
        put_u64(&mut payload, p.req.iterations);
        put_u64(&mut payload, p.req.accept.proposed);
        put_u64(&mut payload, p.req.accept.accepted);
        put_u64(&mut payload, p.req.accept.ewma.to_bits());
        put_u64(&mut payload, p.tape_pos());
        put_i32s(&mut payload, &p.req.prompt);
        put_i32s(&mut payload, &p.req.seq);
        if let Some(row) = &p.row {
            put_u32(&mut payload, row.n_layers as u32);
            put_u32(&mut payload, row.max_seq as u32);
            put_u32(&mut payload, row.n_heads as u32);
            put_u32(&mut payload, row.d_head as u32);
            payload.extend_from_slice(&row.len.to_le_bytes());
            put_f32s(&mut payload, &row.k);
            put_f32s(&mut payload, &row.v);
        }

        let flags: u16 = if p.row.is_some() { FLAG_ROW } else { 0 };
        let mut frame = Vec::with_capacity(HEADER + payload.len() + TRAILER);
        put_u32(&mut frame, MAGIC);
        frame.extend_from_slice(&TRANSPORT_VERSION.to_le_bytes());
        frame.extend_from_slice(&flags.to_le_bytes());
        put_u64(&mut frame, payload.len() as u64);
        frame.extend_from_slice(&payload);
        let sum = fnv1a(&frame);
        put_u64(&mut frame, sum);
        frame
    }

    /// Parse a frame back into a payload. Every integrity failure is a
    /// typed [`SpecError::TransportCorrupt`]; hostile bytes never panic.
    pub fn decode(&self, frame: &[u8]) -> Result<MigrationPayload> {
        if frame.len() < HEADER + TRAILER {
            return Err(corrupt(format!("frame too short ({} bytes)", frame.len())));
        }
        let mut hdr = Cursor::new(&frame[..HEADER]);
        if hdr.u32()? != MAGIC {
            return Err(corrupt("bad magic"));
        }
        let version = u16::from_le_bytes([frame[4], frame[5]]);
        hdr.take(2)?;
        if version != TRANSPORT_VERSION {
            return Err(corrupt(format!(
                "version mismatch: frame v{version}, expected v{TRANSPORT_VERSION}"
            )));
        }
        let flags = u16::from_le_bytes([frame[6], frame[7]]);
        hdr.take(2)?;
        let plen = hdr.u64()? as usize;
        if HEADER + plen + TRAILER != frame.len() {
            return Err(corrupt(format!(
                "length mismatch: header says {plen}, frame carries {}",
                frame.len().saturating_sub(HEADER + TRAILER)
            )));
        }
        let body_end = HEADER + plen;
        let want = u64::from_le_bytes(frame[body_end..].try_into().unwrap());
        let got = fnv1a(&frame[..body_end]);
        if want != got {
            return Err(corrupt(format!("checksum mismatch ({got:#018x} != {want:#018x})")));
        }

        let mut c = Cursor::new(&frame[HEADER..body_end]);
        let id = c.u64()?;
        let budget = c.u64()? as usize;
        let done = match c.u8()? {
            0 => false,
            1 => true,
            other => return Err(corrupt(format!("bad done byte {other}"))),
        };
        let iterations = c.u64()?;
        let (proposed, accepted) = (c.u64()?, c.u64()?);
        let accept = AcceptanceStats::from_ledger(proposed, accepted, f64::from_bits(c.u64()?));
        let tape_pos = c.u64()?;
        let prompt = c.i32_vec()?;
        let seq = c.i32_vec()?;
        if seq.len() < prompt.len() || seq[..prompt.len()] != prompt[..] {
            return Err(corrupt("sequence does not extend its prompt"));
        }
        if tape_pos != (seq.len() - prompt.len()) as u64 {
            return Err(corrupt(format!(
                "sampling-tape position {tape_pos} != generated {}",
                seq.len() - prompt.len()
            )));
        }
        let row = if flags & FLAG_ROW != 0 {
            let n_layers = c.u32()? as usize;
            let max_seq = c.u32()? as usize;
            let n_heads = c.u32()? as usize;
            let d_head = c.u32()? as usize;
            let len = i32::from_le_bytes(c.take(4)?.try_into().unwrap());
            let k = c.f32_vec()?;
            let v = c.f32_vec()?;
            if k.len() != v.len() {
                return Err(corrupt("row k/v length mismatch"));
            }
            Some(KvRow { n_layers, max_seq, n_heads, d_head, k, v, len })
        } else {
            None
        };
        if !c.done() {
            return Err(corrupt("trailing bytes after payload"));
        }
        let req = Request { id, prompt, seq, budget, done, accept, iterations };
        Ok(MigrationPayload { req, row })
    }

    /// Move `p` across `wire` (a function that may corrupt the frame in
    /// flight — identity in production, a seeded Bernoulli bit-flipper
    /// under `--chaos transport=p`). Each corrupt receive re-encodes
    /// from the source payload and retries under exponential backoff
    /// until the budget runs out, at which point the typed error
    /// escalates to the caller's re-prefill fallback.
    pub fn deliver(
        &mut self,
        p: &MigrationPayload,
        wire: &mut dyn FnMut(Vec<u8>) -> Vec<u8>,
    ) -> Result<MigrationPayload> {
        let mut attempt: u32 = 0;
        loop {
            self.frames += 1;
            let frame = wire(self.encode(p));
            match self.decode(&frame) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    let is_corrupt = e
                        .downcast_ref::<SpecError>()
                        .map(|s| matches!(s, SpecError::TransportCorrupt { .. }))
                        .unwrap_or(false);
                    if !is_corrupt {
                        return Err(e);
                    }
                    self.corruptions += 1;
                    if attempt >= self.retry_budget {
                        self.escalations += 1;
                        return Err(e);
                    }
                    self.backoff_ticks += 1u64 << attempt.min(5);
                    self.retries += 1;
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Severity;

    fn payload(row: bool) -> MigrationPayload {
        let mut req = Request::new(42, vec![1, 2, 3, 4], 64);
        req.seq.extend_from_slice(&[7, -9, 32000]);
        req.iterations = 5;
        req.accept.observe(8, 6);
        let row = row.then(|| KvRow {
            n_layers: 2,
            max_seq: 8,
            n_heads: 2,
            d_head: 4,
            k: vec![0.5, -1.25, f32::NAN, 3.0e-20, 1.0, 0.0, -0.0, 9.9],
            v: vec![1.0; 8],
            len: 6,
        });
        MigrationPayload { req, row }
    }

    fn assert_same(a: &MigrationPayload, b: &MigrationPayload) {
        assert_eq!(a.req.id, b.req.id);
        assert_eq!(a.req.prompt, b.req.prompt);
        assert_eq!(a.req.seq, b.req.seq);
        assert_eq!(a.req.budget, b.req.budget);
        assert_eq!(a.req.done, b.req.done);
        assert_eq!(a.req.iterations, b.req.iterations);
        assert_eq!(a.req.accept.proposed, b.req.accept.proposed);
        assert_eq!(a.req.accept.accepted, b.req.accept.accepted);
        assert_eq!(a.req.accept.ewma.to_bits(), b.req.accept.ewma.to_bits());
        match (&a.row, &b.row) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.n_layers, y.n_layers);
                assert_eq!(x.max_seq, y.max_seq);
                assert_eq!(x.n_heads, y.n_heads);
                assert_eq!(x.d_head, y.d_head);
                assert_eq!(x.len, y.len);
                // bit-exact, including NaN payloads and signed zeros
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&x.k), bits(&y.k));
                assert_eq!(bits(&x.v), bits(&y.v));
            }
            _ => panic!("row presence mismatch"),
        }
    }

    #[test]
    fn roundtrip_is_byte_exact() {
        let t = RowTransport::default();
        for with_row in [false, true] {
            let p = payload(with_row);
            let frame = t.encode(&p);
            let q = t.decode(&frame).unwrap();
            assert_same(&p, &q);
            // and the re-encoded frame is identical (canonical encoding)
            assert_eq!(frame, t.encode(&q));
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected_and_typed() {
        let t = RowTransport::default();
        let p = payload(true);
        let frame = t.encode(&p);
        // flip one bit per byte across the whole frame: decode must fail
        // with a typed Degradable TransportCorrupt and must never panic
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 1 << (i % 8);
            let err = t.decode(&bad).expect_err("corruption must not decode");
            let se = err.downcast_ref::<SpecError>().expect("typed error");
            assert!(matches!(se, SpecError::TransportCorrupt { .. }));
            assert_eq!(se.severity(), Severity::Degradable);
        }
    }

    #[test]
    fn truncation_and_version_mismatch_are_typed() {
        let t = RowTransport::default();
        let frame = t.encode(&payload(true));
        for cut in [0, 1, HEADER - 1, HEADER, frame.len() - 1] {
            assert!(t.decode(&frame[..cut]).is_err());
        }
        let mut vbad = frame.clone();
        vbad[4] = TRANSPORT_VERSION as u8 + 1; // bump version field
        let err = t.decode(&vbad).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "got: {err:#}");
    }

    #[test]
    fn deliver_retries_through_transient_corruption() {
        let mut t = RowTransport::new(3);
        let p = payload(true);
        let mut drops = 2; // corrupt the first two attempts
        let out = t
            .deliver(&p, &mut |mut f: Vec<u8>| {
                if drops > 0 {
                    drops -= 1;
                    let n = f.len();
                    f[n / 2] ^= 0x40;
                }
                f
            })
            .unwrap();
        assert_same(&p, &out);
        assert_eq!(t.corruptions, 2);
        assert_eq!(t.retries, 2);
        assert_eq!(t.frames, 3);
        assert_eq!(t.escalations, 0);
        assert_eq!(t.backoff_ticks, 1 + 2, "exponential: 1 then 2 ticks");
    }

    #[test]
    fn deliver_escalates_after_the_budget() {
        let mut t = RowTransport::new(2);
        let p = payload(false);
        let err = t
            .deliver(&p, &mut |mut f: Vec<u8>| {
                let n = f.len();
                f[n - 1] ^= 1; // checksum never verifies
                f
            })
            .expect_err("permanent corruption must escalate");
        let se = err.downcast_ref::<SpecError>().expect("typed");
        assert!(matches!(se, SpecError::TransportCorrupt { .. }));
        assert_eq!(t.frames, 3, "initial attempt + 2 retries");
        assert_eq!(t.corruptions, 3);
        assert_eq!(t.retries, 2);
        assert_eq!(t.escalations, 1);
    }

    #[test]
    fn tape_position_guard_catches_spliced_frames() {
        // a frame whose seq/prompt relationship is inconsistent (e.g. a
        // spliced payload that still checksums) must not decode: rebuild
        // a frame with a lying tape_pos and a fresh checksum
        let t = RowTransport::default();
        let p = payload(false);
        let mut frame = t.encode(&p);
        // tape_pos lives after id/budget/done/iterations/accept(3):
        // 8+8+1+8 + 24 = 49 bytes into the payload
        let off = HEADER + 49;
        frame[off..off + 8].copy_from_slice(&999u64.to_le_bytes());
        let body_end = frame.len() - TRAILER;
        let sum = fnv1a(&frame[..body_end]);
        frame[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = t.decode(&frame).unwrap_err();
        assert!(err.to_string().contains("sampling-tape position"), "got: {err:#}");
    }
}
