//! Host-side KV caches and the "KVCache scale" primitive (§4.3).
//!
//! CPU-PJRT returns executables' results as a single tuple buffer (no
//! device-side untupling in xla_extension 0.5.1), so caches round-trip
//! through host memory between steps. The cache layout matches the lowered
//! executables: `[L, b, S, h, dh]` f32, one tensor for keys and one for
//! values. Under the incremental-KV protocol ([`scatter_window`] /
//! `KvProtocol::Window`, see PERF.md) only the entries written by a step
//! come back from the device; the host cache is the source of truth.
//!
//! [`scatter_window`]: KvCache::scatter_window
//!
//! `extract_row` / `insert_row` implement per-request cache migration: when
//! Fastest-of-N deploys an extra verifier for a straggler request, its
//! cache rows are copied over to the new worker (the paper transfers the
//! tail and recomputes; at our scale a straight copy exercises the same
//! code path).

use anyhow::{bail, Result};

/// One model's KV cache at a fixed batch bucket.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub batch: usize,
    pub max_seq: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Per-slot number of valid cache positions (`lens` argument).
    pub lens: Vec<i32>,
}

impl KvCache {
    pub fn new(n_layers: usize, batch: usize, max_seq: usize, n_heads: usize, d_head: usize) -> Self {
        let n = n_layers * batch * max_seq * n_heads * d_head;
        KvCache {
            n_layers,
            batch,
            max_seq,
            n_heads,
            d_head,
            k: vec![0.0; n],
            v: vec![0.0; n],
            lens: vec![0; batch],
        }
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.n_layers, self.batch, self.max_seq, self.n_heads, self.d_head]
    }

    pub fn elems(&self) -> usize {
        self.k.len()
    }

    /// Bytes held by this cache (both k and v).
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * std::mem::size_of::<f32>()
    }

    fn row_stride(&self) -> usize {
        self.max_seq * self.n_heads * self.d_head
    }

    fn layer_stride(&self) -> usize {
        self.batch * self.row_stride()
    }

    /// Copy one request's cache rows (all layers) out.
    pub fn extract_row(&self, slot: usize) -> Result<KvRow> {
        if slot >= self.batch {
            bail!("slot {slot} out of range (batch {})", self.batch);
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        let mut k = Vec::with_capacity(self.n_layers * rs);
        let mut v = Vec::with_capacity(self.n_layers * rs);
        for l in 0..self.n_layers {
            let off = l * ls + slot * rs;
            k.extend_from_slice(&self.k[off..off + rs]);
            v.extend_from_slice(&self.v[off..off + rs]);
        }
        Ok(KvRow {
            n_layers: self.n_layers,
            max_seq: self.max_seq,
            n_heads: self.n_heads,
            d_head: self.d_head,
            k,
            v,
            len: self.lens[slot],
        })
    }

    /// Insert one request's cache rows (all layers) into `slot`.
    pub fn insert_row(&mut self, slot: usize, row: &KvRow) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} out of range (batch {})", self.batch);
        }
        if row.n_layers != self.n_layers
            || row.max_seq != self.max_seq
            || row.n_heads != self.n_heads
            || row.d_head != self.d_head
        {
            bail!("cache row geometry mismatch");
        }
        let want = self.n_layers * self.row_stride();
        if row.k.len() != want || row.v.len() != want {
            bail!(
                "cache row data len {}/{} != L*S*h*dh = {want}",
                row.k.len(),
                row.v.len()
            );
        }
        if row.len < 0 || row.len as usize > self.max_seq {
            bail!("cache row len {} outside [0, {}]", row.len, self.max_seq);
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        for l in 0..self.n_layers {
            let off = l * ls + slot * rs;
            self.k[off..off + rs].copy_from_slice(&row.k[l * rs..(l + 1) * rs]);
            self.v[off..off + rs].copy_from_slice(&row.v[l * rs..(l + 1) * rs]);
        }
        self.lens[slot] = row.len;
        Ok(())
    }

    /// Scatter one step's freshly-written KV entries into the cache
    /// (incremental-KV protocol, see PERF.md).
    ///
    /// `k_win`/`v_win` are row-major `[L, b, w, h, dh]` — the entries the
    /// executable wrote at each slot's `lens[i]..lens[i]+w`. Both source
    /// block and destination range are contiguous `w*h*dh` runs, so each
    /// (layer, slot) pair is a single `copy_from_slice`. `lens` is NOT
    /// advanced — the engine owns it (rollbacks on rejection reuse the
    /// same positions, exactly like the on-device scatter did).
    pub fn scatter_window(&mut self, k_win: &[f32], v_win: &[f32], w: usize) -> Result<()> {
        let hd = self.n_heads * self.d_head;
        let ws = w * hd;
        if k_win.len() != self.n_layers * self.batch * ws || v_win.len() != k_win.len() {
            bail!(
                "kv window len {}/{} != L*b*w*h*dh = {}",
                k_win.len(),
                v_win.len(),
                self.n_layers * self.batch * ws
            );
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        for (slot, &l) in self.lens.iter().enumerate() {
            if l < 0 || (l as usize) + w > self.max_seq {
                bail!("slot {slot}: scatter at {l}+{w} exceeds max_seq {}", self.max_seq);
            }
        }
        for l in 0..self.n_layers {
            for slot in 0..self.batch {
                let src = (l * self.batch + slot) * ws;
                let dst = l * ls + slot * rs + self.lens[slot] as usize * hd;
                self.k[dst..dst + ws].copy_from_slice(&k_win[src..src + ws]);
                self.v[dst..dst + ws].copy_from_slice(&v_win[src..src + ws]);
            }
        }
        Ok(())
    }

    /// Per-row-width variant of [`scatter_window`] for **fused ragged**
    /// verify steps (one target step per round over slots with mixed draft
    /// windows): `k_win`/`v_win` are still row-major `[L, b, w, h, dh]` at
    /// the uniform step window `w` the executable ran at, but only the
    /// leading `widths[slot]` positions of each row are scattered — a
    /// short row's padded tail never touches its cache, and zero-width
    /// rows (free slots riding the fused step as padding) are skipped
    /// entirely. Same guard discipline as [`clear_row`]/[`insert_row`]:
    /// malformed geometry or `lens[slot] + widths[slot] > max_seq` is an
    /// error, never a panic.
    ///
    /// `scatter_window(k, v, w)` ≡ `scatter_window_rows(k, v, w, [w; b])`
    /// byte-for-byte (pinned by `scatter_window_rows_equals_uniform`).
    ///
    /// [`scatter_window`]: KvCache::scatter_window
    /// [`clear_row`]: KvCache::clear_row
    /// [`insert_row`]: KvCache::insert_row
    pub fn scatter_window_rows(
        &mut self,
        k_win: &[f32],
        v_win: &[f32],
        w: usize,
        widths: &[usize],
    ) -> Result<()> {
        let hd = self.n_heads * self.d_head;
        let ws = w * hd;
        if k_win.len() != self.n_layers * self.batch * ws || v_win.len() != k_win.len() {
            bail!(
                "kv window len {}/{} != L*b*w*h*dh = {}",
                k_win.len(),
                v_win.len(),
                self.n_layers * self.batch * ws
            );
        }
        if widths.len() != self.batch {
            bail!("widths len {} != batch {}", widths.len(), self.batch);
        }
        for (slot, (&wi, &l)) in widths.iter().zip(self.lens.iter()).enumerate() {
            if wi == 0 {
                continue; // padding row: nothing scattered, lens untouched
            }
            if wi > w {
                bail!("slot {slot}: row width {wi} exceeds step window {w}");
            }
            if l < 0 || (l as usize) + wi > self.max_seq {
                bail!("slot {slot}: scatter at {l}+{wi} exceeds max_seq {}", self.max_seq);
            }
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        for l in 0..self.n_layers {
            for slot in 0..self.batch {
                let n = widths[slot] * hd;
                if n == 0 {
                    continue;
                }
                let src = (l * self.batch + slot) * ws;
                let dst = l * ls + slot * rs + self.lens[slot] as usize * hd;
                self.k[dst..dst + n].copy_from_slice(&k_win[src..src + n]);
                self.v[dst..dst + n].copy_from_slice(&v_win[src..src + n]);
            }
        }
        Ok(())
    }

    /// Clear one slot (request finished/retired; the slot becomes free
    /// padding until the next admission reuses it).
    pub fn clear_row(&mut self, slot: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("slot {slot} out of range (batch {})", self.batch);
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        for l in 0..self.n_layers {
            let off = l * ls + slot * rs;
            self.k[off..off + rs].fill(0.0);
            self.v[off..off + rs].fill(0.0);
        }
        self.lens[slot] = 0;
        Ok(())
    }

    /// Move one request's rows from `from` to `to` and clear the source —
    /// the compaction primitive for slot defragmentation (e.g. packing
    /// live sequences into a smaller batch bucket). `copy_within` per
    /// (layer, slot), no allocation.
    pub fn move_row(&mut self, from: usize, to: usize) -> Result<()> {
        if from >= self.batch || to >= self.batch {
            bail!("move_row {from}->{to} out of range (batch {})", self.batch);
        }
        if from == to {
            return Ok(());
        }
        let rs = self.row_stride();
        let ls = self.layer_stride();
        for l in 0..self.n_layers {
            let src = l * ls + from * rs;
            let dst = l * ls + to * rs;
            self.k.copy_within(src..src + rs, dst);
            self.v.copy_within(src..src + rs, dst);
        }
        self.lens[to] = self.lens[from];
        self.clear_row(from)
    }
}

/// One request's extracted cache (all layers), used for cache migration
/// between workers / batch buckets.
#[derive(Clone, Debug)]
pub struct KvRow {
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: i32,
}

impl KvRow {
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_cache() -> KvCache {
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in c.v.iter_mut().enumerate() {
            *x = -(i as f32);
        }
        c.lens = vec![1, 2, 3];
        c
    }

    #[test]
    fn extract_insert_roundtrip() {
        let c = filled_cache();
        let row = c.extract_row(1).unwrap();
        assert_eq!(row.len, 2);
        let mut c2 = KvCache::new(2, 3, 4, 1, 2);
        c2.insert_row(2, &row).unwrap();
        let row2 = c2.extract_row(2).unwrap();
        assert_eq!(row.k, row2.k);
        assert_eq!(row.v, row2.v);
        assert_eq!(c2.lens[2], 2);
    }

    #[test]
    fn extract_row_is_layer_contiguous() {
        let c = filled_cache();
        let row = c.extract_row(0).unwrap();
        // layer 0 row 0 starts at 0; layer 1 row 0 starts at layer_stride
        let rs = 4 * 1 * 2;
        let ls = 3 * rs;
        assert_eq!(row.k[0], 0.0);
        assert_eq!(row.k[rs], ls as f32);
    }

    #[test]
    fn clear_row_zeroes() {
        let mut c = filled_cache();
        c.clear_row(1).unwrap();
        let row = c.extract_row(1).unwrap();
        assert!(row.k.iter().all(|&x| x == 0.0));
        assert_eq!(c.lens[1], 0);
        // neighbours untouched
        assert!(c.extract_row(0).unwrap().k.iter().any(|&x| x != 0.0));
        // out-of-range slot is an error, not a panic (serve-loop safety)
        assert!(c.clear_row(99).is_err());
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let c = filled_cache();
        let row = c.extract_row(0).unwrap();
        let mut other = KvCache::new(2, 3, 8, 1, 2);
        assert!(other.insert_row(0, &row).is_err());
        assert!(c.extract_row(99).is_err());
    }

    #[test]
    fn corrupt_row_data_rejected() {
        // geometry fields match but the payload is short / len is bogus —
        // a bad manifest or truncated migration must error, not panic.
        let mut c = filled_cache();
        let mut row = c.extract_row(0).unwrap();
        row.k.truncate(3);
        assert!(c.insert_row(1, &row).is_err());
        let mut row2 = c.extract_row(0).unwrap();
        row2.len = 999;
        assert!(c.insert_row(1, &row2).is_err());
        let mut row3 = c.extract_row(0).unwrap();
        row3.len = -1;
        assert!(c.insert_row(1, &row3).is_err());
    }

    #[test]
    fn scatter_rejects_negative_lens() {
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        c.lens = vec![-1, 0, 0];
        let win = vec![0.0f32; 2 * 3 * 2]; // w=1
        assert!(c.scatter_window(&win, &win, 1).is_err());
    }

    #[test]
    fn move_row_compacts() {
        let mut c = filled_cache();
        let want = c.extract_row(2).unwrap();
        c.move_row(2, 0).unwrap();
        let got = c.extract_row(0).unwrap();
        assert_eq!(got.k, want.k);
        assert_eq!(got.v, want.v);
        assert_eq!(c.lens[0], 3);
        // source cleared
        assert!(c.extract_row(2).unwrap().k.iter().all(|&x| x == 0.0));
        assert_eq!(c.lens[2], 0);
        // no-op and bounds
        c.move_row(1, 1).unwrap();
        assert!(c.move_row(0, 99).is_err());
    }

    #[test]
    fn bytes_accounting() {
        let c = KvCache::new(2, 3, 4, 1, 2);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 4 * 1 * 2 * 4);
    }

    #[test]
    fn scatter_window_writes_at_lens() {
        // L=2, b=3, S=4, h=1, dh=2; scatter w=2 entries per slot.
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        c.lens = vec![0, 1, 2];
        let ws = 4; // w * h * dh = 2 * 1 * 2
        let n = 2 * 3 * ws; // L * b * ws
        let k_win: Vec<f32> = (0..n).map(|i| 1000.0 + i as f32).collect();
        let v_win: Vec<f32> = (0..n).map(|i| -(1000.0 + i as f32)).collect();
        c.scatter_window(&k_win, &v_win, 2).unwrap();
        let rs = 8; // S * h * dh = 4 * 1 * 2
        let ls = 3 * rs;
        for l in 0..2usize {
            for slot in 0..3usize {
                let src = (l * 3 + slot) * ws;
                let dst = l * ls + slot * rs + c.lens[slot] as usize * 2;
                assert_eq!(&c.k[dst..dst + ws], &k_win[src..src + ws], "k l={l} slot={slot}");
                assert_eq!(&c.v[dst..dst + ws], &v_win[src..src + ws], "v l={l} slot={slot}");
            }
        }
        // untouched positions stay zero (slot 0 wrote rows 0..2 of 4)
        assert!(c.k[ws..rs].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scatter_window_equals_full_replacement() {
        // Scattering the window into a copy of the pre-step cache must
        // reproduce exactly what the full-cache protocol would hand back.
        let pre = filled_cache(); // lens = [1, 2, 3], S = 4
        let mut full = pre.clone();
        // simulate the device-side dynamic_update_slice for w=1
        let w = 1;
        let hd = 2; // h * dh
        let ws = w * hd;
        let k_win: Vec<f32> = (0..2 * 3 * ws).map(|i| 7.5 + i as f32).collect();
        let v_win: Vec<f32> = k_win.iter().map(|x| -x).collect();
        let rs = 4 * hd;
        let ls = 3 * rs;
        for l in 0..2usize {
            for slot in 0..3usize {
                let src = (l * 3 + slot) * ws;
                let dst = l * ls + slot * rs + pre.lens[slot] as usize * hd;
                full.k[dst..dst + ws].copy_from_slice(&k_win[src..src + ws]);
                full.v[dst..dst + ws].copy_from_slice(&v_win[src..src + ws]);
            }
        }
        let mut inc = pre.clone();
        inc.scatter_window(&k_win, &v_win, w).unwrap();
        assert_eq!(inc.k, full.k);
        assert_eq!(inc.v, full.v);
    }

    #[test]
    fn scatter_window_rows_equals_uniform() {
        // widths all = w must be byte-identical to the uniform scatter
        let mut a = filled_cache(); // lens [1, 2, 3], S=4 -> w=1 fits all
        let mut b = a.clone();
        let hd = 2;
        let n = 2 * 3 * hd; // L * b * w*h*dh, w=1
        let k_win: Vec<f32> = (0..n).map(|i| 500.0 + i as f32).collect();
        let v_win: Vec<f32> = k_win.iter().map(|x| -x).collect();
        a.scatter_window(&k_win, &v_win, 1).unwrap();
        b.scatter_window_rows(&k_win, &v_win, 1, &[1, 1, 1]).unwrap();
        assert_eq!(a.k, b.k);
        assert_eq!(a.v, b.v);
        assert_eq!(a.lens, b.lens);
    }

    #[test]
    fn scatter_window_rows_short_rows_keep_their_tail() {
        // ragged widths: slot 0 takes both positions, slot 1 one, slot 2
        // none — the skipped tails/rows must stay byte-identical to the
        // pre-scatter cache.
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = 0.25 * i as f32;
        }
        for (i, x) in c.v.iter_mut().enumerate() {
            *x = -0.25 * i as f32;
        }
        c.lens = vec![0, 1, 2];
        let pre = c.clone();
        let hd = 2;
        let ws = 2 * hd; // w=2
        let n = 2 * 3 * ws;
        let k_win: Vec<f32> = (0..n).map(|i| 9000.0 + i as f32).collect();
        let v_win: Vec<f32> = k_win.iter().map(|x| -x).collect();
        c.scatter_window_rows(&k_win, &v_win, 2, &[2, 1, 0]).unwrap();
        let rs = 4 * hd;
        let ls = 3 * rs;
        for l in 0..2usize {
            // slot 0: both positions written at lens 0
            let src = (l * 3) * ws;
            let dst = l * ls;
            assert_eq!(&c.k[dst..dst + ws], &k_win[src..src + ws]);
            // slot 1: exactly one position written at lens 1, tail kept
            let src = (l * 3 + 1) * ws;
            let dst = l * ls + rs + hd;
            assert_eq!(&c.k[dst..dst + hd], &k_win[src..src + hd]);
            assert_eq!(&c.k[dst + hd..dst + 2 * hd], &pre.k[dst + hd..dst + 2 * hd]);
            // slot 2: zero-width row untouched
            let dst = l * ls + 2 * rs;
            assert_eq!(&c.k[dst..dst + rs], &pre.k[dst..dst + rs]);
            assert_eq!(&c.v[dst..dst + rs], &pre.v[dst..dst + rs]);
        }
    }

    #[test]
    fn scatter_window_rows_guards() {
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        c.lens = vec![3, 0, 0];
        let win = vec![0.0f32; 2 * 3 * 2 * 2]; // w=2
        // slot 0: 3 + 2 > max_seq 4 -> error
        assert!(c.scatter_window_rows(&win, &win, 2, &[2, 1, 1]).is_err());
        // zero width skips the over-full row entirely
        assert!(c.scatter_window_rows(&win, &win, 2, &[0, 1, 1]).is_ok());
        // width above the step window
        assert!(c.scatter_window_rows(&win, &win, 2, &[0, 3, 0]).is_err());
        // widths length mismatch
        assert!(c.scatter_window_rows(&win, &win, 2, &[1, 1]).is_err());
        // negative lens on a written row
        c.lens = vec![0, -1, 0];
        assert!(c.scatter_window_rows(&win, &win, 2, &[0, 1, 0]).is_err());
        // ...but not on a skipped row
        assert!(c.scatter_window_rows(&win, &win, 2, &[1, 0, 1]).is_ok());
        // payload geometry mismatch
        assert!(c.scatter_window_rows(&win[..4], &win, 2, &[0, 0, 0]).is_err());
    }

    #[test]
    fn scatter_window_rejects_bad_geometry() {
        let mut c = KvCache::new(2, 3, 4, 1, 2);
        let ok = vec![0.0f32; 2 * 3 * 2]; // w=1
        assert!(c.scatter_window(&ok, &ok[..4], 1).is_err()); // v too short
        assert!(c.scatter_window(&ok, &ok, 2).is_err()); // len != L*b*w*h*dh
        c.lens = vec![3, 0, 0];
        let win2 = vec![0.0f32; 2 * 3 * 2 * 2];
        assert!(c.scatter_window(&win2, &win2, 2).is_err()); // 3+2 > S=4
    }
}
