//! Runtime layer: PJRT client wrapper, artifact/manifest registry, and the
//! host-side KV-cache pool. Loads `artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py` and executes them on the request path — python
//! never runs at serving time.

pub mod client;
pub mod kv;
pub mod manifest;
pub mod transport;

pub use client::{InFlightStep, Runtime, RuntimeStats, RuntimeStatsSnapshot, StepOut};
pub use kv::{KvCache, KvRow};
pub use manifest::{ArtifactKey, FnKind, KvProtocol, Manifest, ModelInfo};
pub use transport::{MigrationPayload, RowTransport, TRANSPORT_VERSION};
