//! `artifacts/manifest.json` — the python→rust contract.
//!
//! The manifest lists every AOT-lowered executable (model, fn, batch
//! bucket, draft window), per-model configs, and the weight parameter
//! order. See `python/compile/aot.py` for the writer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Which lowered entrypoint an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FnKind {
    /// `prefill(tokens[b, P]) -> (last_logits, k, v)` with a fresh cache.
    Prefill,
    /// `step(tokens[b, w], lens[b], k, v) -> (logits[b, w, V], k', v')`.
    /// `w = 1` decodes; `w > 1` verifies a draft window.
    Step,
}

impl FnKind {
    pub fn parse(s: &str) -> Result<FnKind> {
        match s {
            "prefill" => Ok(FnKind::Prefill),
            "step" => Ok(FnKind::Step),
            other => bail!("unknown fn kind {other:?}"),
        }
    }
}

/// How the lowered executables hand KV state back to the host.
///
/// `Window` is the incremental-KV protocol (PERF.md): step/prefill return
/// only the `[L, b, w, h, dh]` entries written this call and the runtime
/// scatters them into the host cache at each slot's `lens..lens+w`, so the
/// device→host KV traffic is O(w) per step instead of O(max_seq). `Full`
/// is the legacy whole-cache return, kept so old artifact sets still load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvProtocol {
    /// Executables return full `[L, b, S, h, dh]` caches.
    #[default]
    Full,
    /// Executables return only the written `[L, b, w, h, dh]` window.
    Window,
}

impl KvProtocol {
    pub fn parse(s: &str) -> Result<KvProtocol> {
        match s {
            "full" => Ok(KvProtocol::Full),
            "window" => Ok(KvProtocol::Window),
            other => bail!("unknown kv_protocol {other:?}"),
        }
    }
}

/// Key identifying one executable.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArtifactKey {
    pub model: String,
    pub kind: FnKind,
    pub batch: usize,
    /// draft window for Step; prompt length for Prefill.
    pub window: usize,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub key: ArtifactKey,
    pub file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub weights_file: PathBuf,
    pub weight_names: Vec<String>,
}

impl ModelInfo {
    /// KV-cache element count for one of k/v at batch `b`:
    /// `[L, b, S, h, dh]` f32.
    pub fn cache_elems(&self, batch: usize) -> usize {
        self.n_layers * batch * self.max_seq * self.n_heads * self.d_head
    }

    /// KV-cache dims for one of k/v at batch `b`.
    pub fn cache_dims(&self, batch: usize) -> [usize; 5] {
        [self.n_layers, batch, self.max_seq, self.n_heads, self.d_head]
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// KV hand-back protocol the artifacts were lowered with (absent in
    /// pre-v2 manifests, which implies [`KvProtocol::Full`]).
    pub kv_protocol: KvProtocol,
    pub eos_id: i32,
    pub pad_id: i32,
    pub reserved: i32,
    pub noisy_band_lo: i32,
    pub prompt_len: usize,
    pub batch_buckets: Vec<usize>,
    pub windows: Vec<usize>,
    pub target: String,
    pub drafters: Vec<String>,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<ArtifactKey, ArtifactEntry>,
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: missing numeric field {key:?}"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .as_str()
        .ok_or_else(|| anyhow!("manifest: missing string field {key:?}"))?
        .to_string())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = Json::parse(&raw).map_err(|e| anyhow!("manifest.json: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in j
            .get("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: models not an object"))?
        {
            let weight_names = m
                .get("weight_names")
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: weight_names"))?
                .iter()
                .map(|x| x.as_str().unwrap_or_default().to_string())
                .collect();
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    vocab: get_usize(m, "vocab")?,
                    d_model: get_usize(m, "d_model")?,
                    n_layers: get_usize(m, "n_layers")?,
                    n_heads: get_usize(m, "n_heads")?,
                    d_head: get_usize(m, "d_head")?,
                    d_ff: get_usize(m, "d_ff")?,
                    max_seq: get_usize(m, "max_seq")?,
                    weights_file: dir.join(get_str(m, "weights_file")?),
                    weight_names,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: artifacts not an array"))?
        {
            let kind = FnKind::parse(&get_str(a, "fn")?)?;
            let key = ArtifactKey {
                model: get_str(a, "model")?,
                kind,
                batch: get_usize(a, "batch")?,
                window: get_usize(a, "window")?,
            };
            let file = dir.join(get_str(a, "file")?);
            if !file.exists() {
                bail!("manifest lists missing artifact {file:?}");
            }
            artifacts.insert(key.clone(), ArtifactEntry { key, file });
        }

        let drafters = j
            .get("drafters")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: drafters"))?
            .iter()
            .map(|x| x.as_str().unwrap_or_default().to_string())
            .collect();

        let kv_protocol = match j.get("kv_protocol").as_str() {
            Some(s) => KvProtocol::parse(s)?,
            None => KvProtocol::Full,
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            kv_protocol,
            eos_id: get_usize(&j, "eos_id")? as i32,
            pad_id: get_usize(&j, "pad_id")? as i32,
            reserved: get_usize(&j, "reserved")? as i32,
            noisy_band_lo: get_usize(&j, "noisy_band_lo")? as i32,
            prompt_len: get_usize(&j, "prompt_len")?,
            batch_buckets: j
                .get("batch_buckets")
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: batch_buckets"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            windows: j
                .get("windows")
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: windows"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            target: get_str(&j, "target")?,
            drafters,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    pub fn artifact(&self, key: &ArtifactKey) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(key)
            .ok_or_else(|| anyhow!("no artifact lowered for {key:?}"))
    }

    /// Smallest lowered batch bucket that fits `n` live requests.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| anyhow!("batch {n} exceeds largest lowered bucket"))
    }

    /// Deterministic synthetic prompt for request `id`: `prompt_len`
    /// tokens from the non-reserved vocab range, offset per request so
    /// different requests exercise different acceptance behaviour. Shared
    /// by the serve CLI/demo/bench drivers and the integration tests.
    pub fn synth_prompt(&self, id: u64) -> Result<Vec<i32>> {
        // i64 arithmetic with the id reduced first: `(id * 83) % range`
        // overflows i32 for id >= i32::MAX/83, and long-running open-loop
        // serving reaches such ids. `((id % range) * 83) % range` is the
        // same residue without the overflow.
        let vocab = self.model(&self.target)?.vocab as i64;
        let reserved = self.reserved as i64;
        let range = vocab - reserved;
        if range <= 0 {
            bail!("manifest: vocab {vocab} leaves no tokens above reserved {reserved}");
        }
        let start = reserved + ((id % range as u64) as i64 * 83) % range;
        Ok((0..self.prompt_len as i64)
            .map(|j| (reserved + (start + j) % range) as i32)
            .collect())
    }

    /// Largest per-request generation budget the engine can serve: cache
    /// capacity minus the prompt and headroom for the largest lowered
    /// step window (a plan group's verify step spans the whole bucket, so
    /// every row must satisfy `lens + w <= max_seq` whatever window any
    /// group runs).
    pub fn max_new_tokens(&self) -> Result<usize> {
        let wmax = self.windows.iter().copied().max().unwrap_or(1).max(2);
        Ok(self.model(&self.target)?.max_seq - self.prompt_len - wmax)
    }

    /// Draft windows whose verify step is lowered exactly: `w - 1` for
    /// each lowered step window `w >= 2`. The shared derivation behind
    /// the serve replanner's and the reconfigurator's window grids (the
    /// engine additionally rounds intermediate windows up at verify
    /// time — see `Worker::verify_window_for`).
    pub fn draft_windows(&self) -> Vec<usize> {
        self.windows.iter().filter(|&&w| w >= 2).map(|w| w - 1).collect()
    }

    /// Largest lowered draft window <= `w` (planner may ask for any w).
    pub fn window_for(&self, w: usize) -> Result<usize> {
        self.windows
            .iter()
            .copied()
            .filter(|&x| x <= w.max(1))
            .max()
            .ok_or_else(|| anyhow!("no lowered window <= {w}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests against real artifacts live in rust/tests/;
    // here we test pure logic on a synthetic manifest value.

    #[test]
    fn fn_kind_parse() {
        assert_eq!(FnKind::parse("prefill").unwrap(), FnKind::Prefill);
        assert_eq!(FnKind::parse("step").unwrap(), FnKind::Step);
        assert!(FnKind::parse("bogus").is_err());
    }

    #[test]
    fn kv_protocol_parse_and_default() {
        assert_eq!(KvProtocol::parse("full").unwrap(), KvProtocol::Full);
        assert_eq!(KvProtocol::parse("window").unwrap(), KvProtocol::Window);
        assert!(KvProtocol::parse("bogus").is_err());
        // pre-v2 manifests (no kv_protocol key) must imply Full
        assert_eq!(KvProtocol::default(), KvProtocol::Full);
    }

    #[test]
    fn cache_dims() {
        let m = ModelInfo {
            name: "m".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_ff: 256,
            max_seq: 256,
            weights_file: PathBuf::new(),
            weight_names: vec![],
        };
        assert_eq!(m.cache_dims(8), [4, 8, 256, 4, 32]);
        assert_eq!(m.cache_elems(8), 4 * 8 * 256 * 4 * 32);
    }
}
