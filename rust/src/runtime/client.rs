//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! Mirrors a serving engine's model-executor layer: one [`Runtime`] per
//! worker process/thread owns a PJRT client, lazily compiles the
//! (model, fn, batch-bucket, window) executables it needs, keeps them in a
//! cache, and holds each model's weights as literals uploaded with every
//! call (the CPU client's `execute` copies host literals to device
//! internally; weights are ~100 KiB so this is noise next to the KV cache).
//!
//! The interchange format is HLO **text** — see DESIGN.md and
//! /opt/xla-example/README.md for why serialized protos don't work.
//!
//! KV hand-back follows the manifest's [`KvProtocol`]: under `Window` (the
//! shipped protocol) executables return only the `[L, b, w, h, dh]` cache
//! entries written that call and the runtime scatters them into the host
//! cache, so steady-state device→host KV traffic is O(w) per step instead
//! of O(max_seq) — see PERF.md.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use xla::FromRawBytes;

use super::kv::KvCache;
use super::manifest::{ArtifactKey, FnKind, KvProtocol, Manifest, ModelInfo};

/// Output of one prefill/step execution.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Row-major logits. Prefill: `[b, vocab]`; Step: `[b, w, vocab]`.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub window: usize,
    pub vocab: usize,
    /// Per-row REAL window under a fused ragged step
    /// ([`Runtime::step_ragged`]): positions `widths[i]..window` of row `i`
    /// were computed from padding inputs and are garbage. `None` = uniform
    /// step, every position of every row is real.
    pub widths: Option<Vec<usize>>,
}

impl StepOut {
    /// Logits for batch slot `i`, window position `j` — RAW positional
    /// access with no ragged-width check; reads into a fused step's padded
    /// tail return garbage. Use [`StepOut::logits_at`] anywhere a ragged
    /// step can flow.
    pub fn at(&self, i: usize, j: usize) -> &[f32] {
        let off = (i * self.window + j) * self.vocab;
        &self.logits[off..off + self.vocab]
    }

    /// Real window of row `i`: the number of leading positions computed
    /// from real tokens (0 for a padding row of a ragged step; `window`
    /// for every row of a uniform step).
    pub fn row_window(&self, i: usize) -> usize {
        match &self.widths {
            Some(ws) => ws.get(i).copied().unwrap_or(0),
            None => self.window,
        }
    }

    /// Ragged-safe logits access: errors instead of silently handing back
    /// padded garbage when `j` lies outside row `i`'s real window.
    pub fn logits_at(&self, i: usize, j: usize) -> Result<&[f32]> {
        if i >= self.batch {
            bail!("logits row {i} out of range (batch {})", self.batch);
        }
        let w = self.row_window(i);
        if j >= w {
            bail!(
                "logits position {j} outside row {i}'s real window {w} \
                 (step window {}): padded positions hold garbage",
                self.window
            );
        }
        Ok(self.at(i, j))
    }
}

/// Cumulative execution counters (perf accounting; see PERF.md).
///
/// Every field is an atomic: under the overlapped round (PR 8) round
/// R+1's h2d staging is accounted while round R's d2h readback may still
/// be in flight on another thread, so the directional copy counters must
/// tolerate concurrent increment without losing updates. Durations are
/// stored as integer nanoseconds so they ride the same relaxed
/// `fetch_add` as the byte counters; read them back through the seconds
/// accessors or a coherent [`RuntimeStatsSnapshot`].
#[derive(Debug, Default)]
pub struct RuntimeStats {
    compiles: AtomicU64,
    compile_ns: AtomicU64,
    executions: AtomicU64,
    execute_ns: AtomicU64,
    host_copy_ns: AtomicU64,
    kv_h2d_ns: AtomicU64,
    kv_d2h_ns: AtomicU64,
    kv_h2d_bytes: AtomicU64,
    kv_d2h_bytes: AtomicU64,
    logits_d2h_bytes: AtomicU64,
}

/// Plain-data copy of [`RuntimeStats`] at one instant — what benches,
/// tests and the metrics registry consume. Field meanings:
///
/// - `host_copy_s`: wall time building KV input literals, copying results
///   back to host vectors and scattering KV windows into the cache.
/// - `kv_h2d_s` / `kv_d2h_s`: the directional split of `host_copy_s`
///   (staging input literals vs readback + window scatter) — the serve
///   tracer attributes copy time per direction from these, and the
///   overlapped round hides exactly the h2d share behind compute.
/// - `kv_h2d_bytes`: KV bytes staged host→device per call (the full cache
///   travels down every step; CPU-PJRT has no persistent device-side cache
///   buffers — see PERF.md §Incremental-KV protocol).
/// - `kv_d2h_bytes`: KV bytes copied device→host per call; under
///   [`KvProtocol::Window`] this is O(L·b·w·h·dh) per step — the
///   incremental-KV win — versus O(L·b·S·h·dh) under the legacy protocol.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStatsSnapshot {
    pub compiles: u64,
    pub compile_s: f64,
    pub executions: u64,
    pub execute_s: f64,
    pub host_copy_s: f64,
    pub kv_h2d_s: f64,
    pub kv_d2h_s: f64,
    pub kv_h2d_bytes: u64,
    pub kv_d2h_bytes: u64,
    pub logits_d2h_bytes: u64,
}

impl RuntimeStats {
    #[inline]
    fn add_ns(cell: &AtomicU64, secs: f64) {
        cell.fetch_add((secs * 1e9).round() as u64, Ordering::Relaxed);
    }

    #[inline]
    fn secs(cell: &AtomicU64) -> f64 {
        cell.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Account one executable compilation.
    pub fn record_compile(&self, secs: f64) {
        self.compiles.fetch_add(1, Ordering::Relaxed);
        Self::add_ns(&self.compile_ns, secs);
    }

    /// Account one executable invocation (submission side).
    pub fn record_execute(&self, secs: f64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        Self::add_ns(&self.execute_ns, secs);
    }

    /// Account time blocked waiting on an already-submitted execution
    /// ([`Runtime::await_step`]'s device sync) — execute wall time with no
    /// extra invocation counted.
    pub fn record_execute_wait(&self, secs: f64) {
        Self::add_ns(&self.execute_ns, secs);
    }

    /// Account a host→device staging copy (KV input literal build).
    pub fn record_h2d(&self, secs: f64, bytes: u64) {
        Self::add_ns(&self.host_copy_ns, secs);
        Self::add_ns(&self.kv_h2d_ns, secs);
        self.kv_h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Account a device→host readback or cache scatter. The scatter half
    /// passes 0 bytes: it moves bytes the readback already counted.
    pub fn record_d2h(&self, secs: f64, kv_bytes: u64, logits_bytes: u64) {
        Self::add_ns(&self.host_copy_ns, secs);
        Self::add_ns(&self.kv_d2h_ns, secs);
        self.kv_d2h_bytes.fetch_add(kv_bytes, Ordering::Relaxed);
        self.logits_d2h_bytes.fetch_add(logits_bytes, Ordering::Relaxed);
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    pub fn execute_s(&self) -> f64 {
        Self::secs(&self.execute_ns)
    }

    pub fn host_copy_s(&self) -> f64 {
        Self::secs(&self.host_copy_ns)
    }

    pub fn kv_h2d_s(&self) -> f64 {
        Self::secs(&self.kv_h2d_ns)
    }

    pub fn kv_d2h_s(&self) -> f64 {
        Self::secs(&self.kv_d2h_ns)
    }

    pub fn kv_h2d_bytes(&self) -> u64 {
        self.kv_h2d_bytes.load(Ordering::Relaxed)
    }

    pub fn kv_d2h_bytes(&self) -> u64 {
        self.kv_d2h_bytes.load(Ordering::Relaxed)
    }

    /// Coherent-enough plain copy of every counter (relaxed loads; exact
    /// once concurrent staging has quiesced).
    pub fn snapshot(&self) -> RuntimeStatsSnapshot {
        RuntimeStatsSnapshot {
            compiles: self.compiles.load(Ordering::Relaxed),
            compile_s: Self::secs(&self.compile_ns),
            executions: self.executions.load(Ordering::Relaxed),
            execute_s: Self::secs(&self.execute_ns),
            host_copy_s: Self::secs(&self.host_copy_ns),
            kv_h2d_s: Self::secs(&self.kv_h2d_ns),
            kv_d2h_s: Self::secs(&self.kv_d2h_ns),
            kv_h2d_bytes: self.kv_h2d_bytes.load(Ordering::Relaxed),
            kv_d2h_bytes: self.kv_d2h_bytes.load(Ordering::Relaxed),
            logits_d2h_bytes: self.logits_d2h_bytes.load(Ordering::Relaxed),
        }
    }
}

impl RuntimeStatsSnapshot {
    /// Register the runtime's execution/copy ledger into a scrape
    /// snapshot (`specactor_runtime_*`) — all cumulative, so counters.
    pub fn register_metrics(&self, reg: &mut crate::obs::MetricRegistry) {
        let series: [(&str, &str, f64); 9] = [
            ("compiles", "Executable compilations", self.compiles as f64),
            ("compile_seconds", "Wall time compiling executables", self.compile_s),
            ("executions", "Executable invocations", self.executions as f64),
            ("execute_seconds", "Wall time inside PJRT execution", self.execute_s),
            ("host_copy_seconds", "Wall time in host-side copies", self.host_copy_s),
            ("kv_h2d_seconds", "Host to device share of host_copy_seconds", self.kv_h2d_s),
            ("kv_d2h_seconds", "Device to host share of host_copy_seconds", self.kv_d2h_s),
            ("kv_h2d_bytes", "KV bytes staged host to device", self.kv_h2d_bytes as f64),
            ("kv_d2h_bytes", "KV bytes copied device to host", self.kv_d2h_bytes as f64),
        ];
        for (name, help, v) in series {
            reg.counter(&format!("specactor_runtime_{name}"), help, v);
        }
        reg.counter(
            "specactor_runtime_logits_d2h_bytes",
            "Logits bytes copied device to host",
            self.logits_d2h_bytes as f64,
        );
    }
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: RefCell<HashMap<ArtifactKey, Rc<xla::PjRtLoadedExecutable>>>,
    /// model name -> ordered weight literals (manifest order).
    weights: RefCell<HashMap<String, Rc<Vec<xla::Literal>>>>,
    pub stats: RuntimeStats,
}

/// One submitted step whose results have not been read back yet: the
/// device buffers from `execute` plus the shape metadata `await_step`
/// needs to validate and scatter them. Holding two of these against two
/// distinct caches is the double-buffered staging the overlapped round
/// uses — round R+1's [`Runtime::submit_ragged`] h2d staging runs while
/// round R's `InFlightStep` still owns its un-read buffers, so upload and
/// readback of adjacent rounds overlap instead of serializing.
pub struct InFlightStep {
    out: Vec<Vec<xla::PjRtBuffer>>,
    batch: usize,
    window: usize,
    vocab: usize,
    widths: Option<Vec<usize>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RuntimeStats::default(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// Compile (or fetch cached) executable for `key`.
    pub fn executable(&self, key: &ArtifactKey) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.artifact(key)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| anyhow!("parse {:?}: {e:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {:?}: {e:?}", entry.file))?;
        self.stats.record_compile(t0.elapsed().as_secs_f64());
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(key.clone(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile every artifact of `model` (warmup; avoids first-call
    /// latency spikes on the serving path).
    pub fn warmup_model(&self, model: &str) -> Result<usize> {
        let keys: Vec<ArtifactKey> = self
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.model == model)
            .cloned()
            .collect();
        for k in &keys {
            self.executable(k)?;
        }
        Ok(keys.len())
    }

    /// Ordered weight literals for `model`, loaded from its .npz once.
    fn model_weights(&self, model: &str) -> Result<Rc<Vec<xla::Literal>>> {
        if let Some(w) = self.weights.borrow().get(model) {
            return Ok(w.clone());
        }
        let info = self.manifest.model(model)?;
        let entries = xla::Literal::read_npz(&info.weights_file, &())
            .map_err(|e| anyhow!("read {:?}: {e:?}", info.weights_file))?;
        let mut by_name: HashMap<String, xla::Literal> = entries.into_iter().collect();
        let mut ordered = Vec::with_capacity(info.weight_names.len());
        for name in &info.weight_names {
            // npz entries may carry a trailing ".npy" in their names
            let lit = by_name
                .remove(name)
                .or_else(|| by_name.remove(&format!("{name}.npy")))
                .ok_or_else(|| anyhow!("weights npz missing {name:?}"))?;
            ordered.push(lit);
        }
        let rc = Rc::new(ordered);
        self.weights.borrow_mut().insert(model.to_string(), rc.clone());
        Ok(rc)
    }

    /// Fresh KV cache for `model` at batch bucket `b`.
    pub fn new_cache(&self, model: &str, batch: usize) -> Result<KvCache> {
        let m = self.manifest.model(model)?;
        Ok(KvCache::new(m.n_layers, batch, m.max_seq, m.n_heads, m.d_head))
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape i32 {dims:?}: {e:?}"))
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape f32 {dims:?}: {e:?}"))
    }

    /// Run prefill for `model` on `tokens` (row-major `[b, P]`), writing the
    /// produced cache into `cache` (must be sized for batch bucket `b`).
    /// Returns last-position logits `[b, vocab]`.
    pub fn prefill(&self, model: &str, tokens: &[i32], cache: &mut KvCache) -> Result<StepOut> {
        let info = self.manifest.model(model)?;
        let b = cache.batch;
        let p = self.manifest.prompt_len;
        if tokens.len() != b * p {
            bail!("prefill tokens len {} != b*P = {}", tokens.len(), b * p);
        }
        let key = ArtifactKey { model: model.to_string(), kind: FnKind::Prefill, batch: b, window: p };
        let exe = self.executable(&key)?;
        let weights = self.model_weights(model)?;

        let mut args: Vec<&xla::Literal> = weights.iter().collect();
        let tok_lit = Self::lit_i32(tokens, &[b as i64, p as i64])?;
        args.push(&tok_lit);

        let (logits, k, v) = self.run3(&exe, &args, info, b, 1)?;
        if self.manifest.kv_protocol == KvProtocol::Window {
            // The executable computed rows 0..P; the host cache may be
            // reused, so reset it before the scatter (a memset, no alloc).
            cache.k.fill(0.0);
            cache.v.fill(0.0);
            cache.lens.fill(0);
        }
        self.apply_kv(cache, k, v, p, None)?;
        for l in cache.lens.iter_mut() {
            *l = p as i32;
        }
        Ok(StepOut { logits, batch: b, window: 1, vocab: info.vocab, widths: None })
    }

    /// Run one decode/verify step. `tokens` is `[b, w]` row-major; the
    /// cache's `lens` field supplies per-slot positions and is advanced by
    /// the caller (engine) according to how many tokens were accepted.
    pub fn step(&self, model: &str, tokens: &[i32], window: usize, cache: &mut KvCache) -> Result<StepOut> {
        let fl = self.submit_inner(model, tokens, window, cache, None)?;
        self.await_step(fl, cache)
    }

    /// Run one **fused ragged** verify step: the executable runs at the
    /// uniform `window` (short rows padded in `tokens`), but only the
    /// leading `widths[i]` positions of row `i` carry real tokens.
    /// Under [`KvProtocol::Window`] the KV hand-back is scattered per-row
    /// ([`KvCache::scatter_window_rows`]) so a short row's cache never
    /// receives its padded tail; under the legacy `Full` protocol the
    /// whole cache comes back as always (padded entries land at
    /// `lens..lens+window` and are overwritten by the row's next step,
    /// exactly like the grouped discipline's off-group rows). The returned
    /// [`StepOut`] carries the widths, so [`StepOut::logits_at`] refuses
    /// reads into any row's padded tail.
    ///
    /// `widths` is taken by value and handed back inside the returned
    /// [`StepOut`] — callers on the decode hot path reclaim the buffer
    /// after reading the outputs (`out.widths.take()`) so the fused step
    /// allocates nothing per call (PERF.md §Memory discipline).
    pub fn step_ragged(
        &self,
        model: &str,
        tokens: &[i32],
        window: usize,
        cache: &mut KvCache,
        widths: Vec<usize>,
    ) -> Result<StepOut> {
        let fl = self.submit_ragged(model, tokens, window, cache, widths)?;
        self.await_step(fl, cache)
    }

    /// The submit half of [`Runtime::step_ragged`]: validate, stage the
    /// h2d literals and launch the execution, returning an
    /// [`InFlightStep`] whose readback is deferred to
    /// [`Runtime::await_step`]. Between submit and await the caller is
    /// free to draft, stage another cache, or run serve-tick bookkeeping —
    /// that is the overlap window the pipelined round exploits. The cache
    /// is borrowed immutably here; it must not be mutated before the
    /// matching `await_step` scatters the step's KV window into it.
    pub fn submit_ragged(
        &self,
        model: &str,
        tokens: &[i32],
        window: usize,
        cache: &KvCache,
        widths: Vec<usize>,
    ) -> Result<InFlightStep> {
        if widths.len() != cache.batch {
            bail!("ragged widths len {} != batch {}", widths.len(), cache.batch);
        }
        if let Some((slot, &wi)) = widths.iter().enumerate().find(|(_, &wi)| wi > window) {
            bail!("slot {slot}: ragged width {wi} exceeds step window {window}");
        }
        self.submit_inner(model, tokens, window, cache, Some(widths))
    }

    fn submit_inner(
        &self,
        model: &str,
        tokens: &[i32],
        window: usize,
        cache: &KvCache,
        widths: Option<Vec<usize>>,
    ) -> Result<InFlightStep> {
        let info = self.manifest.model(model)?;
        let b = cache.batch;
        if tokens.len() != b * window {
            bail!("step tokens len {} != b*w = {}", tokens.len(), b * window);
        }
        for (slot, &l) in cache.lens.iter().enumerate() {
            if l as usize + window > info.max_seq {
                bail!(
                    "slot {slot}: cache len {l} + window {window} exceeds max_seq {}",
                    info.max_seq
                );
            }
        }
        let key = ArtifactKey { model: model.to_string(), kind: FnKind::Step, batch: b, window };
        let exe = self.executable(&key)?;
        let weights = self.model_weights(model)?;

        let dims = cache.dims().map(|d| d as i64);
        let mut args: Vec<&xla::Literal> = weights.iter().collect();
        let tok_lit = Self::lit_i32(tokens, &[b as i64, window as i64])?;
        let lens_lit = Self::lit_i32(&cache.lens, &[b as i64])?;
        let t0 = Instant::now();
        let k_lit = Self::lit_f32(&cache.k, &dims)?;
        let v_lit = Self::lit_f32(&cache.v, &dims)?;
        self.stats.record_h2d(t0.elapsed().as_secs_f64(), cache.bytes() as u64);
        args.push(&tok_lit);
        args.push(&lens_lit);
        args.push(&k_lit);
        args.push(&v_lit);

        let t1 = Instant::now();
        let out = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        self.stats.record_execute(t1.elapsed().as_secs_f64());
        Ok(InFlightStep { out, batch: b, window, vocab: info.vocab, widths })
    }

    /// The await half of the split step: sync the device buffers, read
    /// logits/KV back to host and scatter the KV window into `cache`
    /// (which must be the cache the step was submitted against).
    pub fn await_step(&self, fl: InFlightStep, cache: &mut KvCache) -> Result<StepOut> {
        let InFlightStep { out, batch, window, vocab, widths } = fl;
        let t0 = Instant::now();
        let tup = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        self.stats.record_execute_wait(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        let (lg, k, v) = tup
            .to_tuple3()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits: Vec<f32> = lg.to_vec().map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let kk: Vec<f32> = k.to_vec().map_err(|e| anyhow!("k to_vec: {e:?}"))?;
        let vv: Vec<f32> = v.to_vec().map_err(|e| anyhow!("v to_vec: {e:?}"))?;
        self.stats.record_d2h(
            t1.elapsed().as_secs_f64(),
            ((kk.len() + vv.len()) * std::mem::size_of::<f32>()) as u64,
            (logits.len() * std::mem::size_of::<f32>()) as u64,
        );
        let want = batch * window * vocab;
        if logits.len() != want {
            bail!("logits len {} != expected {}", logits.len(), want);
        }
        self.apply_kv(cache, kk, vv, window, widths.as_deref())?;
        Ok(StepOut { logits, batch, window, vocab, widths })
    }

    /// Fold an execution's KV output back into the host cache according to
    /// the manifest's [`KvProtocol`].
    ///
    /// `Window`: `k`/`v` are the `[L, b, w, h, dh]` entries written this
    /// call; scatter them at each slot's `lens..lens+w` (two contiguous
    /// `copy_from_slice` runs per (layer, slot) — see
    /// [`KvCache::scatter_window`]), or only the leading `widths[i]`
    /// positions per row for a ragged step
    /// ([`KvCache::scatter_window_rows`]). `Full`: `k`/`v` are whole
    /// caches and simply replace the host copies (a move, but the
    /// device→host transfer behind it was O(max_seq) per step — the cost
    /// this protocol retires); ragged widths are moot there, the padded
    /// entries ride along and are overwritten by each row's next step.
    fn apply_kv(
        &self,
        cache: &mut KvCache,
        k: Vec<f32>,
        v: Vec<f32>,
        window: usize,
        widths: Option<&[usize]>,
    ) -> Result<()> {
        let t0 = Instant::now();
        match self.manifest.kv_protocol {
            KvProtocol::Full => {
                if k.len() != cache.elems() || v.len() != cache.elems() {
                    bail!(
                        "full kv output len {}/{} != cache elems {}",
                        k.len(),
                        v.len(),
                        cache.elems()
                    );
                }
                cache.k = k;
                cache.v = v;
            }
            KvProtocol::Window => match widths {
                Some(ws) => cache.scatter_window_rows(&k, &v, window, ws)?,
                None => cache.scatter_window(&k, &v, window)?,
            },
        }
        self.stats.record_d2h(t0.elapsed().as_secs_f64(), 0, 0);
        Ok(())
    }

    /// Execute and unpack the `(logits, k, v)` tuple. `k`/`v` are returned
    /// raw (window- or full-cache-sized depending on the manifest's
    /// protocol); [`Runtime::apply_kv`] validates and applies them.
    fn run3(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
        info: &ModelInfo,
        batch: usize,
        window: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let t0 = Instant::now();
        let out = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tup = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        self.stats.record_execute(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        let (lg, k, v) = tup
            .to_tuple3()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits: Vec<f32> = lg.to_vec().map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let kk: Vec<f32> = k.to_vec().map_err(|e| anyhow!("k to_vec: {e:?}"))?;
        let vv: Vec<f32> = v.to_vec().map_err(|e| anyhow!("v to_vec: {e:?}"))?;
        self.stats.record_d2h(
            t1.elapsed().as_secs_f64(),
            ((kk.len() + vv.len()) * std::mem::size_of::<f32>()) as u64,
            (logits.len() * std::mem::size_of::<f32>()) as u64,
        );
        let want = batch * window * info.vocab;
        if logits.len() != want {
            bail!("logits len {} != expected {}", logits.len(), want);
        }
        Ok((logits, kk, vv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fused step's StepOut: b=3, W=4, vocab=2, ragged widths [3, 1, 0]
    /// (slot 0 verified a 2-draft window, slot 1 decoded vanilla, slot 2
    /// is padding). Positions >= widths[i] were computed from pad inputs.
    fn ragged_out() -> StepOut {
        StepOut {
            logits: (0..3 * 4 * 2).map(|x| x as f32).collect(),
            batch: 3,
            window: 4,
            vocab: 2,
            widths: Some(vec![3, 1, 0]),
        }
    }

    #[test]
    fn logits_at_refuses_padded_tail() {
        // REGRESSION: under the fused ragged step, reading a window
        // position past a row's real width used to silently return the
        // padded garbage `at()` points at; it must be an error.
        let out = ragged_out();
        assert_eq!(out.logits_at(0, 2).unwrap(), out.at(0, 2));
        assert!(out.logits_at(0, 3).is_err(), "padded tail read must error");
        assert_eq!(out.logits_at(1, 0).unwrap(), out.at(1, 0));
        assert!(out.logits_at(1, 1).is_err());
        assert!(out.logits_at(2, 0).is_err(), "padding row has no real positions");
        assert!(out.logits_at(9, 0).is_err(), "row out of range");
    }

    #[test]
    fn uniform_step_exposes_full_window() {
        let mut out = ragged_out();
        out.widths = None;
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(out.logits_at(i, j).unwrap(), out.at(i, j));
            }
        }
        assert_eq!(out.row_window(1), 4);
        assert_eq!(ragged_out().row_window(1), 1);
    }

    #[test]
    fn concurrent_staging_loses_no_increments() {
        // REGRESSION: RuntimeStats used to live in a RefCell and assume
        // single-threaded mutation; the overlapped round accounts round
        // R+1's h2d staging while round R's d2h readback is still being
        // recorded. Hammer the directional counters from many threads and
        // require exact totals — a lost fetch_add fails the equality.
        let st = RuntimeStats::default();
        const THREADS: u64 = 8;
        const ITERS: u64 = 1000;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let st = &st;
                s.spawn(move || {
                    for i in 0..ITERS {
                        // 1ms per op keeps ns→s rounding exact.
                        st.record_h2d(1e-3, t * ITERS + i);
                        st.record_d2h(1e-3, 2, 1);
                        if i % 4 == 0 {
                            st.record_execute(1e-3);
                        } else {
                            st.record_execute_wait(1e-3);
                        }
                    }
                });
            }
        });
        let snap = st.snapshot();
        // Sum over t of ITERS*t*ITERS + (0+1+..+ITERS-1)
        let h2d_bytes: u64 =
            (0..THREADS).map(|t| t * ITERS * ITERS + ITERS * (ITERS - 1) / 2).sum();
        assert_eq!(snap.kv_h2d_bytes, h2d_bytes, "lost h2d byte increments");
        assert_eq!(snap.kv_d2h_bytes, 2 * THREADS * ITERS, "lost d2h byte increments");
        assert_eq!(snap.logits_d2h_bytes, THREADS * ITERS);
        assert_eq!(snap.executions, THREADS * ITERS / 4);
        let n = (THREADS * ITERS) as f64;
        assert!((snap.kv_h2d_s - n * 1e-3).abs() < 1e-9, "lost h2d seconds");
        assert!((snap.kv_d2h_s - n * 1e-3).abs() < 1e-9, "lost d2h seconds");
        assert!((snap.execute_s - n * 1e-3).abs() < 1e-9, "lost execute seconds");
        assert!((snap.host_copy_s - 2.0 * n * 1e-3).abs() < 1e-9);
    }
}
