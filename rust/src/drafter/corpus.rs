//! Wave-global draft corpus: online draft learning across requests.
//!
//! RL rollout waves are the ideal workload for cross-request draft
//! sharing — one policy, one prompt distribution, massive redundancy —
//! yet per-slot token drafters learn only from their own sequence, so
//! every admission cold-starts at near-zero acceptance. The corpus fixes
//! that: every completed request's verified tokens are folded into ONE
//! shared suffix automaton + gram table, and new admissions seed their
//! drafters from it instead of from empty state.
//!
//! Concurrency discipline (the whole point of the design):
//!
//! * **Snapshots are immutable.** A [`CorpusSnapshot`] owns fully-built
//!   [`SamDrafter`]/[`NgramDrafter`] state behind an `Arc`. Seeding a
//!   slot CLONES the builders out of the snapshot — after that the slot
//!   drafter is exclusively owned, so the per-token draft hot path
//!   touches **no shared state and takes no locks**, exactly like an
//!   unseeded drafter.
//! * **Publication is epoch-swapped.** [`DraftCorpus`] accumulates
//!   accepted segments off the critical path and, at round boundaries,
//!   folds them into its builders and swaps a fresh `Arc` into the
//!   shared [`CorpusHandle`] (Arc-swap style: readers grab the current
//!   pointer; in-flight drafting on the previous snapshot is never
//!   perturbed — it owns its clones).
//! * **Decay on weight updates.** Post-training changes the policy every
//!   iteration, so corpus content goes stale exactly when
//!   `ServeEngine::invalidate_draft_state` fires. [`DraftCorpus::decay`]
//!   drops the accumulated wave, publishes an empty epoch, and the serve
//!   loop reseeds from the live verified prefixes (still-valid context
//!   the new policy must continue from) and re-widens measured priors.
//!
//! Losslessness is untouched by construction: the corpus only changes
//! what drafters *propose*; verification against the target decides
//! every token, and the sampling tape is keyed by (seed, request id,
//! position) — never by drafter state.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::ngram::NgramDrafter;
use super::sam::SamDrafter;
use super::{DraftMethod, TokenDrafter};

/// Separator folded before every corpus segment (and appended when
/// seeding, before the request's own history) so suffix matches never
/// bridge two unrelated requests. Far outside any vocab id; drafting it
/// is possible but harmless — drafts only propose, verification rejects.
pub const SEGMENT_SEP: i32 = i32::MIN + 0x5A17;

/// Corpus tokens retained before the oldest segments are evicted: bounds
/// both snapshot memory and the rebuild cost an eviction pays.
pub const DEFAULT_CAP_TOKENS: usize = 1 << 15;

/// Immutable, epoch-stamped view of the corpus: prebuilt drafter state
/// ready to be cloned into admitted slots.
#[derive(Clone)]
pub struct CorpusSnapshot {
    /// Monotone publication epoch (0 = the empty pre-wave snapshot).
    pub epoch: u64,
    /// Corpus tokens indexed by this snapshot (excludes separators).
    pub tokens: u64,
    /// Segments (completed requests / reseeded prefixes) folded in.
    pub segments: u64,
    sam: SamDrafter,
    ngram: NgramDrafter,
}

impl CorpusSnapshot {
    /// The empty snapshot at `epoch`. Hyper-parameters MUST match
    /// [`DraftMethod::new_token_drafter`] so a seeded and an unseeded
    /// drafter are the same structure, differing only in history.
    pub fn empty(epoch: u64) -> Self {
        CorpusSnapshot {
            epoch,
            tokens: 0,
            segments: 0,
            sam: SamDrafter::new(16),
            ngram: NgramDrafter::new(3),
        }
    }

    /// Does this snapshot hold any corpus content worth seeding from?
    pub fn is_warm(&self) -> bool {
        self.tokens > 0
    }

    /// Fold one accepted segment into the builders (separator first, so
    /// patterns never span segment boundaries).
    fn fold(&mut self, seg: &[i32]) {
        if seg.is_empty() {
            return;
        }
        self.sam.extend(&[SEGMENT_SEP]);
        self.ngram.extend(&[SEGMENT_SEP]);
        self.sam.extend(seg);
        self.ngram.extend(seg);
        self.segments += 1;
        self.tokens += seg.len() as u64;
    }

    /// Clone-seed a token drafter for `method` from this snapshot (None
    /// for model methods, which live in KV caches, and for cold
    /// snapshots, where an empty drafter is cheaper than a clone). The
    /// clone ends with a segment separator, so the caller's
    /// `extend(&req.seq)` continues a fresh segment: a seeded drafter is
    /// byte-for-byte the drafter that indexed
    /// `SEP·seg1·…·SEP·segN·SEP·req.seq` from scratch — the differential
    /// identity `rust/tests/drafter_differential.rs` pins.
    pub fn seed_token_drafter(&self, method: &DraftMethod) -> Option<Box<dyn TokenDrafter>> {
        if !self.is_warm() {
            return None;
        }
        let mut td: Box<dyn TokenDrafter> = match method {
            DraftMethod::Model(_) => return None,
            DraftMethod::Ngram => Box::new(self.ngram.clone()),
            DraftMethod::Sam => Box::new(self.sam.clone()),
        };
        td.extend(&[SEGMENT_SEP]);
        Some(td)
    }
}

/// Cheap clonable reader handle to the latest published snapshot.
///
/// `load` is one mutex-guarded `Arc` clone — a pointer load plus a
/// refcount bump, performed at SEED and lifecycle-reset time only, never
/// per drafted token (slot drafters own their clones outright). std has
/// no atomic `Arc` swap, so the single pointer cell is mutex-guarded;
/// the critical section is the clone itself and publication is rare
/// (round boundaries), so the guard is never contended on a hot path.
#[derive(Clone)]
pub struct CorpusHandle {
    cur: Arc<Mutex<Arc<CorpusSnapshot>>>,
}

impl Default for CorpusHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl CorpusHandle {
    pub fn new() -> Self {
        CorpusHandle { cur: Arc::new(Mutex::new(Arc::new(CorpusSnapshot::empty(0)))) }
    }

    /// The latest published snapshot (immutable; in-flight users of
    /// older epochs are unaffected by later publishes).
    pub fn load(&self) -> Arc<CorpusSnapshot> {
        match self.cur.lock() {
            Ok(g) => g.clone(),
            // a poisoned cell only ever holds a fully-published snapshot
            Err(p) => p.into_inner().clone(),
        }
    }

    fn publish(&self, snap: Arc<CorpusSnapshot>) {
        match self.cur.lock() {
            Ok(mut g) => *g = snap,
            Err(p) => *p.into_inner() = snap,
        }
    }

    /// Epoch of the latest published snapshot.
    pub fn epoch(&self) -> u64 {
        self.load().epoch
    }
}

/// Corpus telemetry, mirrored into `ServeMetrics` each tick (the single
/// enumeration both the JSON summary and the scrape render from).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CorpusStats {
    /// Corpus tokens indexed by the latest published snapshot.
    pub tokens: u64,
    /// Admissions whose drafters were seeded from a warm snapshot.
    pub seeds: u64,
    /// Snapshot epochs published (decay epochs included).
    pub publishes: u64,
    /// Segments evicted by the retention cap.
    pub evictions: u64,
    /// Weight-update decays (wave resets).
    pub decays: u64,
}

/// The mutable half: accumulates accepted segments and publishes
/// immutable epochs into a [`CorpusHandle`].
///
/// Two roles share the type: a **publisher** (standalone serve loop, or
/// the cluster supervisor) owns the retained segment window and the
/// incremental builders; a **tap** (per-worker batcher under a cluster)
/// only buffers segments and decay events for the supervisor to drain —
/// publication stays single-writer, and replication to every worker is
/// the shared handle itself (all engines read the same epoch).
pub struct DraftCorpus {
    handle: CorpusHandle,
    /// Retained segments, oldest first (publisher only): the eviction
    /// window the builders are rebuilt from when the cap trips.
    segments: VecDeque<Vec<i32>>,
    /// Builders already folded over `segments`; publish clones them into
    /// the next snapshot, so steady-state publish cost is O(new tokens)
    /// plus the clone — paid at a round boundary, never per token.
    built: CorpusSnapshot,
    /// Segments accepted since the last publish/drain.
    pending: Vec<Vec<i32>>,
    epoch: u64,
    cap_tokens: usize,
    publisher: bool,
    decay_on_invalidate: bool,
    decay_flag: bool,
    pub stats: CorpusStats,
}

impl DraftCorpus {
    /// A publishing corpus with the default retention cap.
    pub fn new() -> Self {
        Self::with_cap(DEFAULT_CAP_TOKENS)
    }

    /// A publishing corpus retaining at most `cap_tokens` corpus tokens.
    pub fn with_cap(cap_tokens: usize) -> Self {
        DraftCorpus {
            handle: CorpusHandle::new(),
            segments: VecDeque::new(),
            built: CorpusSnapshot::empty(0),
            pending: Vec::new(),
            epoch: 0,
            cap_tokens: cap_tokens.max(1),
            publisher: true,
            decay_on_invalidate: true,
            decay_flag: false,
            stats: CorpusStats::default(),
        }
    }

    /// A non-publishing tap feeding a cluster supervisor's publisher
    /// through the SAME handle (see type docs).
    pub fn tap(handle: CorpusHandle) -> Self {
        let mut c = Self::new();
        c.handle = handle;
        c.publisher = false;
        c
    }

    /// Keep the corpus across weight updates (A/B knob for the bench's
    /// stale-corpus cell — production serving wants the default decay).
    pub fn persist_across_updates(mut self) -> Self {
        self.decay_on_invalidate = false;
        self
    }

    /// Reader handle for engines / drafter threads.
    pub fn handle(&self) -> CorpusHandle {
        self.handle.clone()
    }

    /// Should `invalidate_draft_state` decay this corpus?
    pub fn decay_on_invalidate(&self) -> bool {
        self.decay_on_invalidate
    }

    /// Is the published snapshot warm (worth counting a seed against)?
    pub fn is_warm(&self) -> bool {
        self.handle.load().is_warm()
    }

    /// Current publication epoch, read through the shared handle: for a
    /// publisher this equals its local counter; for a tap (which never
    /// publishes, so never advances a local counter) it is the master's
    /// replicated epoch — the only meaningful answer.
    pub fn epoch(&self) -> u64 {
        self.handle.epoch()
    }

    /// Does this corpus publish its own epochs (false for cluster taps,
    /// whose harvest the supervisor drains and publishes)?
    pub fn is_publisher(&self) -> bool {
        self.publisher
    }

    /// An admission seeded its drafters from the warm snapshot.
    pub fn note_seed(&mut self) {
        self.stats.seeds += 1;
    }

    /// Queue one accepted segment (a completed request's verified
    /// sequence, or a live prefix at reseed) for the next publish.
    pub fn add_segment(&mut self, seg: &[i32]) {
        if seg.is_empty() {
            return;
        }
        self.pending.push(seg.to_vec());
    }

    /// Anything queued for the next epoch?
    pub fn publish_due(&self) -> bool {
        self.publisher && !self.pending.is_empty()
    }

    /// Fold pending segments, apply the retention cap, and swap the next
    /// epoch into the handle. Returns the token count folded (0 for taps
    /// and empty publishes). O(new tokens + clone) without eviction; an
    /// eviction rebuilds the builders over the retained window.
    pub fn publish(&mut self) -> u64 {
        if !self.publisher || self.pending.is_empty() {
            return 0;
        }
        let mut folded = 0u64;
        for seg in self.pending.drain(..) {
            folded += seg.len() as u64;
            self.built.fold(&seg);
            self.segments.push_back(seg);
        }
        let mut total: usize = self.segments.iter().map(|s| s.len()).sum();
        if total > self.cap_tokens {
            while total > self.cap_tokens && self.segments.len() > 1 {
                let dropped = self.segments.pop_front().map(|s| s.len()).unwrap_or(0);
                total -= dropped;
                self.stats.evictions += 1;
            }
            let mut rebuilt = CorpusSnapshot::empty(self.epoch);
            for seg in &self.segments {
                rebuilt.fold(seg);
            }
            self.built = rebuilt;
        }
        self.epoch += 1;
        self.built.epoch = self.epoch;
        self.stats.publishes += 1;
        self.stats.tokens = self.built.tokens;
        self.handle.publish(Arc::new(self.built.clone()));
        folded
    }

    /// Weight-update decay: the accumulated wave indexed the OLD
    /// policy's continuations — drop it. A publisher publishes an empty
    /// epoch immediately (readers go cold at the next pointer load); a
    /// tap records the event for the supervisor to act on.
    pub fn decay(&mut self) {
        self.stats.decays += 1;
        self.pending.clear();
        if !self.publisher {
            self.decay_flag = true;
            return;
        }
        self.segments.clear();
        self.epoch += 1;
        self.built = CorpusSnapshot::empty(self.epoch);
        self.stats.publishes += 1;
        self.stats.tokens = 0;
        self.handle.publish(Arc::new(self.built.clone()));
    }

    /// Drain buffered segments (cluster supervisor pulling from a tap).
    pub fn drain_pending(&mut self) -> Vec<Vec<i32>> {
        std::mem::take(&mut self.pending)
    }

    /// Take-and-clear the tap's decay event flag.
    pub fn take_decay_flag(&mut self) -> bool {
        std::mem::take(&mut self.decay_flag)
    }
}

impl Default for DraftCorpus {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(vals: &[i32]) -> Vec<i32> {
        vals.to_vec()
    }

    #[test]
    fn publish_bumps_epoch_and_warms_the_handle() {
        let mut c = DraftCorpus::new();
        let h = c.handle();
        assert_eq!(h.epoch(), 0);
        assert!(!h.load().is_warm());
        c.add_segment(&seg(&[1, 2, 3, 1, 2, 3]));
        assert!(c.publish_due());
        assert_eq!(c.publish(), 6);
        let s = h.load();
        assert_eq!(s.epoch, 1);
        assert!(s.is_warm());
        assert_eq!(s.tokens, 6);
        assert_eq!(c.stats.publishes, 1);
        assert!(!c.publish_due(), "pending drained by publish");
    }

    #[test]
    fn seeded_drafter_matches_from_scratch_over_concatenated_stream() {
        let mut c = DraftCorpus::new();
        let segs = [seg(&[5, 6, 7, 5, 6, 7, 5, 6]), seg(&[9, 9, 3, 9, 9, 3])];
        for s in &segs {
            c.add_segment(s);
        }
        c.publish();
        let snap = c.handle().load();
        let req: Vec<i32> = vec![5, 6, 7, 5, 6];
        for method in [DraftMethod::Sam, DraftMethod::Ngram] {
            let mut seeded = snap.seed_token_drafter(&method).expect("warm snapshot seeds");
            seeded.extend(&req);
            let mut scratch = method.new_token_drafter().unwrap();
            for s in &segs {
                scratch.extend(&[SEGMENT_SEP]);
                scratch.extend(s);
            }
            scratch.extend(&[SEGMENT_SEP]);
            scratch.extend(&req);
            assert_eq!(seeded.len(), scratch.len(), "{method:?} history length");
            assert_eq!(
                seeded.draft(8),
                scratch.draft(8),
                "{} seeded vs from-scratch proposals diverged",
                method.label()
            );
        }
    }

    #[test]
    fn publication_never_perturbs_prior_epoch_clones() {
        let mut c = DraftCorpus::new();
        c.add_segment(&seg(&[1, 2, 3, 1, 2, 3, 1, 2]));
        c.publish();
        let h = c.handle();
        let mut in_flight = h.load().seed_token_drafter(&DraftMethod::Ngram).unwrap();
        in_flight.extend(&[1, 2, 3, 1]);
        let before = in_flight.draft(4);
        // a later epoch lands while the clone is mid-request
        c.add_segment(&seg(&[7, 7, 7, 7, 7, 7]));
        c.publish();
        assert_eq!(h.epoch(), 2);
        assert_eq!(in_flight.draft(4), before, "in-flight clone saw the publish");
    }

    #[test]
    fn cold_snapshot_seeds_nothing_and_models_never_seed() {
        let c = DraftCorpus::new();
        let snap = c.handle().load();
        assert!(snap.seed_token_drafter(&DraftMethod::Sam).is_none());
        let mut warm = DraftCorpus::new();
        warm.add_segment(&[4, 4, 4, 4]);
        warm.publish();
        let snap = warm.handle().load();
        assert!(snap.seed_token_drafter(&DraftMethod::Model("draft_small".into())).is_none());
        assert!(snap.seed_token_drafter(&DraftMethod::Sam).is_some());
    }

    #[test]
    fn decay_publishes_a_cold_epoch_and_counts() {
        let mut c = DraftCorpus::new();
        c.add_segment(&[1, 2, 1, 2, 1, 2]);
        c.publish();
        let h = c.handle();
        assert!(h.load().is_warm());
        c.decay();
        let s = h.load();
        assert_eq!(s.epoch, 2, "decay is its own epoch");
        assert!(!s.is_warm(), "decayed snapshot must be cold");
        assert_eq!(c.stats.decays, 1);
        assert_eq!(c.stats.tokens, 0);
        // the wave restarts cleanly afterwards
        c.add_segment(&[8, 8, 8, 8]);
        c.publish();
        assert!(h.load().is_warm());
        assert_eq!(h.epoch(), 3);
    }

    #[test]
    fn cap_evicts_oldest_segments_and_rebuilds() {
        let mut c = DraftCorpus::with_cap(10);
        c.add_segment(&seg(&[1; 6]));
        c.publish();
        c.add_segment(&seg(&[2; 6]));
        c.publish();
        // 12 > 10: the oldest segment must go
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.stats.tokens, 6);
        let snap = c.handle().load();
        assert_eq!(snap.tokens, 6);
        // the rebuilt builders index only the retained segment
        let mut td = snap.seed_token_drafter(&DraftMethod::Sam).unwrap();
        td.extend(&[2, 2, 2]);
        assert!(td.draft(3).iter().all(|&t| t == 2));
    }

    #[test]
    fn tap_buffers_for_the_supervisor_and_never_publishes() {
        let mut master = DraftCorpus::new();
        let mut tap = DraftCorpus::tap(master.handle());
        tap.add_segment(&[3, 1, 4, 1, 5]);
        assert!(!tap.publish_due());
        assert_eq!(tap.publish(), 0, "taps never publish");
        assert_eq!(master.handle().epoch(), 0);
        for s in tap.drain_pending() {
            master.add_segment(&s);
        }
        master.publish();
        assert_eq!(tap.handle().epoch(), 1, "replication is the shared handle");
        assert_eq!(tap.epoch(), 1, "a tap's epoch() must read the replicated handle");
        assert!(tap.is_warm());
        tap.decay();
        assert!(tap.take_decay_flag(), "tap decay is an event for the supervisor");
        assert!(!tap.take_decay_flag());
        assert_eq!(master.handle().epoch(), 1, "tap decay must not publish");
    }

    #[test]
    fn persist_knob_disables_decay_wiring() {
        let c = DraftCorpus::new().persist_across_updates();
        assert!(!c.decay_on_invalidate());
        assert!(DraftCorpus::new().decay_on_invalidate());
    }
}
