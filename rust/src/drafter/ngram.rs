//! Prompt-lookup n-gram drafter [2]: hash the last `n` tokens, find the
//! most recent earlier occurrence of the same n-gram in the history, and
//! propose the tokens that followed it.

use std::collections::HashMap;

use super::TokenDrafter;

pub struct NgramDrafter {
    /// n-gram order (falls back to shorter grams down to 1).
    pub max_n: usize,
    history: Vec<i32>,
    /// gram (packed) -> (most recent, previous) end positions (exclusive).
    /// Two entries are kept because the current tail indexes itself: the
    /// lookup needs the latest occurrence *strictly before* the tail.
    index: Vec<HashMap<u64, (usize, usize)>>,
}

fn pack(gram: &[i32]) -> u64 {
    // tokens are < 2^16 in practice; fold into 64 bits with a prime mix.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in gram {
        h ^= t as u64 as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl NgramDrafter {
    pub fn new(max_n: usize) -> Self {
        assert!(max_n >= 1);
        NgramDrafter {
            max_n,
            history: Vec::new(),
            index: vec![HashMap::new(); max_n],
        }
    }

    fn index_position(&mut self, end: usize) {
        // index all grams ending at `end` (exclusive end)
        for n in 1..=self.max_n.min(end) {
            let gram = &self.history[end - n..end];
            let key = pack(gram);
            let slot = self.index[n - 1].entry(key).or_insert((end, end));
            if slot.0 != end {
                *slot = (end, slot.0);
            }
        }
    }
}

impl TokenDrafter for NgramDrafter {
    fn name(&self) -> &'static str {
        "ngram"
    }

    fn extend(&mut self, tokens: &[i32]) {
        for &t in tokens {
            self.history.push(t);
            self.index_position(self.history.len());
        }
    }

    fn draft(&mut self, n_tokens: usize) -> Vec<i32> {
        let len = self.history.len();
        if len == 0 || n_tokens == 0 {
            return Vec::new();
        }
        // longest gram first
        for n in (1..=self.max_n.min(len)).rev() {
            let gram = &self.history[len - n..len];
            if let Some(&(latest, prev)) = self.index[n - 1].get(&pack(gram)) {
                // the tail gram indexes itself at `len`; use the latest
                // occurrence strictly before it
                let end = if latest < len { latest } else { prev };
                if end < len {
                    // propose what followed the previous occurrence
                    let take = n_tokens.min(len - end);
                    if take > 0 {
                        return self.history[end..end + take].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    fn len(&self) -> usize {
        self.history.len()
    }

    fn reset(&mut self) {
        self.history.clear();
        for m in &mut self.index {
            m.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drafts_from_repeated_pattern() {
        let mut d = NgramDrafter::new(3);
        // history: A B C D A B C — suffix "A B C" matched earlier, next was D
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let out = d.draft(2);
        assert_eq!(out, vec![4, 1]);
    }

    #[test]
    fn no_match_returns_empty() {
        let mut d = NgramDrafter::new(3);
        d.extend(&[1, 2, 3, 4, 5]);
        assert!(d.draft(4).is_empty());
    }

    #[test]
    fn prefers_longest_gram() {
        let mut d = NgramDrafter::new(3);
        // "2 3" appears twice with different continuations; the 3-gram
        // "1 2 3" disambiguates to the earlier full match.
        d.extend(&[1, 2, 3, 7, 9, 2, 3, 8, 1, 2, 3]);
        let out = d.draft(1);
        assert_eq!(out, vec![7]); // continuation of the 3-gram match
    }

    #[test]
    fn most_recent_occurrence_wins_for_short_grams() {
        let mut d = NgramDrafter::new(1);
        d.extend(&[5, 1, 5, 2, 5]);
        // last occurrence of gram [5] before the end is at position 5 →
        // no continuation; the index maps to the latest end (5), take=0 →
        // falls through to empty. Extend so a continuation exists:
        let out = d.draft(1);
        // gram [5] ends at 5 (the current tail itself) → no tokens follow.
        assert!(out.is_empty() || out == vec![2]);
    }

    #[test]
    fn reset_clears() {
        let mut d = NgramDrafter::new(2);
        d.extend(&[1, 2, 1, 2]);
        assert!(!d.is_empty());
        d.reset();
        assert!(d.is_empty());
        assert!(d.draft(2).is_empty());
    }

    #[test]
    fn cyclic_sequence_high_hit_rate() {
        // The SpecGPT successor process is near-cyclic: n-gram drafting
        // should predict it almost perfectly once the cycle repeats.
        let mut d = NgramDrafter::new(3);
        let cycle: Vec<i32> = (0..10).collect();
        for _ in 0..3 {
            d.extend(&cycle);
        }
        let out = d.draft(5);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }
}
