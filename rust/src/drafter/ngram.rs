//! Prompt-lookup n-gram drafter [2]: hash the last `n` tokens, find the
//! most recent earlier occurrence of the same n-gram in the history, and
//! propose the tokens that followed it.
//!
//! The gram index is a [`GramTable`] — a flat open-addressing hash table
//! (linear probing, power-of-two capacity) instead of a `HashMap` per
//! order: lookups touch one contiguous allocation and inserts only
//! allocate on the amortised doubling rehash (PERF.md §Memory
//! discipline). Drafting writes into the caller's buffer via
//! [`TokenDrafter::draft_into`].

use super::TokenDrafter;

/// Flat open-addressing map `u64 gram-hash -> (latest, prev)` end
/// positions (exclusive, 1-based — so `latest == 0` marks an empty slot).
///
/// Two positions are kept because the current tail indexes itself: the
/// lookup needs the latest occurrence *strictly before* the tail.
#[derive(Clone, Debug)]
struct GramTable {
    keys: Vec<u64>,
    /// (latest, prev) end positions; `.0 == 0` ⇒ slot empty.
    vals: Vec<(u32, u32)>,
    live: usize,
    mask: usize,
}

impl GramTable {
    fn with_capacity_pow2(cap: usize) -> Self {
        debug_assert!(cap.is_power_of_two());
        GramTable {
            keys: vec![0; cap],
            vals: vec![(0, 0); cap],
            live: 0,
            mask: cap - 1,
        }
    }

    fn new() -> Self {
        Self::with_capacity_pow2(64)
    }

    /// Slot holding `key`, or the empty slot where it would be inserted.
    fn probe(&self, key: u64) -> usize {
        let mut i = (key as usize) & self.mask;
        loop {
            if self.vals[i].0 == 0 || self.keys[i] == key {
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    fn get(&self, key: u64) -> Option<(u32, u32)> {
        let i = self.probe(key);
        if self.vals[i].0 == 0 {
            None
        } else {
            Some(self.vals[i])
        }
    }

    /// Record an occurrence of `key` ending at `end` (1-based exclusive).
    fn record(&mut self, key: u64, end: u32) {
        debug_assert!(end > 0);
        let i = self.probe(key);
        if self.vals[i].0 == 0 {
            self.keys[i] = key;
            self.vals[i] = (end, end);
            self.live += 1;
            if self.live * 10 > self.keys.len() * 7 {
                self.grow();
            }
        } else if self.vals[i].0 != end {
            self.vals[i] = (end, self.vals[i].0);
        }
    }

    fn grow(&mut self) {
        let mut next = GramTable::with_capacity_pow2(self.keys.len() * 2);
        for i in 0..self.keys.len() {
            if self.vals[i].0 != 0 {
                let j = next.probe(self.keys[i]);
                next.keys[j] = self.keys[i];
                next.vals[j] = self.vals[i];
                next.live += 1;
            }
        }
        *self = next;
    }

    fn clear(&mut self) {
        self.keys.fill(0);
        self.vals.fill((0, 0));
        self.live = 0;
    }
}

/// `Clone` supports corpus snapshot seeding: a prebuilt table is cloned
/// out of the published corpus snapshot into an admitted slot.
#[derive(Clone)]
pub struct NgramDrafter {
    /// n-gram order (falls back to shorter grams down to 1).
    pub max_n: usize,
    history: Vec<i32>,
    /// One table per gram order.
    index: Vec<GramTable>,
}

fn pack(gram: &[i32]) -> u64 {
    // tokens are < 2^16 in practice; fold into 64 bits with a prime mix.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in gram {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl NgramDrafter {
    pub fn new(max_n: usize) -> Self {
        assert!(max_n >= 1);
        NgramDrafter {
            max_n,
            history: Vec::new(),
            index: (0..max_n).map(|_| GramTable::new()).collect(),
        }
    }

    fn index_position(&mut self, end: usize) {
        // index all grams ending at `end` (exclusive end)
        for n in 1..=self.max_n.min(end) {
            let gram = &self.history[end - n..end];
            self.index[n - 1].record(pack(gram), end as u32);
        }
    }
}

impl TokenDrafter for NgramDrafter {
    fn name(&self) -> &'static str {
        "ngram"
    }

    fn extend(&mut self, tokens: &[i32]) {
        for &t in tokens {
            self.history.push(t);
            self.index_position(self.history.len());
        }
    }

    fn draft_into(&mut self, n_tokens: usize, out: &mut Vec<i32>) {
        out.clear();
        let len = self.history.len();
        if len == 0 || n_tokens == 0 {
            return;
        }
        // longest gram first
        for n in (1..=self.max_n.min(len)).rev() {
            let gram = &self.history[len - n..len];
            if let Some((latest, prev)) = self.index[n - 1].get(pack(gram)) {
                // the tail gram indexes itself at `len`; use the latest
                // occurrence strictly before it
                let end = if (latest as usize) < len { latest as usize } else { prev as usize };
                if end < len {
                    // propose what followed the previous occurrence
                    let take = n_tokens.min(len - end);
                    if take > 0 {
                        out.extend_from_slice(&self.history[end..end + take]);
                        return;
                    }
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.history.len()
    }

    fn reset(&mut self) {
        self.history.clear();
        for t in &mut self.index {
            t.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drafts_from_repeated_pattern() {
        let mut d = NgramDrafter::new(3);
        // history: A B C D A B C — suffix "A B C" matched earlier, next was D
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let out = d.draft(2);
        assert_eq!(out, vec![4, 1]);
    }

    #[test]
    fn no_match_returns_empty() {
        let mut d = NgramDrafter::new(3);
        d.extend(&[1, 2, 3, 4, 5]);
        assert!(d.draft(4).is_empty());
    }

    #[test]
    fn prefers_longest_gram() {
        let mut d = NgramDrafter::new(3);
        // "2 3" appears twice with different continuations; the 3-gram
        // "1 2 3" disambiguates to the earlier full match.
        d.extend(&[1, 2, 3, 7, 9, 2, 3, 8, 1, 2, 3]);
        let out = d.draft(1);
        assert_eq!(out, vec![7]); // continuation of the 3-gram match
    }

    #[test]
    fn most_recent_occurrence_wins_for_short_grams() {
        let mut d = NgramDrafter::new(1);
        d.extend(&[5, 1, 5, 2, 5]);
        // last occurrence of gram [5] before the end is at position 5 →
        // no continuation; the index maps to the latest end (5), take=0 →
        // falls through to empty. Extend so a continuation exists:
        let out = d.draft(1);
        // gram [5] ends at 5 (the current tail itself) → no tokens follow.
        assert!(out.is_empty() || out == vec![2]);
    }

    #[test]
    fn reset_clears() {
        let mut d = NgramDrafter::new(2);
        d.extend(&[1, 2, 1, 2]);
        assert!(!d.is_empty());
        d.reset();
        assert!(d.is_empty());
        assert!(d.draft(2).is_empty());
    }

    #[test]
    fn cyclic_sequence_high_hit_rate() {
        // The SpecGPT successor process is near-cyclic: n-gram drafting
        // should predict it almost perfectly once the cycle repeats.
        let mut d = NgramDrafter::new(3);
        let cycle: Vec<i32> = (0..10).collect();
        for _ in 0..3 {
            d.extend(&cycle);
        }
        let out = d.draft(5);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn gram_table_record_get_and_growth() {
        let mut t = GramTable::with_capacity_pow2(4); // force rehashes
        for end in 1..=200u32 {
            t.record(end as u64 * 0x9e37_79b9, end);
        }
        assert_eq!(t.live, 200);
        for end in 1..=200u32 {
            assert_eq!(t.get(end as u64 * 0x9e37_79b9), Some((end, end)));
        }
        assert_eq!(t.get(12345), None);
        // updating the same key keeps (latest, prev) history
        t.record(42, 10);
        t.record(42, 10); // same end twice: no change
        assert_eq!(t.get(42), Some((10, 10)));
        t.record(42, 20);
        assert_eq!(t.get(42), Some((20, 10)));
        t.record(42, 30);
        assert_eq!(t.get(42), Some((30, 20)));
    }

    #[test]
    fn draft_into_appends_into_reused_buffer() {
        let mut d = NgramDrafter::new(3);
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let mut buf = vec![7; 8];
        d.draft_into(2, &mut buf);
        assert_eq!(buf, vec![4, 1]);
        let cap = buf.capacity();
        d.draft_into(2, &mut buf);
        assert_eq!(buf, vec![4, 1]);
        assert_eq!(buf.capacity(), cap, "steady-state draft reallocated");
    }
}
