//! Suffix-automaton drafter (SAM-decoding [25]).
//!
//! Builds a suffix automaton over the request's token history online
//! (amortised O(1) per appended token) and tracks the automaton state of
//! the *current suffix*. To draft, it jumps to the end position of the
//! longest history match of the current suffix and proposes the tokens
//! that followed it — like the n-gram drafter but with unbounded match
//! length and true longest-match semantics.

use std::collections::HashMap;

use super::TokenDrafter;

#[derive(Clone, Debug)]
struct State {
    /// Longest substring length represented by this state.
    len: usize,
    /// Suffix link.
    link: i32,
    /// Transitions token -> state.
    next: HashMap<i32, u32>,
    /// One end position (exclusive) of an occurrence of this state's
    /// substrings (the first time the state was created).
    end_pos: usize,
}

pub struct SamDrafter {
    states: Vec<State>,
    last: u32,
    history: Vec<i32>,
    /// Matching state/length for the current full suffix (decode cursor).
    cur_state: u32,
    cur_len: usize,
    /// Cap on drafted continuation length per call.
    pub max_draft: usize,
}

impl SamDrafter {
    pub fn new(max_draft: usize) -> Self {
        let root = State { len: 0, link: -1, next: HashMap::new(), end_pos: 0 };
        SamDrafter {
            states: vec![root],
            last: 0,
            history: Vec::new(),
            cur_state: 0,
            cur_len: 0,
            max_draft,
        }
    }

    fn add_token(&mut self, c: i32) {
        // classic SAM online construction (Blumer et al.)
        let cur = self.states.len() as u32;
        let end_pos = self.history.len() + 1;
        self.states.push(State {
            len: self.states[self.last as usize].len + 1,
            link: 0,
            next: HashMap::new(),
            end_pos,
        });
        let mut p = self.last as i32;
        while p >= 0 && !self.states[p as usize].next.contains_key(&c) {
            self.states[p as usize].next.insert(c, cur);
            p = self.states[p as usize].link;
        }
        if p == -1 {
            self.states[cur as usize].link = 0;
        } else {
            let q = self.states[p as usize].next[&c];
            if self.states[p as usize].len + 1 == self.states[q as usize].len {
                self.states[cur as usize].link = q as i32;
            } else {
                // clone q
                let clone = self.states.len() as u32;
                let mut cl = self.states[q as usize].clone();
                cl.len = self.states[p as usize].len + 1;
                self.states.push(cl);
                while p >= 0 && self.states[p as usize].next.get(&c) == Some(&q) {
                    self.states[p as usize].next.insert(c, clone);
                    p = self.states[p as usize].link;
                }
                self.states[q as usize].link = clone as i32;
                self.states[cur as usize].link = clone as i32;
            }
        }
        self.last = cur;
        self.history.push(c);
    }

    /// Advance the decode cursor (matching state) by one token, following
    /// suffix links on mismatch — identical to online string matching.
    fn advance_cursor(&mut self, c: i32) {
        loop {
            if let Some(&nxt) = self.states[self.cur_state as usize].next.get(&c) {
                self.cur_state = nxt;
                self.cur_len += 1;
                // clamp to the state's max length
                let sl = self.states[self.cur_state as usize].len;
                if self.cur_len > sl {
                    self.cur_len = sl;
                }
                return;
            }
            let link = self.states[self.cur_state as usize].link;
            if link < 0 {
                self.cur_state = 0;
                self.cur_len = 0;
                return;
            }
            self.cur_state = link as u32;
            self.cur_len = self.states[self.cur_state as usize].len;
        }
    }
}

impl TokenDrafter for SamDrafter {
    fn name(&self) -> &'static str {
        "sam"
    }

    fn extend(&mut self, tokens: &[i32]) {
        for &t in tokens {
            // cursor must be advanced against the automaton *before* the
            // token is added (else it would trivially match itself)
            self.advance_cursor(t);
            self.add_token(t);
        }
    }

    fn draft(&mut self, n_tokens: usize) -> Vec<i32> {
        if self.cur_len == 0 || self.history.is_empty() {
            return Vec::new();
        }
        // end position of one occurrence of the current matched suffix
        let end = self.states[self.cur_state as usize].end_pos;
        if end >= self.history.len() {
            return Vec::new();
        }
        let take = n_tokens.min(self.max_draft).min(self.history.len() - end);
        self.history[end..end + take].to_vec()
    }

    fn len(&self) -> usize {
        self.history.len()
    }

    fn reset(&mut self) {
        *self = SamDrafter::new(self.max_draft);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn drafts_repeated_pattern() {
        let mut d = SamDrafter::new(8);
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let out = d.draft(2);
        assert_eq!(out, vec![4, 1]);
    }

    #[test]
    fn longest_match_beats_short() {
        // suffix "9 2 3" matched once (continuation 8); the shorter "2 3"
        // also occurred earlier with continuation 7 — SAM must use the
        // longest match.
        let mut d = SamDrafter::new(8);
        d.extend(&[2, 3, 7, 0, 9, 2, 3, 8, 5, 9, 2, 3]);
        let out = d.draft(1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn empty_without_match() {
        let mut d = SamDrafter::new(8);
        d.extend(&[1, 2, 3, 4, 5]);
        assert!(d.draft(3).is_empty());
    }

    #[test]
    fn cyclic_predicts_perfectly() {
        let mut d = SamDrafter::new(16);
        let cycle: Vec<i32> = (10..30).collect();
        d.extend(&cycle);
        d.extend(&cycle);
        let out = d.draft(10);
        assert_eq!(out, (10..20).collect::<Vec<i32>>());
    }

    #[test]
    fn reset_clears() {
        let mut d = SamDrafter::new(4);
        d.extend(&[1, 1, 1]);
        d.reset();
        assert!(d.is_empty());
        assert!(d.draft(2).is_empty());
    }

    #[test]
    fn prop_drafts_are_history_substring_continuations() {
        // Whatever SAM drafts must literally appear in the history right
        // after an occurrence of the current suffix.
        check("sam-draft-validity", 100, |g| {
            let alpha = 2 + g.usize_in(0, 4);
            let len = 5 + g.usize_in(0, 60);
            let toks: Vec<i32> = (0..len).map(|_| g.usize_in(0, alpha) as i32).collect();
            let mut d = SamDrafter::new(8);
            d.extend(&toks);
            let out = d.draft(4);
            if out.is_empty() {
                return Ok(());
            }
            // check: exists i < len such that history[i..i+out.len] == out
            // and history[..i] ends with a suffix of the current history.
            let found = (0..toks.len().saturating_sub(out.len()) + 1)
                .any(|i| toks[i..].starts_with(&out));
            prop_assert!(found, "drafted {:?} not a substring of history", out);
            Ok(())
        });
    }

    #[test]
    fn prop_matches_ngram_on_long_patterns() {
        // On strongly periodic inputs SAM should draft at least as
        // accurately as a 3-gram.
        check("sam-vs-ngram-periodic", 30, |g| {
            let period = 3 + g.usize_in(0, 8);
            let reps = 3;
            let toks: Vec<i32> = (0..period * reps).map(|i| (i % period) as i32).collect();
            let mut sam = SamDrafter::new(8);
            sam.extend(&toks);
            let out = sam.draft(period.min(8));
            let expect: Vec<i32> = (0..out.len()).map(|i| (i % period) as i32).collect();
            prop_assert!(out == expect, "period {period}: {out:?} != {expect:?}");
            Ok(())
        });
    }
}
