//! Suffix-automaton drafter (SAM-decoding [25]).
//!
//! Builds a suffix automaton over the request's token history online
//! (amortised O(1) per appended token) and tracks the automaton state of
//! the *current suffix*. To draft, it jumps to the end position of the
//! longest history match of the current suffix and proposes the tokens
//! that followed it — like the n-gram drafter but with unbounded match
//! length and true longest-match semantics.
//!
//! Transitions live in a [`TransArena`]: one flat `Vec` of token-sorted
//! per-state blocks, looked up by binary search. Compared to the obvious
//! `HashMap<i32, u32>` per state this allocates nothing per state, keeps
//! lookups on a few cache lines, and makes `extend` allocation-free in the
//! steady state (blocks grow by amortised relocation inside the arena) —
//! see PERF.md §Memory discipline.

use super::TokenDrafter;

/// Per-state transition block descriptor inside the arena.
#[derive(Clone, Copy, Debug)]
struct Block {
    off: u32,
    len: u32,
    cap: u32,
}

/// Flat transition storage: every state's outgoing transitions are a
/// token-sorted `(token, target)` block inside one shared `Vec`.
///
/// Blocks grow by relocation to the arena tail with doubled capacity; the
/// abandoned block becomes dead space (bounded by ~2× the live transition
/// count, the classic amortised-doubling bound).
#[derive(Clone, Debug, Default)]
struct TransArena {
    data: Vec<(i32, u32)>,
    blocks: Vec<Block>,
}

impl TransArena {
    /// Append a new state with no transitions.
    fn push_state(&mut self) {
        self.blocks.push(Block { off: self.data.len() as u32, len: 0, cap: 0 });
    }

    /// Append a new state whose transitions are a snapshot of `src`'s
    /// (the SAM clone operation).
    fn push_state_cloned_from(&mut self, src: u32) {
        let b = self.blocks[src as usize];
        let off = self.data.len() as u32;
        self.data.extend_from_within(b.off as usize..(b.off + b.len) as usize);
        self.blocks.push(Block { off, len: b.len, cap: b.len });
    }

    fn seg(&self, state: u32) -> &[(i32, u32)] {
        let b = self.blocks[state as usize];
        &self.data[b.off as usize..(b.off + b.len) as usize]
    }

    /// Transition target of `state` on `token`, if present.
    fn get(&self, state: u32, token: i32) -> Option<u32> {
        let seg = self.seg(state);
        seg.binary_search_by_key(&token, |&(t, _)| t).ok().map(|i| seg[i].1)
    }

    /// Insert or overwrite `state --token--> target`, keeping the block
    /// token-sorted.
    fn set(&mut self, state: u32, token: i32, target: u32) {
        let b = self.blocks[state as usize];
        let pos = self.data[b.off as usize..(b.off + b.len) as usize]
            .binary_search_by_key(&token, |&(t, _)| t);
        match pos {
            Ok(i) => self.data[b.off as usize + i].1 = target,
            Err(i) => {
                if b.len == b.cap {
                    self.relocate(state);
                }
                let b = self.blocks[state as usize];
                let off = b.off as usize;
                let len = b.len as usize;
                // shift the tail right by one slot inside the block
                self.data.copy_within(off + i..off + len, off + i + 1);
                self.data[off + i] = (token, target);
                self.blocks[state as usize].len += 1;
            }
        }
    }

    /// Move `state`'s block to the arena tail with doubled capacity.
    fn relocate(&mut self, state: u32) {
        let b = self.blocks[state as usize];
        let new_cap = (b.cap * 2).max(2);
        let off = self.data.len() as u32;
        self.data.extend_from_within(b.off as usize..(b.off + b.len) as usize);
        // placeholder entries reserve the block's spare capacity; they sit
        // beyond `len` and are never read
        self.data.resize(off as usize + new_cap as usize, (0, 0));
        self.blocks[state as usize] = Block { off, len: b.len, cap: new_cap };
    }
}

#[derive(Clone, Copy, Debug)]
struct State {
    /// Longest substring length represented by this state.
    len: u32,
    /// Suffix link.
    link: i32,
    /// One end position (exclusive) of an occurrence of this state's
    /// substrings (the first time the state was created).
    end_pos: u32,
}

/// `Clone` supports corpus snapshot seeding: a prebuilt automaton is
/// cloned out of the published corpus snapshot into a slot.
#[derive(Clone)]
pub struct SamDrafter {
    states: Vec<State>,
    trans: TransArena,
    last: u32,
    history: Vec<i32>,
    /// Matching state/length for the current full suffix (decode cursor).
    cur_state: u32,
    cur_len: usize,
    /// Cap on drafted continuation length per call.
    pub max_draft: usize,
}

impl SamDrafter {
    pub fn new(max_draft: usize) -> Self {
        let mut trans = TransArena::default();
        trans.push_state();
        SamDrafter {
            states: vec![State { len: 0, link: -1, end_pos: 0 }],
            trans,
            last: 0,
            history: Vec::new(),
            cur_state: 0,
            cur_len: 0,
            max_draft,
        }
    }

    fn add_token(&mut self, c: i32) {
        // classic SAM online construction (Blumer et al.)
        let cur = self.states.len() as u32;
        let end_pos = (self.history.len() + 1) as u32;
        self.states.push(State {
            len: self.states[self.last as usize].len + 1,
            link: 0,
            end_pos,
        });
        self.trans.push_state();
        let mut p = self.last as i32;
        while p >= 0 && self.trans.get(p as u32, c).is_none() {
            self.trans.set(p as u32, c, cur);
            p = self.states[p as usize].link;
        }
        if p == -1 {
            self.states[cur as usize].link = 0;
        } else {
            let q = self.trans.get(p as u32, c).expect("transition exists after scan");
            if self.states[p as usize].len + 1 == self.states[q as usize].len {
                self.states[cur as usize].link = q as i32;
            } else {
                // clone q
                let clone = self.states.len() as u32;
                let mut cl = self.states[q as usize];
                cl.len = self.states[p as usize].len + 1;
                self.states.push(cl);
                self.trans.push_state_cloned_from(q);
                while p >= 0 && self.trans.get(p as u32, c) == Some(q) {
                    self.trans.set(p as u32, c, clone);
                    p = self.states[p as usize].link;
                }
                self.states[q as usize].link = clone as i32;
                self.states[cur as usize].link = clone as i32;
            }
        }
        self.last = cur;
        self.history.push(c);
    }

    /// Advance the decode cursor (matching state) by one token, following
    /// suffix links on mismatch — identical to online string matching.
    fn advance_cursor(&mut self, c: i32) {
        loop {
            if let Some(nxt) = self.trans.get(self.cur_state, c) {
                self.cur_state = nxt;
                self.cur_len += 1;
                // clamp to the state's max length
                let sl = self.states[self.cur_state as usize].len as usize;
                if self.cur_len > sl {
                    self.cur_len = sl;
                }
                return;
            }
            let link = self.states[self.cur_state as usize].link;
            if link < 0 {
                self.cur_state = 0;
                self.cur_len = 0;
                return;
            }
            self.cur_state = link as u32;
            self.cur_len = self.states[self.cur_state as usize].len as usize;
        }
    }
}

impl TokenDrafter for SamDrafter {
    fn name(&self) -> &'static str {
        "sam"
    }

    fn extend(&mut self, tokens: &[i32]) {
        for &t in tokens {
            // cursor must be advanced against the automaton *before* the
            // token is added (else it would trivially match itself)
            self.advance_cursor(t);
            self.add_token(t);
        }
    }

    fn draft_into(&mut self, n_tokens: usize, out: &mut Vec<i32>) {
        out.clear();
        if self.cur_len == 0 || self.history.is_empty() {
            return;
        }
        // end position of one occurrence of the current matched suffix
        let end = self.states[self.cur_state as usize].end_pos as usize;
        if end >= self.history.len() {
            return;
        }
        let take = n_tokens.min(self.max_draft).min(self.history.len() - end);
        out.extend_from_slice(&self.history[end..end + take]);
    }

    fn len(&self) -> usize {
        self.history.len()
    }

    fn reset(&mut self) {
        *self = SamDrafter::new(self.max_draft);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn drafts_repeated_pattern() {
        let mut d = SamDrafter::new(8);
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let out = d.draft(2);
        assert_eq!(out, vec![4, 1]);
    }

    #[test]
    fn longest_match_beats_short() {
        // suffix "9 2 3" matched once (continuation 8); the shorter "2 3"
        // also occurred earlier with continuation 7 — SAM must use the
        // longest match.
        let mut d = SamDrafter::new(8);
        d.extend(&[2, 3, 7, 0, 9, 2, 3, 8, 5, 9, 2, 3]);
        let out = d.draft(1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn empty_without_match() {
        let mut d = SamDrafter::new(8);
        d.extend(&[1, 2, 3, 4, 5]);
        assert!(d.draft(3).is_empty());
    }

    #[test]
    fn cyclic_predicts_perfectly() {
        let mut d = SamDrafter::new(16);
        let cycle: Vec<i32> = (10..30).collect();
        d.extend(&cycle);
        d.extend(&cycle);
        let out = d.draft(10);
        assert_eq!(out, (10..20).collect::<Vec<i32>>());
    }

    #[test]
    fn reset_clears() {
        let mut d = SamDrafter::new(4);
        d.extend(&[1, 1, 1]);
        d.reset();
        assert!(d.is_empty());
        assert!(d.draft(2).is_empty());
    }

    #[test]
    fn draft_into_reuses_buffer() {
        let mut d = SamDrafter::new(8);
        d.extend(&[1, 2, 3, 4, 1, 2, 3]);
        let mut buf = vec![9, 9, 9, 9, 9]; // stale contents must be cleared
        d.draft_into(2, &mut buf);
        assert_eq!(buf, vec![4, 1]);
        let cap = buf.capacity();
        d.draft_into(2, &mut buf);
        assert_eq!(buf, vec![4, 1]);
        assert_eq!(buf.capacity(), cap, "steady-state draft reallocated");
    }

    #[test]
    fn arena_set_get_overwrite_and_growth() {
        let mut a = TransArena::default();
        a.push_state();
        // out-of-order inserts must stay sorted and findable
        for (i, t) in [5, 1, 9, 3, 7, 2, 8].iter().enumerate() {
            a.set(0, *t, i as u32);
        }
        assert_eq!(a.get(0, 1), Some(1));
        assert_eq!(a.get(0, 9), Some(2));
        assert_eq!(a.get(0, 4), None);
        let seg: Vec<i32> = a.seg(0).iter().map(|&(t, _)| t).collect();
        assert_eq!(seg, vec![1, 2, 3, 5, 7, 8, 9]);
        // overwrite keeps length
        a.set(0, 3, 42);
        assert_eq!(a.get(0, 3), Some(42));
        assert_eq!(a.seg(0).len(), 7);
        // cloned block is an independent snapshot
        a.push_state_cloned_from(0);
        a.set(1, 100, 7);
        assert_eq!(a.get(1, 3), Some(42));
        assert_eq!(a.get(0, 100), None);
        assert_eq!(a.get(1, 100), Some(7));
    }

    #[test]
    fn prop_drafts_are_history_substring_continuations() {
        // Whatever SAM drafts must literally appear in the history right
        // after an occurrence of the current suffix.
        check("sam-draft-validity", 100, |g| {
            let alpha = 2 + g.usize_in(0, 4);
            let len = 5 + g.usize_in(0, 60);
            let toks: Vec<i32> = (0..len).map(|_| g.usize_in(0, alpha) as i32).collect();
            let mut d = SamDrafter::new(8);
            d.extend(&toks);
            let out = d.draft(4);
            if out.is_empty() {
                return Ok(());
            }
            // check: exists i < len such that history[i..i+out.len] == out
            // and history[..i] ends with a suffix of the current history.
            let found = (0..toks.len().saturating_sub(out.len()) + 1)
                .any(|i| toks[i..].starts_with(&out));
            prop_assert!(found, "drafted {:?} not a substring of history", out);
            Ok(())
        });
    }

    #[test]
    fn prop_matches_ngram_on_long_patterns() {
        // On strongly periodic inputs SAM should draft at least as
        // accurately as a 3-gram.
        check("sam-vs-ngram-periodic", 30, |g| {
            let period = 3 + g.usize_in(0, 8);
            let reps = 3;
            let toks: Vec<i32> = (0..period * reps).map(|i| (i % period) as i32).collect();
            let mut sam = SamDrafter::new(8);
            sam.extend(&toks);
            let out = sam.draft(period.min(8));
            let expect: Vec<i32> = (0..out.len()).map(|i| (i % period) as i32).collect();
            prop_assert!(out == expect, "period {period}: {out:?} != {expect:?}");
            Ok(())
        });
    }
}
