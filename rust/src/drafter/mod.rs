//! Draft methods: the "N" in Fastest-of-N.
//!
//! Three families, matching the paper's ladder (§4.2):
//! * model-based drafters (small SpecGPT family members run through the
//!   runtime; see `engine::draft_worker`),
//! * n-gram lookup ([`NgramDrafter`], prompt-lookup style [2]),
//! * suffix-automaton lookup ([`SamDrafter`], SAM-decoding style [25]).
//!
//! Model-free drafters implement [`TokenDrafter`] — they see only the
//! request's token history, draft in O(1)-ish per token, and run on the
//! worker's CPU (the paper piggybacks them on existing workers the same
//! way).

pub mod corpus;
pub mod ngram;
pub mod sam;

pub use corpus::{CorpusHandle, CorpusSnapshot, CorpusStats, DraftCorpus, SEGMENT_SEP};
pub use ngram::NgramDrafter;
pub use sam::SamDrafter;

/// A model-free draft method over one request's token history.
///
/// Drafting writes into a caller-provided buffer ([`draft_into`]) so the
/// engine's decode loop can reuse one `Vec` per slot across rounds — the
/// hot path does zero steady-state allocation (PERF.md §Memory
/// discipline). [`draft`] is an allocating convenience wrapper for tests
/// and one-off callers.
///
/// [`draft_into`]: TokenDrafter::draft_into
/// [`draft`]: TokenDrafter::draft
pub trait TokenDrafter: Send {
    /// Human-readable method name (ladder key).
    fn name(&self) -> &'static str;

    /// Ingest newly accepted tokens (extends the indexed history).
    fn extend(&mut self, tokens: &[i32]);

    /// Propose up to `n` next tokens given the current history, appending
    /// them to `out` (which is cleared first). May produce fewer (or none)
    /// when the structure has no prediction.
    fn draft_into(&mut self, n: usize, out: &mut Vec<i32>);

    /// Allocating wrapper around [`TokenDrafter::draft_into`].
    fn draft(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.draft_into(n, &mut out);
        out
    }

    /// Current history length (for testing / resync checks).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reset to a bare state (request restart / migration).
    fn reset(&mut self);
}

/// Identifier for a draft method in ladders/plans (model-based methods are
/// named by their model; token drafters by their algorithm).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DraftMethod {
    /// Small model drafter, e.g. "draft_small" / "draft_mid".
    Model(String),
    /// N-gram hash lookup.
    Ngram,
    /// Suffix-automaton lookup.
    Sam,
}

impl DraftMethod {
    pub fn label(&self) -> String {
        match self {
            DraftMethod::Model(m) => m.clone(),
            DraftMethod::Ngram => "ngram".to_string(),
            DraftMethod::Sam => "sam".to_string(),
        }
    }

    pub fn parse(s: &str) -> DraftMethod {
        match s {
            "ngram" => DraftMethod::Ngram,
            "sam" => DraftMethod::Sam,
            other => DraftMethod::Model(other.to_string()),
        }
    }

    pub fn is_model(&self) -> bool {
        matches!(self, DraftMethod::Model(_))
    }

    /// Model name for model-based drafting, None for token drafters.
    pub fn model_name(&self) -> Option<&str> {
        match self {
            DraftMethod::Model(m) => Some(m),
            _ => None,
        }
    }

    /// Fresh per-request token-drafter state for model-free methods
    /// (None for model-based drafting, which lives in a KV cache instead).
    /// The single construction point for drafter hyper-parameters, so a
    /// slot-plan hot swap and a worker prefill build identical state.
    pub fn new_token_drafter(&self) -> Option<Box<dyn TokenDrafter>> {
        match self {
            DraftMethod::Model(_) => None,
            DraftMethod::Ngram => Some(Box::new(NgramDrafter::new(3)) as Box<dyn TokenDrafter>),
            DraftMethod::Sam => Some(Box::new(SamDrafter::new(16)) as Box<dyn TokenDrafter>),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_label_roundtrip() {
        for m in [
            DraftMethod::Ngram,
            DraftMethod::Sam,
            DraftMethod::Model("draft_small".into()),
        ] {
            assert_eq!(DraftMethod::parse(&m.label()), m);
        }
    }
}
