//! Algorithm 3: greedy Fastest-of-N drafter assignment.
//!
//! When workers free up (their batch finished), deploy additional draft
//! methods for straggler requests: requests sorted by acceptance rate
//! ascending (worst first — they gain least from the current method),
//! methods sorted by ladder rank, each (request, method) pair mapped to
//! the least-loaded worker serving that method, bounded by `b_max`.
//!
//! Methods are identified by their **index into the ladder rank** within
//! the assignment structures, so the inner greedy loop compares and
//! inserts plain `(u64, usize)` keys — no per-pair `String` clones.
//! [`slot_plans`] converts a finished assignment into the engine's
//! [`SlotPlan`] currency for the racing replicas.

use std::collections::BTreeMap;

use crate::drafter::DraftMethod;
use crate::engine::SlotPlan;

/// A free worker that can host one additional (drafter + verifier) pair.
#[derive(Clone, Debug)]
pub struct FreeWorker {
    pub id: usize,
    /// Verification slots still available on this worker.
    pub capacity: usize,
    /// Ladder-rank index of the draft method this worker has been assigned
    /// to serve (None = any; it is fixed by the first assignment, matching
    /// the paper's one-method-per-scaled-verifier deployment).
    pub method: Option<usize>,
    pub load: usize,
}

/// Assignment map: (request, ladder-rank method index) -> worker id.
pub type Assignment = BTreeMap<(u64, usize), usize>;

/// Inputs: straggler requests with their acceptance rates and the methods
/// already attached to them.
#[derive(Clone, Debug)]
pub struct Straggler {
    pub request: u64,
    pub accept_rate: f64,
    pub methods: Vec<String>,
}

/// Algorithm 3. `ladder_rank` must list methods best-first; assignment
/// keys index into it.
pub fn assign(
    stragglers: &mut [Straggler],
    ladder_rank: &[String],
    workers: &mut [FreeWorker],
    b_max: usize,
) -> Assignment {
    let mut out = Assignment::new();
    // line 1: sort requests by acceptance rate ascending (total_cmp: a NaN
    // rate from a 0/0 measurement must not panic the scheduler)
    stragglers.sort_by(|a, b| a.accept_rate.total_cmp(&b.accept_rate));
    // lines 3–9: draft-first greedy
    for r in stragglers.iter() {
        for (mi, method) in ladder_rank.iter().enumerate() {
            if r.methods.iter().any(|m| m == method) || out.contains_key(&(r.request, mi)) {
                continue; // M(r, d) is not None
            }
            // GetMinLoadWorker(W_d, b_max): least-loaded worker already
            // serving `method`, else claim an unassigned worker.
            let cand = workers
                .iter_mut()
                .filter(|w| {
                    w.load < w.capacity.min(b_max)
                        && (w.method == Some(mi) || w.method.is_none())
                })
                .min_by_key(|w| (w.method.is_none() as usize, w.load));
            match cand {
                Some(w) => {
                    w.method.get_or_insert(mi);
                    w.load += 1;
                    out.insert((r.request, mi), w.id);
                }
                None => continue,
            }
        }
    }
    out
}

/// Route an assignment into per-replica slot plans: each (request, method)
/// pair becomes `(request, worker, SlotPlan)` for the racing replica —
/// coupled speculation at `window` (dedicated tail service at b ≈ 1, per
/// Algorithm 2's modelling; the replica that finishes first wins and
/// losslessness makes the race output-invariant).
pub fn slot_plans(
    a: &Assignment,
    ladder_rank: &[String],
    window: usize,
) -> Vec<(u64, usize, SlotPlan)> {
    a.iter()
        .map(|(&(req, mi), &wid)| {
            (req, wid, SlotPlan::coupled(DraftMethod::parse(&ladder_rank[mi]), window))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    fn workers(n: usize, cap: usize) -> Vec<FreeWorker> {
        (0..n).map(|id| FreeWorker { id, capacity: cap, method: None, load: 0 }).collect()
    }

    fn rank() -> Vec<String> {
        vec!["draft_mid".into(), "draft_small".into(), "ngram".into()]
    }

    #[test]
    fn worst_request_gets_most_methods() {
        let mut s = vec![
            Straggler { request: 1, accept_rate: 0.9, methods: vec!["draft_small".into()] },
            Straggler { request: 2, accept_rate: 0.2, methods: vec!["draft_small".into()] },
        ];
        let mut w = workers(2, 1); // only 2 slots total
        let a = assign(&mut s, &rank(), &mut w, 1);
        // request 2 (worst) must be served first and get both free slots
        let r2: Vec<_> = a.keys().filter(|(r, _)| *r == 2).collect();
        let r1: Vec<_> = a.keys().filter(|(r, _)| *r == 1).collect();
        assert_eq!(r2.len(), 2, "worst straggler under-served: {a:?}");
        assert_eq!(r1.len(), 0);
    }

    #[test]
    fn never_duplicates_existing_method() {
        let mut s = vec![Straggler {
            request: 7,
            accept_rate: 0.1,
            methods: vec!["draft_mid".into(), "draft_small".into(), "ngram".into()],
        }];
        let mut w = workers(4, 8);
        let a = assign(&mut s, &rank(), &mut w, 8);
        assert!(a.is_empty(), "assigned a method the request already has");
    }

    #[test]
    fn respects_capacity() {
        let mut s: Vec<Straggler> = (0..10)
            .map(|i| Straggler { request: i, accept_rate: 0.1, methods: vec![] })
            .collect();
        let mut w = workers(1, 3);
        let a = assign(&mut s, &rank(), &mut w, 8);
        assert_eq!(a.len(), 3, "capacity 3 exceeded: {}", a.len());
        assert_eq!(w[0].load, 3);
    }

    #[test]
    fn b_max_caps_load() {
        let mut s: Vec<Straggler> = (0..10)
            .map(|i| Straggler { request: i, accept_rate: 0.1, methods: vec![] })
            .collect();
        let mut w = workers(1, 100);
        let a = assign(&mut s, &rank(), &mut w, 4);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn one_method_per_scaled_worker() {
        let mut s: Vec<Straggler> = (0..6)
            .map(|i| Straggler { request: i, accept_rate: 0.1 * i as f64, methods: vec![] })
            .collect();
        let mut w = workers(3, 4);
        let _ = assign(&mut s, &rank(), &mut w, 4);
        for wk in &w {
            assert!(wk.method.is_some() || wk.load == 0);
        }
    }

    #[test]
    fn nan_acceptance_does_not_panic() {
        let mut s = vec![
            Straggler { request: 0, accept_rate: f64::NAN, methods: vec![] },
            Straggler { request: 1, accept_rate: 0.4, methods: vec![] },
        ];
        let mut w = workers(1, 2);
        let a = assign(&mut s, &rank(), &mut w, 2);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn slot_plans_map_rank_indices_to_methods() {
        let mut s = vec![Straggler { request: 5, accept_rate: 0.1, methods: vec![] }];
        let mut w = workers(1, 2);
        let a = assign(&mut s, &rank(), &mut w, 2);
        let plans = slot_plans(&a, &rank(), 3);
        assert_eq!(plans.len(), a.len());
        for (req, wid, plan) in &plans {
            assert_eq!(*req, 5);
            assert_eq!(*wid, 0);
            assert_eq!(plan.window, 3);
            assert!(rank().contains(&plan.method.label()));
        }
    }

    #[test]
    fn prop_assignment_invariants() {
        check("fon-invariants", 150, |g| {
            let n_req = 1 + g.usize_in(0, 12);
            let n_work = g.usize_in(0, 6);
            let cap = 1 + g.usize_in(0, 6);
            let b_max = 1 + g.usize_in(0, 6);
            let mut s: Vec<Straggler> = (0..n_req)
                .map(|i| Straggler {
                    request: i as u64,
                    accept_rate: g.prob(),
                    methods: if g.bool() { vec!["draft_mid".into()] } else { vec![] },
                })
                .collect();
            let mut w = workers(n_work, cap);
            let a = assign(&mut s, &rank(), &mut w, b_max);
            let rank = rank();
            // no worker overloaded
            for wk in &w {
                prop_assert!(
                    wk.load <= wk.capacity.min(b_max),
                    "worker {} load {} cap {}",
                    wk.id,
                    wk.load,
                    wk.capacity.min(b_max)
                );
            }
            // no (request, method) duplicate of existing methods
            for ((r, mi), _) in &a {
                let st = s.iter().find(|x| x.request == *r).unwrap();
                prop_assert!(*mi < rank.len(), "method index {mi} out of rank");
                prop_assert!(
                    !st.methods.contains(&rank[*mi]),
                    "duplicated {} for {r}",
                    rank[*mi]
                );
            }
            // every assignment points at a real worker serving that method
            for ((_, mi), wid) in &a {
                let wk = w.iter().find(|x| x.id == *wid).unwrap();
                prop_assert!(wk.method == Some(*mi), "worker method mismatch");
            }
            // total assignments = total load
            let total: usize = w.iter().map(|x| x.load).sum();
            prop_assert!(total == a.len(), "load {total} != assignments {}", a.len());
            Ok(())
        });
    }
}
