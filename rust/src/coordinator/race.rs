//! Algorithm 3 executed **in-process**: fork a straggler into replica
//! slots, race draft methods, first finisher wins.
//!
//! `fon::assign` decides *which* methods chase *which* stragglers; this
//! module makes the race real. A racing replica is a [`Worker::fork`] of
//! the straggler's live slot — the verified-prefix KV row copied through
//! the `extract_row`/`insert_row` migration path plus the request state,
//! with its own [`SlotPlan`] naming the raced draft method. Because the
//! sampling tape is keyed by (seed, request id, position), every member
//! of a race generates the IDENTICAL token stream; only round counts
//! differ, so "fastest of N" can never change the rollout output. The
//! [`RaceArbiter`] enforces that invariant at resolution time: finished
//! members must agree exactly and unfinished members must hold a prefix
//! of the winner's sequence — a divergence is a hard losslessness error,
//! not a metric.
//!
//! Races are *priced before launch* ([`race_gain`]): rounds saved by the
//! replica's profiled acceptance × the fused round time, minus the fork
//! cost ([`CostModel::fork_cost`]), the replica's extra verify row riding
//! every fused step ([`CostModel::replica_overhead`] — β-free, the whole
//! reason racing on freed capacity is cheap) and its own drafting.
//! Algorithm 3 only launches races it expects to win.
//!
//! Drivers: the serve loop (`serve::Batcher` with `--fon-race`) spends
//! idle slots on tail races when occupancy drops; the global coordinator
//! (`coordinator::global::rollout`) and `examples/fon_demo.rs` race via
//! [`race_in_process`]. Everything is generic over [`ServeEngine`], so
//! the arbiter runs identically on the real [`Worker`] and the hermetic
//! `SyntheticEngine` (unit tests, `serve --smoke --fon-race`, CI).
//!
//! [`Worker`]: crate::engine::Worker
//! [`Worker::fork`]: crate::engine::Worker::fork

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::reconfig::cost_method;
use crate::drafter::DraftMethod;
use crate::engine::{EngineConfig, EngineReport, Request, SlotPlan, SpecError, Worker};
use crate::planner::costmodel::CostModel;
use crate::planner::tgs::{step_up, tau_coupled};
use crate::runtime::{Manifest, Runtime};
use crate::serve::ServeEngine;

/// Race-launch policy knobs.
#[derive(Clone, Debug)]
pub struct RaceConfig {
    /// Launch races only while occupancy (requests + replicas) is at or
    /// below this fraction of engine capacity: races spend *idle* slots,
    /// they never crowd out admissions (which also preempt them — see
    /// `Batcher::tick`).
    pub occupancy_frac: f64,
    /// Replicas a single race may fork (Algorithm 3's `b_max` at slot
    /// scale).
    pub max_replicas: usize,
    /// Skip requests with fewer remaining tokens than this: a fork cannot
    /// pay for itself on an almost-finished request.
    pub min_remaining: usize,
    /// Absolute measured acceptance below which a slot is raceable even
    /// without a below-mean comparison: the flagship FoN case is the LAST
    /// straggler decoding alone on idle capacity (or N equal-rate tails),
    /// where no slot can be *strictly below* the live mean.
    pub solo_accept: f64,
    /// Ladder rank best-first: (method label, profiled acceptance). The
    /// race skips the straggler's current method and walks down the rank.
    pub rank: Vec<(String, f64)>,
    /// Verifiable draft windows (ascending) for fused pricing
    /// (`step_up`).
    pub windows: Vec<usize>,
}

impl RaceConfig {
    pub fn new(rank: Vec<(String, f64)>, windows: Vec<usize>) -> Self {
        RaceConfig {
            occupancy_frac: 0.5,
            max_replicas: 2,
            min_remaining: 4,
            solo_accept: 0.5,
            rank,
            windows,
        }
    }
}

#[derive(Clone, Debug)]
struct Replica {
    slot: usize,
    method: String,
    /// `Request::iterations` at fork time: the replica's rounds since are
    /// pure waste if it loses.
    rounds_at_fork: u64,
}

/// One in-flight race: the straggler's original slot plus its replicas.
#[derive(Clone, Debug)]
pub struct Race {
    pub request: u64,
    pub primary: usize,
    replicas: Vec<Replica>,
}

/// A resolved race.
#[derive(Clone, Debug)]
pub struct RaceFinish {
    pub request: u64,
    pub primary: usize,
    pub winner_slot: usize,
    /// Winning member's draft-method label (the primary's own method when
    /// it held on).
    pub winner_method: String,
    /// True when a replica finished strictly before the primary — the
    /// paper's `fon_win`.
    pub replica_won: bool,
    /// The winner's retired request (tokens, acceptance stats).
    pub req: Request,
    /// Replicas cancelled by this resolution.
    pub cancelled: usize,
    /// Replica rounds thrown away by this resolution.
    pub wasted_rounds: u64,
    /// Every slot this resolution freed (winner + cancelled members).
    pub freed: Vec<usize>,
}

/// Cancelled-race accounting (admission preemption).
#[derive(Clone, Debug, Default)]
pub struct Cancelled {
    pub freed: Vec<usize>,
    pub replicas: usize,
    pub wasted_rounds: u64,
}

/// Steps races to resolution: detects the first finisher, cancels the
/// losers, retires the winner, and keeps the launch/win/waste ledger.
pub struct RaceArbiter {
    cost: CostModel,
    pub cfg: RaceConfig,
    races: Vec<Race>,
    /// Races started.
    pub races_started: u64,
    /// Replicas forked.
    pub launches: u64,
    /// Races a replica finished strictly first.
    pub wins: u64,
    pub wins_by_method: BTreeMap<String, u64>,
    pub cancelled_replicas: u64,
    pub wasted_replica_rounds: u64,
}

impl RaceArbiter {
    pub fn new(cost: CostModel, cfg: RaceConfig) -> Self {
        RaceArbiter {
            cost,
            cfg,
            races: Vec::new(),
            races_started: 0,
            launches: 0,
            wins: 0,
            wins_by_method: BTreeMap::new(),
            cancelled_replicas: 0,
            wasted_replica_rounds: 0,
        }
    }

    /// Arbiter for externally-forked races only ([`RaceArbiter::register`]
    /// — `race_in_process`, tests): an empty rank disables `consider`.
    pub fn manual() -> Self {
        Self::new(CostModel::paper_32b(), RaceConfig::new(Vec::new(), vec![1, 3, 7]))
    }

    /// Arbiter wired to a lowered artifact set: verifiable draft windows
    /// from the manifest, rank from the caller's profiled ladder.
    pub fn for_manifest(m: &Manifest, cost: CostModel, rank: Vec<(String, f64)>) -> Self {
        let cfg = RaceConfig::new(rank, m.draft_windows());
        Self::new(cost, cfg)
    }

    /// Default arbiter for the synthetic smoke engine: the paper cost
    /// model, the default AOT window grid, and the profiled model ladder
    /// extended with the token drafters every worker can host (best
    /// profiled acceptance first, as `fon::assign` expects).
    pub fn synthetic() -> Self {
        let rank = vec![
            ("draft_mid".to_string(), 0.82),
            ("sam".to_string(), 0.80),
            ("draft_small".to_string(), 0.74),
            ("ngram".to_string(), 0.40),
        ];
        Self::new(CostModel::paper_32b(), RaceConfig::new(rank, vec![1, 3, 7]))
    }

    /// Is `slot` part of an in-flight race (primary or replica)?
    pub fn is_member(&self, slot: usize) -> bool {
        self.races
            .iter()
            .any(|r| r.primary == slot || r.replicas.iter().any(|x| x.slot == slot))
    }

    pub fn active_races(&self) -> usize {
        self.races.len()
    }

    /// Register the arbiter's own launch/win/waste ledger into a scrape
    /// snapshot (`specactor_race_*`) — the arbiter-side counterpart of
    /// the `ServeMetrics` race series, kept separate so the two ledgers
    /// can be reconciled against each other.
    pub fn register_metrics(&self, reg: &mut crate::obs::MetricRegistry) {
        reg.counter(
            "specactor_race_started",
            "Fastest-of-N races started by the arbiter",
            self.races_started as f64,
        );
        reg.counter(
            "specactor_race_replicas_forked",
            "Replicas forked across all races",
            self.launches as f64,
        );
        reg.counter(
            "specactor_race_replica_wins",
            "Races a replica finished strictly first",
            self.wins as f64,
        );
        for (method, v) in &self.wins_by_method {
            reg.counter_l(
                "specactor_race_replica_wins_by_method",
                "Replica wins per draft method",
                &[("method", method)],
                *v as f64,
            );
        }
        reg.counter(
            "specactor_race_replicas_cancelled",
            "Replicas cancelled (race lost or preempted)",
            self.cancelled_replicas as f64,
        );
        reg.counter(
            "specactor_race_wasted_replica_rounds",
            "Rounds spent by replicas that were then cancelled",
            self.wasted_replica_rounds as f64,
        );
        reg.gauge(
            "specactor_race_active",
            "Races currently in flight",
            self.races.len() as f64,
        );
    }

    /// Register an externally-forked race (the caller already forked
    /// `replica_slots` off `primary`).
    pub fn register<E: ServeEngine>(
        &mut self,
        engine: &E,
        primary: usize,
        replica_slots: &[usize],
    ) -> Result<()> {
        let id = engine
            .request(primary)
            .ok_or_else(|| anyhow!("race primary slot {primary} is empty"))?
            .id;
        let mut replicas = Vec::with_capacity(replica_slots.len());
        for &slot in replica_slots {
            let r = engine
                .request(slot)
                .ok_or_else(|| anyhow!("race replica slot {slot} is empty"))?;
            let method = engine
                .slot_plan(slot)
                .ok_or_else(|| anyhow!("race replica slot {slot} has no plan"))?
                .method
                .label();
            replicas.push(Replica { slot, method, rounds_at_fork: r.iterations });
        }
        if replicas.is_empty() {
            bail!("a race needs at least one replica");
        }
        self.races_started += 1;
        self.launches += replicas.len() as u64;
        self.races.push(Race { request: id, primary, replicas });
        Ok(())
    }

    /// Consider launching ONE race on idle capacity: pick the live
    /// speculative slot with the worst measured acceptance — raceable
    /// when strictly below the live mean, or absolutely bad
    /// ([`RaceConfig::solo_accept`], the lone-last-straggler case) — and
    /// enough work left, then fork one replica per positively-priced
    /// next-rank method into the caller-provided `pool` slots (a prefix
    /// is consumed; the caller releases the rest). Returns the number of
    /// pool slots used.
    pub fn consider<E: ServeEngine>(
        &mut self,
        engine: &mut E,
        occupancy: usize,
        pool: &[usize],
    ) -> Result<usize> {
        if pool.is_empty() || self.cfg.rank.len() < 2 {
            return Ok(0);
        }
        let cap = engine.capacity();
        if occupancy as f64 > cap as f64 * self.cfg.occupancy_frac {
            return Ok(0);
        }
        // gather live speculative slots with acceptance evidence and
        // enough remaining work to be worth rescuing
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for slot in 0..cap {
            if self.is_member(slot) || engine.is_done(slot) {
                continue;
            }
            let Some(r) = engine.request(slot) else { continue };
            let Some(p) = engine.slot_plan(slot) else { continue };
            if p.window == 0 || r.accept.proposed == 0 {
                continue;
            }
            if r.budget - r.generated() < self.cfg.min_remaining {
                continue;
            }
            rates.push((slot, r.accept.rate()));
        }
        // the worst-acceptance slot is raceable when it stands out below
        // the live mean, OR when it is absolutely bad (`solo_accept`) —
        // the latter covers the last straggler decoding alone and a tail
        // of equal-rate stragglers, where nothing is strictly below mean
        let Some(&(primary, p_cur)) =
            rates.iter().min_by(|a, b| a.1.total_cmp(&b.1))
        else {
            return Ok(0);
        };
        let mean = rates.iter().map(|(_, p)| p).sum::<f64>() / rates.len() as f64;
        let stands_out = rates.len() >= 2 && p_cur < mean;
        if !stands_out && p_cur >= self.cfg.solo_accept {
            return Ok(0);
        }

        // the candidate was scanned live with a plan just above, so a
        // miss here means the engine's slot table is inconsistent — a
        // typed SlotFatal, not a panic (the batcher quarantines it)
        let Some(plan) = engine.slot_plan(primary) else {
            return Err(SpecError::RequestStateInconsistent {
                slot: primary,
                detail: "race candidate lost its plan between scan and fork".into(),
            }
            .into());
        };
        let cur_label = plan.method.label();
        let (id, remaining) = {
            let Some(r) = engine.request(primary) else {
                return Err(SpecError::RequestStateInconsistent {
                    slot: primary,
                    detail: "race candidate is no longer live".into(),
                }
                .into());
            };
            (r.id, r.budget - r.generated())
        };
        let w = plan.window.max(1);
        let w_step = step_up(&self.cfg.windows, w);
        let b = occupancy.max(1);
        let mut used = 0usize;
        let mut replicas = Vec::new();
        for (method, p_new) in &self.cfg.rank {
            if used >= pool.len() || replicas.len() >= self.cfg.max_replicas {
                break;
            }
            if *method == cur_label {
                continue;
            }
            // launch gate: only races the model expects to win (priced
            // with the cost family the method maps to — sam borrows the
            // n-gram curve, unknown drafters too)
            let cost_key = cost_method(&self.cost, &DraftMethod::parse(method));
            let gain = race_gain(
                &self.cost,
                &cost_key,
                self.cost.g_ref,
                w,
                w_step,
                b,
                p_cur,
                *p_new,
                remaining,
            );
            if gain <= 0.0 {
                continue;
            }
            let dst = pool[used];
            // A failed fork leaves `dst` unoccupied (Worker::fork mutates
            // the slot table only after every fallible step), so degrade
            // to racing whatever was already forked instead of erroring —
            // an Err here would orphan live replicas (no race registered)
            // and leak the caller's pool slots.
            if engine
                .fork(primary, dst, SlotPlan::coupled(DraftMethod::parse(method), w))
                .is_err()
            {
                break;
            }
            let rounds_at_fork = engine.request(dst).map(|r| r.iterations).unwrap_or(0);
            replicas.push(Replica { slot: dst, method: method.clone(), rounds_at_fork });
            used += 1;
        }
        if replicas.is_empty() {
            return Ok(0);
        }
        self.races_started += 1;
        self.launches += replicas.len() as u64;
        self.races.push(Race { request: id, primary, replicas });
        Ok(used)
    }

    /// Resolve every race with a finished member: first finisher wins
    /// (ties go to the primary — a replica win must be strictly earlier),
    /// losers are cancelled, the winner is retired and returned. Verifies
    /// the losslessness invariant across members before touching anything.
    pub fn resolve<E: ServeEngine>(&mut self, engine: &mut E) -> Result<Vec<RaceFinish>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.races.len() {
            let race = &self.races[i];
            let any_done = engine.is_done(race.primary)
                || race.replicas.iter().any(|r| engine.is_done(r.slot));
            if !any_done {
                i += 1;
                continue;
            }
            let race = self.races.swap_remove(i);
            out.push(self.finish(engine, race)?);
        }
        Ok(out)
    }

    fn finish<E: ServeEngine>(&mut self, engine: &mut E, race: Race) -> Result<RaceFinish> {
        let winner = if engine.is_done(race.primary) {
            None
        } else {
            race.replicas.iter().position(|r| engine.is_done(r.slot))
        };
        let winner_slot = winner.map(|ri| race.replicas[ri].slot).unwrap_or(race.primary);
        // losslessness gate: finished members must agree exactly with the
        // winner; unfinished members must hold a prefix of its sequence
        let win_seq = engine
            .request(winner_slot)
            .ok_or_else(|| anyhow!("race winner slot {winner_slot} is empty"))?
            .seq
            .clone();
        let members = std::iter::once(race.primary).chain(race.replicas.iter().map(|r| r.slot));
        for slot in members {
            let r = engine
                .request(slot)
                .ok_or_else(|| anyhow!("race member slot {slot} is empty"))?;
            let ok = if engine.is_done(slot) {
                r.seq == win_seq
            } else {
                win_seq.len() >= r.seq.len() && win_seq[..r.seq.len()] == r.seq[..]
            };
            if !ok {
                bail!(
                    "losslessness violated: race member in slot {slot} diverged from the \
                     winner for request {}",
                    race.request
                );
            }
        }
        // cancel losing replicas (their rounds since the fork are waste)
        let mut freed = Vec::with_capacity(1 + race.replicas.len());
        let mut cancelled = 0usize;
        let mut wasted = 0u64;
        for (ri, rep) in race.replicas.iter().enumerate() {
            if winner == Some(ri) {
                continue;
            }
            let req = engine.retire(rep.slot)?;
            wasted += req.iterations.saturating_sub(rep.rounds_at_fork);
            cancelled += 1;
            freed.push(rep.slot);
        }
        let (winner_method, replica_won) = match winner {
            Some(ri) => (race.replicas[ri].method.clone(), true),
            None => {
                let label = engine
                    .slot_plan(race.primary)
                    .map(|p| p.method.label())
                    .unwrap_or_default();
                (label, false)
            }
        };
        if replica_won {
            // the primary lost: retire it too (its pre-fork rounds were
            // necessary work, so they are not counted as replica waste)
            engine.retire(race.primary)?;
            freed.push(race.primary);
        }
        let req = engine.retire(winner_slot)?;
        freed.push(winner_slot);
        self.cancelled_replicas += cancelled as u64;
        self.wasted_replica_rounds += wasted;
        if replica_won {
            self.wins += 1;
            *self.wins_by_method.entry(winner_method.clone()).or_insert(0) += 1;
        }
        Ok(RaceFinish {
            request: race.request,
            primary: race.primary,
            winner_slot,
            winner_method,
            replica_won,
            req,
            cancelled,
            wasted_rounds: wasted,
            freed,
        })
    }

    /// Cancel the most recent race outright: replica slots are freed, the
    /// primary keeps decoding as an ordinary slot. The serve loop preempts
    /// races this way when real admissions need the capacity.
    pub fn cancel_one<E: ServeEngine>(&mut self, engine: &mut E) -> Result<Cancelled> {
        let Some(race) = self.races.pop() else {
            return Ok(Cancelled::default());
        };
        let mut out = Cancelled::default();
        for rep in &race.replicas {
            let req = engine.retire(rep.slot)?;
            out.wasted_rounds += req.iterations.saturating_sub(rep.rounds_at_fork);
            out.replicas += 1;
            out.freed.push(rep.slot);
        }
        self.cancelled_replicas += out.replicas as u64;
        self.wasted_replica_rounds += out.wasted_rounds;
        Ok(out)
    }
}

/// Modelled net gain (seconds) of racing `method_new` (cost-model key)
/// against the incumbent on a straggler with `remaining` tokens left:
/// rounds saved × the fused round time, minus the replica's costs — the
/// fork ([`CostModel::fork_cost`]), its extra verify row riding every
/// fused step ([`CostModel::replica_overhead`]; β-free) and its own
/// drafting at b = 1. Positive gain = a race Algorithm 3 expects to win.
#[allow(clippy::too_many_arguments)]
pub fn race_gain(
    m: &CostModel,
    method_new: &str,
    g_v: usize,
    w: usize,
    w_step: usize,
    b: usize,
    p_cur: f64,
    p_new: f64,
    remaining: usize,
) -> f64 {
    let w = w.max(1);
    let w_step = w_step.max(w);
    let b = b.max(1);
    let tokens_per_round = |p: f64| tau_coupled(w, p.clamp(0.0, 1.0)).max(1e-9);
    let t_round = m.verify_fused(g_v, w as f64, w_step, b);
    let rounds_cur = remaining as f64 / tokens_per_round(p_cur);
    let rounds_new = remaining as f64 / tokens_per_round(p_new);
    let overhead = m.fork_cost
        + rounds_new * m.replica_overhead(g_v, w as f64, w_step, b)
        + rounds_new * w as f64 * m.draft(method_new, 1);
    (rounds_cur - rounds_new) * t_round - overhead
}

/// Outcome of one [`race_in_process`] run.
#[derive(Clone, Debug)]
pub struct RaceRunOut {
    /// Winning member's method label.
    pub winner_method: String,
    pub replica_won: bool,
    /// Generated tokens of the winner (prompt excluded).
    pub tokens: Vec<i32>,
    /// Engine rounds until resolution.
    pub rounds: u64,
    pub launches: usize,
    pub cancelled_replicas: usize,
    pub wasted_replica_rounds: u64,
}

/// Race `replica_plans` against `primary` for one request inside a single
/// fused worker: admit the primary, fork one replica per plan, round
/// until the first member finishes. The global coordinator
/// (`coordinator::global::rollout`) and `examples/fon_demo.rs` drive
/// Algorithm 3's planned races through this.
pub fn race_in_process(
    rt: &Runtime,
    id: u64,
    prompt: &[i32],
    budget: usize,
    primary: SlotPlan,
    replica_plans: &[SlotPlan],
    ecfg: &EngineConfig,
) -> Result<RaceRunOut> {
    if replica_plans.is_empty() {
        bail!("no replica plans to race");
    }
    let mut w = Worker::with_capacity(rt, ecfg.clone(), 1 + replica_plans.len())?;
    w.admit_with_plan(0, Request::new(id, prompt.to_vec(), budget), primary)?;
    let mut replica_slots = Vec::with_capacity(replica_plans.len());
    for (k, plan) in replica_plans.iter().enumerate() {
        w.fork(0, k + 1, plan.clone())?;
        replica_slots.push(k + 1);
    }
    let mut ar = RaceArbiter::manual();
    ar.register(&w, 0, &replica_slots)?;
    let mut rep = EngineReport::default();
    let fin = loop {
        if w.round(&mut rep)? == 0 {
            bail!("race drained without a finisher for request {id}");
        }
        if let Some(f) = ar.resolve(&mut w)?.pop() {
            break f;
        }
    };
    Ok(RaceRunOut {
        winner_method: fin.winner_method,
        replica_won: fin.replica_won,
        tokens: fin.req.seq[fin.req.prompt.len()..].to_vec(),
        rounds: rep.iterations,
        launches: replica_slots.len(),
        cancelled_replicas: fin.cancelled,
        wasted_replica_rounds: fin.wasted_rounds,
    })
}

/// Pick the slot most worth racing ACROSS workers: among the engine's
/// live, unfinished slots that are not already race members, the one with
/// the worst lifetime acceptance rate whose remaining budget still
/// justifies a fork (`min_remaining`, the same floor [`RaceConfig`]
/// applies in-process). The cluster supervisor calls this per source
/// worker and forks the winner onto a *remote* idle slot — Algorithm 3's
/// Fastest-of-N at fleet scale, where the spare capacity lives on a
/// different runtime.
pub fn cross_race_candidate<E: ServeEngine>(
    engine: &E,
    is_member: impl Fn(usize) -> bool,
    min_remaining: usize,
) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for s in 0..engine.capacity() {
        if is_member(s) || engine.is_done(s) {
            continue;
        }
        let Some(r) = engine.request(s) else {
            continue;
        };
        if r.done || r.budget.saturating_sub(r.generated()) < min_remaining {
            continue;
        }
        let rate = r.accept.rate();
        let better = match best {
            None => true,
            Some((_, b)) => rate < b,
        };
        if better {
            best = Some((s, rate));
        }
    }
    best.map(|(s, _)| s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlanMode;
    use crate::serve::SyntheticEngine;

    fn spec_plan(method: DraftMethod, w: usize) -> SlotPlan {
        SlotPlan { method, window: w, mode: PlanMode::Coupled }
    }

    /// Engine with a healthy request (id 0) and a tail straggler (id 3 —
    /// `SyntheticEngine` tails accept 0.2 everywhere except sam's 0.8),
    /// stepped until acceptance evidence accumulates.
    fn skewed_engine(budget: usize) -> (SyntheticEngine, EngineReport) {
        let mut e = SyntheticEngine::new(8, 42);
        e.admit(0, Request::new(0, vec![1; 4], budget), spec_plan(DraftMethod::Ngram, 3))
            .unwrap();
        e.admit(1, Request::new(3, vec![1; 4], budget), spec_plan(DraftMethod::Ngram, 3))
            .unwrap();
        let mut rep = EngineReport::default();
        for _ in 0..4 {
            e.round(&mut rep).unwrap();
        }
        (e, rep)
    }

    #[test]
    fn race_gain_prices_uplift_and_overheads() {
        let m = CostModel::paper_32b();
        // a big acceptance uplift on a long remaining tail pays
        assert!(race_gain(&m, "ngram", 4, 3, 3, 4, 0.2, 0.8, 64) > 0.0);
        // no uplift = pure overhead
        assert!(race_gain(&m, "ngram", 4, 3, 3, 4, 0.8, 0.8, 64) < 0.0);
        // an almost-finished request cannot amortise the fork
        let short = race_gain(&m, "ngram", 4, 3, 3, 4, 0.2, 0.8, 1);
        let long = race_gain(&m, "ngram", 4, 3, 3, 4, 0.2, 0.8, 64);
        assert!(long > short);
    }

    #[test]
    fn consider_races_the_tail_and_replica_wins() {
        let (mut e, _rep) = skewed_engine(40);
        let mut ar = RaceArbiter::synthetic();
        // id 3's measured acceptance (~0.2) is far below the mean
        let used = ar.consider(&mut e, 2, &[4, 5]).unwrap();
        assert!(used > 0, "the tail straggler must be raced");
        assert_eq!(ar.races_started, 1);
        assert_eq!(ar.launches as usize, used);
        assert!(ar.is_member(1), "primary is a race member");
        assert!(ar.is_member(4), "first pool slot hosts a replica");
        // the sam replica accepts 0.8 on the tail id: it must finish first
        let mut rep = EngineReport::default();
        let fin = loop {
            e.round(&mut rep).unwrap();
            if let Some(f) = ar.resolve(&mut e).unwrap().pop() {
                break f;
            }
        };
        assert_eq!(fin.request, 3);
        assert!(fin.replica_won, "sam must beat the 0.2-acceptance primary");
        assert_eq!(fin.winner_method, "sam");
        assert_eq!(ar.wins, 1);
        assert_eq!(ar.wins_by_method.get("sam"), Some(&1));
        // everything the race touched is freed, the winner's output kept
        assert_eq!(fin.freed.len(), 1 + fin.cancelled + 1); // replicas + primary + winner
        assert_eq!(fin.req.generated(), 40);
        assert!(ar.resolve(&mut e).unwrap().is_empty());
        assert_eq!(ar.active_races(), 0);
    }

    #[test]
    fn primary_win_counts_no_fon_win() {
        // race a HEALTHY slot by hand: the ngram replica advances exactly
        // as fast as its ngram primary (same id, same tape), so they
        // finish in the same round — and ties go to the primary
        let (mut e, _rep) = skewed_engine(40);
        e.retire(1).unwrap(); // drop the tail; race the healthy slot 0
        let mut ar = RaceArbiter::manual();
        e.fork(0, 4, spec_plan(DraftMethod::Ngram, 3)).unwrap();
        ar.register(&e, 0, &[4]).unwrap();
        let mut rep = EngineReport::default();
        let fin = loop {
            e.round(&mut rep).unwrap();
            if let Some(f) = ar.resolve(&mut e).unwrap().pop() {
                break f;
            }
        };
        assert!(!fin.replica_won, "a tie must go to the primary");
        assert_eq!(ar.wins, 0);
        assert_eq!(ar.cancelled_replicas, 1);
        assert!(ar.wasted_replica_rounds > 0);
    }

    #[test]
    fn consider_skips_high_occupancy_and_negative_gain() {
        let (mut e, _r) = skewed_engine(40);
        let mut ar = RaceArbiter::synthetic();
        // occupancy above the threshold: no race even with a tail
        assert_eq!(ar.consider(&mut e, 7, &[4, 5]).unwrap(), 0);
        assert_eq!(ar.races_started, 0);
        // a rank with zero profiled acceptance can never save a round:
        // every candidate race prices negative and the launch gate holds
        let mut ar2 = RaceArbiter::synthetic();
        ar2.cfg.rank = vec![("draft_small".to_string(), 0.0), ("sam".to_string(), 0.0)];
        assert_eq!(ar2.consider(&mut e, 2, &[4, 5]).unwrap(), 0);
        assert_eq!(ar2.races_started, 0);
    }

    #[test]
    fn cancel_one_frees_replicas_and_keeps_the_primary() {
        let (mut e, _r) = skewed_engine(40);
        let mut ar = RaceArbiter::synthetic();
        let used = ar.consider(&mut e, 2, &[4, 5]).unwrap();
        assert!(used > 0);
        let c = ar.cancel_one(&mut e).unwrap();
        assert_eq!(c.replicas, used);
        assert_eq!(c.freed.len(), used);
        assert_eq!(ar.active_races(), 0);
        assert!(!ar.is_member(1), "primary reverts to an ordinary slot");
        assert!(e.request(1).is_some(), "primary keeps decoding");
        assert!(e.request(4).is_none(), "replica slot is freed");
    }

    #[test]
    fn lone_last_straggler_is_raceable() {
        // the flagship FoN case: one tail request decoding alone on an
        // otherwise idle engine. There is no live mean to stand out from,
        // but its absolute acceptance is terrible (`solo_accept`), so the
        // idle capacity must still be spent on the race.
        let mut e = SyntheticEngine::new(8, 13);
        e.admit(0, Request::new(3, vec![1; 4], 40), spec_plan(DraftMethod::Ngram, 3))
            .unwrap();
        let mut rep = EngineReport::default();
        for _ in 0..4 {
            e.round(&mut rep).unwrap();
        }
        let mut ar = RaceArbiter::synthetic();
        let used = ar.consider(&mut e, 1, &[4, 5]).unwrap();
        assert!(used > 0, "a lone straggler below solo_accept must be raced");
        let mut guard = 0;
        let fin = loop {
            e.round(&mut rep).unwrap();
            if let Some(f) = ar.resolve(&mut e).unwrap().pop() {
                break f;
            }
            guard += 1;
            assert!(guard < 500, "lone-straggler race did not resolve");
        };
        assert!(fin.replica_won);
        assert_eq!(fin.winner_method, "sam");
        // a lone HEALTHY slot must not race (0.85 is above solo_accept)
        let mut h = SyntheticEngine::new(8, 13);
        h.admit(0, Request::new(0, vec![1; 4], 40), spec_plan(DraftMethod::Ngram, 3))
            .unwrap();
        let mut rep2 = EngineReport::default();
        for _ in 0..4 {
            h.round(&mut rep2).unwrap();
        }
        let mut ar2 = RaceArbiter::synthetic();
        assert_eq!(ar2.consider(&mut h, 1, &[4, 5]).unwrap(), 0);
    }

    #[test]
    fn min_remaining_gates_launches() {
        let (mut e, _r) = skewed_engine(40);
        let mut ar = RaceArbiter::synthetic();
        ar.cfg.min_remaining = 1_000; // nothing has that much left
        assert_eq!(ar.consider(&mut e, 2, &[4, 5]).unwrap(), 0);
    }
}
