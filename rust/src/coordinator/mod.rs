//! Coordinator: the global scheduler's decision algorithms.
//!
//! * [`reconfig`] — Algorithm 2: request-level draft-window / mode
//!   reconfiguration for below-average-acceptance requests.
//! * [`fon`] — Algorithm 3: greedy Fastest-of-N drafter assignment onto
//!   freed workers.
//! * [`global`] — the real-engine orchestration used by the e2e example:
//!   plan → per-worker rollout → FoN racing for stragglers.

pub mod fon;
pub mod global;
pub mod reconfig;

pub use fon::{assign, Assignment, FreeWorker, Straggler};
pub use reconfig::{reconfigure_batch, reconfigure_request, Mode, RequestPlan};
