//! Coordinator: the global scheduler's decision algorithms.
//!
//! * [`reconfig`] — Algorithm 2: request-level draft-window / mode
//!   reconfiguration for below-average-acceptance requests, plus the live
//!   [`Reconfigurator`] the serve loop fires every `period` rounds.
//! * [`fon`] — Algorithm 3: greedy Fastest-of-N drafter assignment onto
//!   freed workers, routed into racing [`SlotPlan`]s.
//! * [`race`] — Algorithm 3 **executed**: the [`RaceArbiter`] forks
//!   stragglers into replica slots (`Worker::fork`), prices launches
//!   ([`race::race_gain`]), detects the first finisher, cancels losers
//!   and enforces the losslessness invariant across race members.
//! * [`global`] — the real-engine orchestration used by the e2e example:
//!   plan → per-worker rollout → FoN races run in-process for stragglers.
//!
//! [`SlotPlan`]: crate::engine::SlotPlan

pub mod fon;
pub mod global;
pub mod race;
pub mod reconfig;

pub use fon::{assign, slot_plans, Assignment, FreeWorker, Straggler};
pub use race::{race_in_process, RaceArbiter, RaceConfig, RaceFinish};
pub use reconfig::{
    cost_method, reconfigure_batch, reconfigure_request, LiveSlot, Mode, Reconfigurator,
    RequestPlan,
};
