//! Coordinator: the global scheduler's decision algorithms.
//!
//! * [`reconfig`] — Algorithm 2: request-level draft-window / mode
//!   reconfiguration for below-average-acceptance requests, plus the live
//!   [`Reconfigurator`] the serve loop fires every `period` rounds.
//! * [`fon`] — Algorithm 3: greedy Fastest-of-N drafter assignment onto
//!   freed workers, routed into racing [`SlotPlan`]s.
//! * [`global`] — the real-engine orchestration used by the e2e example:
//!   plan → per-worker rollout → FoN planning for stragglers.
//!
//! [`SlotPlan`]: crate::engine::SlotPlan

pub mod fon;
pub mod global;
pub mod reconfig;

pub use fon::{assign, slot_plans, Assignment, FreeWorker, Straggler};
pub use reconfig::{
    cost_method, reconfigure_batch, reconfigure_request, LiveSlot, Mode, Reconfigurator,
    RequestPlan,
};
