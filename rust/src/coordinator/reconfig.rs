//! Algorithm 2: request-level reconfiguration during rollout.
//!
//! Two layers:
//!
//! * the pure decision functions ([`reconfigure_request`] /
//!   [`reconfigure_batch`]): for a request whose measured acceptance rate
//!   fell below the batch average, re-derive its best draft window under
//!   both coupled and decoupled modelling at b = 1 and switch it to
//!   whichever is faster;
//! * the **live** wrapper ([`Reconfigurator`]): fired every
//!   `period` engine rounds by the serve loop (and any other round-based
//!   driver), it measures each slot's *recent* acceptance as the delta of
//!   the engine's per-slot counters since the last firing, runs the
//!   decision functions with each slot's own draft method, clamps the
//!   chosen window to what the lowered artifacts can verify, and returns
//!   ready-to-apply [`SlotPlan`]s — `Worker::set_plan` hot-swaps them in
//!   place.

use crate::drafter::DraftMethod;
use crate::engine::{SlotAccept, SlotPlan, VerifyDiscipline};
use crate::planner::costmodel::CostModel;
use crate::planner::tgs::{
    step_up, tgs_coupled, tgs_coupled_fused, tgs_decoupled, tgs_decoupled_fused,
};
use crate::runtime::Manifest;

/// Speculation mode flag in a per-request plan (paper's `m_r`) — the
/// engine's [`PlanMode`], re-exported under Algorithm 2's historical name.
///
/// [`PlanMode`]: crate::engine::PlanMode
pub use crate::engine::PlanMode as Mode;

/// Per-request draft plan `(w_r, m_r)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestPlan {
    pub w: usize,
    pub mode: Mode,
    pub tgs: f64,
}

/// argmax_w TGS for one mode at batch 1. `fused_grid` prices each window
/// as the fused engine runs it — rounded up into the lowered grid with
/// the padding-waste term; `None` is the exact pre-fusion pricing.
fn best_window(
    m: &CostModel,
    method: &str,
    g_v: usize,
    p: f64,
    max_w: usize,
    mode: Mode,
    fused_grid: Option<&[usize]>,
) -> (usize, f64) {
    let mut best = (1usize, f64::MIN);
    for w in 1..=max_w {
        let t = match (mode, fused_grid) {
            (Mode::Coupled, None) => tgs_coupled(m, method, g_v, w, 1, p),
            (Mode::Decoupled, None) => tgs_decoupled(m, method, g_v, w, 1, p),
            (Mode::Coupled, Some(grid)) => {
                tgs_coupled_fused(m, method, g_v, w, step_up(grid, w), 1, p)
            }
            (Mode::Decoupled, Some(grid)) => {
                tgs_decoupled_fused(m, method, g_v, w, step_up(grid, w), 1, p)
            }
        };
        if t > best.1 {
            best = (w, t);
        }
    }
    best
}

/// SelectBetter: model both modes at batch 1 and keep the faster plan.
/// `fused_grid` as in [`best_window`].
fn select_better(
    m: &CostModel,
    method: &str,
    g_v: usize,
    p: f64,
    max_w: usize,
    fused_grid: Option<&[usize]>,
) -> RequestPlan {
    let (wc, tc) = best_window(m, method, g_v, p, max_w, Mode::Coupled, fused_grid);
    let (wd, td) = best_window(m, method, g_v, p, max_w, Mode::Decoupled, fused_grid);
    if tc >= td {
        RequestPlan { w: wc, mode: Mode::Coupled, tgs: tc }
    } else {
        RequestPlan { w: wd, mode: Mode::Decoupled, tgs: td }
    }
}

/// Algorithm 2 for one request: profile → model both modes → SelectBetter.
pub fn reconfigure_request(
    m: &CostModel,
    method: &str,
    g_v: usize,
    measured_p: f64,
    max_w: usize,
) -> RequestPlan {
    select_better(m, method, g_v, measured_p, max_w, None)
}

/// Algorithm 2 over a batch: reconfigure every request whose acceptance is
/// below the batch average. Returns (request index, plan) pairs.
pub fn reconfigure_batch(
    m: &CostModel,
    method: &str,
    g_v: usize,
    accept_rates: &[f64],
    max_w: usize,
) -> Vec<(usize, RequestPlan)> {
    if accept_rates.is_empty() {
        return Vec::new();
    }
    let avg = accept_rates.iter().sum::<f64>() / accept_rates.len() as f64;
    accept_rates
        .iter()
        .enumerate()
        .filter(|(_, &p)| p < avg)
        .map(|(i, &p)| (i, reconfigure_request(m, method, g_v, p, max_w)))
        .collect()
}

/// Cost-model key for an engine draft method. Model drafters are named by
/// their model; token drafters without their own profiled cost curve
/// borrow the n-gram curve — both are O(1)-per-token CPU lookups the paper
/// piggybacks on the worker, and the cost model only needs the family's
/// order of magnitude. The suffix-automaton drafter starts in that
/// fallback but graduates to its OWN key once live acceptance evidence
/// arrives and [`Reconfigurator::feed_measured`] installs a dedicated
/// "sam" curve ([`CostModel::install_sam_curve`]).
pub fn cost_method(cost: &CostModel, method: &DraftMethod) -> String {
    let label = method.label();
    if cost.methods().iter().any(|m| *m == label) {
        label
    } else {
        "ngram".to_string()
    }
}

/// A live speculative slot offered to the reconfigurator: where it is and
/// what drafts for it (the window/mode are re-derived, the method kept).
#[derive(Clone, Debug)]
pub struct LiveSlot {
    pub slot: usize,
    pub method: DraftMethod,
}

/// Periodic Algorithm 2 driver over the live engine: measures per-slot
/// acceptance as counter deltas between firings and emits ready-to-apply
/// [`SlotPlan`]s for below-average slots.
#[derive(Clone, Debug)]
pub struct Reconfigurator {
    cost: CostModel,
    /// Engine rounds between firings.
    period: u64,
    g_v: usize,
    max_w: usize,
    /// Draft windows the lowered artifacts can verify, ascending.
    allowed: Vec<usize>,
    rounds: u64,
    /// Per-slot counter snapshot at the last firing (admissions reset
    /// their slot so a recycled slot never inherits the previous
    /// request's acceptance history).
    baseline: Vec<SlotAccept>,
    /// Restrict SelectBetter to coupled-mode plans. The in-process engine
    /// emulates decoupled discipline without the pipelining that
    /// `tgs_decoupled` models (it only forgoes the bonus token), so
    /// applying a Decoupled pick there would strictly slow the slot down —
    /// serve-loop constructors set this; deployments that route Decoupled
    /// slots to the threaded pipeline clear it.
    coupled_only: bool,
    /// Verify discipline of the engine the plans land on. **Fused**
    /// (default): heterogeneous windows share one β-amortised step, so a
    /// straggler gets its exact argmax window over the full `1..=max_w`
    /// grid, priced with the fused padding-waste term — aggressive
    /// per-slot specialisation. **Grouped**: every distinct window is
    /// another β-paying verify step, so the chosen window is snapped DOWN
    /// into the lowered grid — the convergence pressure that herds
    /// stragglers into existing plan groups.
    discipline: VerifyDiscipline,
    /// Set by [`Reconfigurator::note_decay`] at a policy-weight-update
    /// boundary: the next round re-baselines EVERY slot's counters and
    /// skips that firing, so no measurement window straddles the update
    /// (the old policy's acceptance says nothing about the new weights).
    rewiden: bool,
    /// Firings that changed at least one slot.
    pub fired: u64,
}

impl Reconfigurator {
    pub fn new(
        cost: CostModel,
        g_v: usize,
        max_w: usize,
        allowed: Vec<usize>,
        period: u64,
    ) -> Self {
        let mut allowed: Vec<usize> = allowed.into_iter().filter(|&w| w > 0).collect();
        allowed.sort_unstable();
        allowed.dedup();
        Reconfigurator {
            cost,
            period: period.max(1),
            g_v,
            max_w: max_w.max(1),
            allowed,
            rounds: 0,
            baseline: Vec::new(),
            coupled_only: true,
            discipline: VerifyDiscipline::Fused,
            rewiden: false,
            fired: 0,
        }
    }

    /// Allow Decoupled-mode plans in SelectBetter (only meaningful when
    /// the caller runs those slots on the real threaded pipeline).
    pub fn with_decoupled_modes(mut self) -> Self {
        self.coupled_only = false;
        self
    }

    /// Target a grouped-verify engine (`--grouped-verify` A/B): derived
    /// windows snap down into the lowered grid so stragglers coalesce
    /// into existing `(method, window)` groups instead of each paying the
    /// verify intercept β again.
    pub fn for_discipline(mut self, d: VerifyDiscipline) -> Self {
        self.discipline = d;
        self
    }

    /// Reconfigurator wired to a lowered artifact set: verifiable draft
    /// windows from its lowered step windows.
    pub fn for_manifest(m: &Manifest, cost: CostModel, max_w: usize, period: u64) -> Self {
        let g_v = cost.g_ref;
        Self::new(cost, g_v, max_w, m.draft_windows(), period)
    }

    /// Default driver for engines without a manifest (the synthetic smoke
    /// engine): the default AOT window grid and paper cost model.
    pub fn synthetic(period: u64) -> Self {
        let cost = CostModel::paper_32b();
        let g_v = cost.g_ref;
        Self::new(cost, g_v, 7, vec![1, 3, 7], period)
    }

    pub fn period(&self) -> u64 {
        self.period
    }

    /// Will the NEXT [`Reconfigurator::on_round`] call fire? Lets the
    /// driver skip gathering live-slot state on the rounds where
    /// `on_round` would discard it anyway.
    pub fn due(&self) -> bool {
        (self.rounds + 1) % self.period == 0
    }

    /// A request was admitted into `slot`: reset the slot's measurement
    /// baseline to the engine's current counters.
    pub fn on_admit(&mut self, slot: usize, per_slot: &[SlotAccept]) {
        if self.baseline.len() <= slot {
            self.baseline.resize(slot + 1, SlotAccept::default());
        }
        self.baseline[slot] = per_slot.get(slot).copied().unwrap_or_default();
    }

    /// A policy weight update landed (`invalidate_draft_state`): the
    /// measured acceptance gathered so far described the OLD weights. The
    /// next round re-baselines every slot and skips its firing, so
    /// Algorithm 2 only ever acts on post-update evidence.
    pub fn note_decay(&mut self) {
        self.rewiden = true;
    }

    /// Fold wave-measured per-method acceptance
    /// (`ServeMetrics::method_acceptance` tuples) into the COST side of
    /// Algorithm 2: once the suffix-automaton drafter has real drafted
    /// evidence, install its own cost curve so [`cost_method`] stops
    /// borrowing the n-gram key and windows for sam slots are priced on
    /// sam's own curve. Returns true when the cost model changed.
    pub fn feed_measured(&mut self, measured: &[(String, f64, u64, u64)]) -> bool {
        let mut changed = false;
        for (method, _rate, _accepted, drafted) in measured {
            if method == "sam"
                && *drafted >= crate::serve::replan::MIN_MEASURED_DRAFTED
            {
                changed |= self.cost.install_sam_curve();
            }
        }
        changed
    }

    /// Note one engine round. Every `period`-th round, run Algorithm 2
    /// over the live speculative slots' measured (delta) acceptance rates
    /// and return the plans to apply; otherwise an empty vec.
    pub fn on_round(
        &mut self,
        per_slot: &[SlotAccept],
        live: &[LiveSlot],
    ) -> Vec<(usize, SlotPlan)> {
        self.rounds += 1;
        if self.rewiden {
            // drop pre-update evidence: every slot measures from the
            // current counters on, and this firing (if due) is skipped
            self.baseline = per_slot.to_vec();
            self.rewiden = false;
            return Vec::new();
        }
        if self.rounds % self.period != 0 {
            return Vec::new();
        }
        // measured recent acceptance per live slot (delta since the last
        // firing; slots with no drafting evidence are skipped)
        let mut rates: Vec<(usize, f64)> = Vec::with_capacity(live.len());
        for (li, ls) in live.iter().enumerate() {
            let cur = per_slot.get(ls.slot).copied().unwrap_or_default();
            let base = self.baseline.get(ls.slot).copied().unwrap_or_default();
            let drafted = cur.drafted.saturating_sub(base.drafted);
            if drafted == 0 {
                continue;
            }
            let accepted = cur.accepted.saturating_sub(base.accepted);
            rates.push((li, accepted as f64 / drafted as f64));
        }
        self.baseline = per_slot.to_vec();
        if rates.is_empty() || self.allowed.is_empty() {
            return Vec::new();
        }
        let avg = rates.iter().map(|(_, p)| p).sum::<f64>() / rates.len() as f64;
        let fused = self.discipline == VerifyDiscipline::Fused;
        // BOTH disciplines round an intermediate window up to the next
        // lowered step size at verify time, so candidates are priced with
        // that padding either way (matching the serve replanner); the
        // disciplines differ only in what the argmax is snapped to below.
        let grid = Some(self.allowed.as_slice());
        // Enumerate only up to the largest verifiable draft window:
        // beyond it `step_up` has no grid element to round into, so a
        // larger candidate would be priced with NO padding waste (and
        // still be clamped before application) — an optimistic phantom
        // that could out-score every fairly-priced runnable window.
        let cap = self.max_w.min(*self.allowed.last().unwrap());
        let mut out = Vec::new();
        for &(li, p) in rates.iter().filter(|(_, p)| *p < avg) {
            let ls = &live[li];
            let method = cost_method(&self.cost, &ls.method);
            let plan = if self.coupled_only {
                let (w, tgs) =
                    best_window(&self.cost, &method, self.g_v, p, cap, Mode::Coupled, grid);
                RequestPlan { w, mode: Mode::Coupled, tgs }
            } else {
                select_better(&self.cost, &method, self.g_v, p, cap, grid)
            };
            let w = if fused {
                // fused engine: heterogeneous windows are free of β, so
                // the straggler keeps its exact argmax window over the
                // full 1..=cap grid (intermediate windows round up at
                // verify time and were priced with that padding)
                plan.w
            } else {
                // grouped engine: every distinct window is another
                // β-paying verify step — snap DOWN into the lowered grid
                // so stragglers converge onto existing plan groups; a
                // window below the whole grid keeps its argmax value
                // (inflating a struggling slot's window would be worse
                // than an extra group)
                self.allowed
                    .iter()
                    .copied()
                    .filter(|&a| a <= plan.w)
                    .max()
                    .unwrap_or(plan.w)
            };
            out.push((
                ls.slot,
                SlotPlan { method: ls.method.clone(), window: w, mode: plan.mode },
            ));
        }
        if !out.is_empty() {
            self.fired += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn low_acceptance_gets_smaller_window() {
        let m = CostModel::paper_32b();
        let hi = reconfigure_request(&m, "draft_small", 4, 0.95, 12);
        let lo = reconfigure_request(&m, "draft_small", 4, 0.25, 12);
        assert!(lo.w <= hi.w, "low-p window {} > high-p window {}", lo.w, hi.w);
    }

    #[test]
    fn only_below_average_requests_reconfigured() {
        let m = CostModel::paper_32b();
        let rates = [0.9, 0.8, 0.4, 0.95];
        let plans = reconfigure_batch(&m, "draft_small", 4, &rates, 8);
        let touched: Vec<usize> = plans.iter().map(|(i, _)| *i).collect();
        assert_eq!(touched, vec![2]);
    }

    #[test]
    fn select_better_really_selects_better() {
        let m = CostModel::paper_32b();
        check("reconfig-selects-max", 100, |g| {
            let p = 0.05 + 0.9 * g.prob();
            let plan = reconfigure_request(&m, "draft_mid", 4, p, 10);
            for w in 1..=10 {
                let tc = tgs_coupled(&m, "draft_mid", 4, w, 1, p);
                let td = tgs_decoupled(&m, "draft_mid", 4, w, 1, p);
                prop_assert!(
                    plan.tgs >= tc - 1e-12 && plan.tgs >= td - 1e-12,
                    "p={p}: picked {:?} but w={w} gives C={tc} D={td}",
                    plan
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_batch_is_noop() {
        let m = CostModel::paper_32b();
        assert!(reconfigure_batch(&m, "ngram", 4, &[], 8).is_empty());
    }

    #[test]
    fn cost_method_maps_known_and_falls_back_unknown() {
        let mut m = CostModel::paper_32b();
        // sam starts with no profiled curve: it borrows the n-gram cost
        // key until measured evidence installs its own
        assert_eq!(cost_method(&m, &DraftMethod::Sam), "ngram");
        assert_eq!(cost_method(&m, &DraftMethod::Ngram), "ngram");
        assert_eq!(
            cost_method(&m, &DraftMethod::Model("draft_mid".into())),
            "draft_mid"
        );
        assert_eq!(
            cost_method(&m, &DraftMethod::Model("mystery_9b".into())),
            "ngram"
        );
        // once the sam curve is installed, sam graduates to its own key
        assert!(m.install_sam_curve());
        assert_eq!(cost_method(&m, &DraftMethod::Sam), "sam");
    }

    #[test]
    fn measured_sam_evidence_installs_the_sam_cost_key() {
        let mut rc = Reconfigurator::synthetic(1);
        // thin evidence: still borrowing ngram
        assert!(!rc.feed_measured(&[("sam".to_string(), 0.8, 10, 12)]));
        assert_eq!(cost_method(&rc.cost, &DraftMethod::Sam), "ngram");
        // a wave of evidence: dedicated curve installed, own key
        assert!(rc.feed_measured(&[("sam".to_string(), 0.8, 400, 500)]));
        assert_eq!(cost_method(&rc.cost, &DraftMethod::Sam), "sam");
        // idempotent on repeated cumulative feeds
        assert!(!rc.feed_measured(&[("sam".to_string(), 0.8, 800, 1000)]));
    }

    #[test]
    fn decay_rebaselines_and_skips_the_straddling_firing() {
        let mut rc = Reconfigurator::synthetic(1);
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Ngram },
            LiveSlot { slot: 1, method: DraftMethod::Ngram },
        ];
        let _ = rc.on_round(&slot_counters(&[(10, 9), (10, 9)]), &live);
        // a weight update lands: slot 1's awful pre-update window must not
        // be measured across the boundary
        rc.note_decay();
        let plans = rc.on_round(&slot_counters(&[(20, 10), (20, 9)]), &live);
        assert!(plans.is_empty(), "straddling firing must be skipped: {plans:?}");
        // post-update evidence only: slot 0 accepted everything since the
        // rebaseline, slot 1 nothing — slot 1 is the straggler
        let plans = rc.on_round(&slot_counters(&[(30, 20), (30, 9)]), &live);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].0, 1, "post-decay deltas must rank slot 1 as the straggler");
    }

    fn slot_counters(pairs: &[(u64, u64)]) -> Vec<SlotAccept> {
        pairs.iter().map(|&(d, a)| SlotAccept { drafted: d, accepted: a }).collect()
    }

    #[test]
    fn reconfigurator_fires_on_period_and_targets_stragglers() {
        let mut rc = Reconfigurator::synthetic(2);
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Sam },
            LiveSlot { slot: 1, method: DraftMethod::Sam },
        ];
        // round 1: off-period, nothing
        assert!(rc.on_round(&slot_counters(&[(4, 4), (4, 1)]), &live).is_empty());
        // round 2: slot 1 is the straggler (delta rate 0.25 vs 1.0)
        let plans = rc.on_round(&slot_counters(&[(8, 8), (8, 2)]), &live);
        assert_eq!(plans.len(), 1, "exactly the below-average slot: {plans:?}");
        assert_eq!(plans[0].0, 1);
        let p = &plans[0].1;
        assert!(
            (1..=7).contains(&p.window),
            "window {} outside the verifiable 1..=7 grid",
            p.window
        );
        assert_eq!(p.mode, Mode::Coupled, "serve-path reconfigurator is coupled-only");
        assert_eq!(p.method, DraftMethod::Sam, "method is kept, window/mode re-derived");
        assert_eq!(rc.fired, 1);
    }

    #[test]
    fn reconfigurator_uses_deltas_not_lifetime_counters() {
        let mut rc = Reconfigurator::synthetic(1);
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Ngram },
            LiveSlot { slot: 1, method: DraftMethod::Ngram },
        ];
        // firing 1 establishes a baseline where slot 0 looks terrible
        let _ = rc.on_round(&slot_counters(&[(10, 0), (10, 9)]), &live);
        // since then slot 0 accepted everything and slot 1 nothing:
        // the *delta* ranking must flip
        let plans = rc.on_round(&slot_counters(&[(20, 10), (20, 9)]), &live);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].0, 1, "delta measurement must rank slot 1 as the straggler");
    }

    #[test]
    fn admission_resets_the_slot_baseline() {
        let mut rc = Reconfigurator::synthetic(1);
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Ngram },
            LiveSlot { slot: 1, method: DraftMethod::Ngram },
        ];
        let _ = rc.on_round(&slot_counters(&[(10, 1), (10, 8)]), &live);
        // a new request recycles slot 0: its horrible history must not leak
        rc.on_admit(0, &slot_counters(&[(10, 1), (10, 8)]));
        let plans = rc.on_round(&slot_counters(&[(14, 5), (14, 9)]), &live);
        // slot 0's delta is 4/4 = 1.0, slot 1's is 1/4 = 0.25
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].0, 1);
    }

    #[test]
    fn grouped_discipline_snaps_windows_into_the_grid() {
        // Target a grouped-verify engine: the straggler's window must land
        // ON the lowered grid {1, 3, 7} (an off-grid window would open a
        // fresh β-paying plan group), while the fused default may pick any
        // window in 1..=7.
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Sam },
            LiveSlot { slot: 1, method: DraftMethod::Sam },
        ];
        let counters = slot_counters(&[(20, 20), (20, 3)]);
        let mut grouped =
            Reconfigurator::synthetic(1).for_discipline(crate::engine::VerifyDiscipline::Grouped);
        let plans = grouped.on_round(&counters, &live);
        assert_eq!(plans.len(), 1);
        assert!(
            [1usize, 3, 7].contains(&plans[0].1.window),
            "grouped discipline must snap window {} onto the lowered grid",
            plans[0].1.window
        );
        let mut fused = Reconfigurator::synthetic(1);
        let plans = fused.on_round(&counters, &live);
        assert_eq!(plans.len(), 1);
        assert!((1..=7).contains(&plans[0].1.window));
    }

    #[test]
    fn windows_never_exceed_the_verifiable_grid() {
        // max_w far above the verifiable grid: enumeration is capped, so
        // no above-grid candidate (priced with zero padding waste — an
        // optimistic phantom) can win and the applied window is runnable.
        let mut rc = Reconfigurator::new(CostModel::paper_32b(), 4, 7, vec![1, 3], 1);
        let live = vec![
            LiveSlot { slot: 0, method: DraftMethod::Ngram },
            LiveSlot { slot: 1, method: DraftMethod::Ngram },
        ];
        let plans = rc.on_round(&slot_counters(&[(20, 19), (20, 2)]), &live);
        assert_eq!(plans.len(), 1);
        assert!(
            plans[0].1.window <= 3,
            "window {} beyond the verifiable grid",
            plans[0].1.window
        );
    }

    #[test]
    fn no_evidence_means_no_plans() {
        let mut rc = Reconfigurator::synthetic(1);
        let live = vec![LiveSlot { slot: 0, method: DraftMethod::Sam }];
        // vanilla slots / fresh slots draft nothing: no deltas, no plans
        assert!(rc.on_round(&[], &live).is_empty());
        assert!(rc.on_round(&slot_counters(&[(0, 0)]), &live).is_empty());
        assert_eq!(rc.fired, 0);
    }
}
