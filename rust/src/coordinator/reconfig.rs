//! Algorithm 2: request-level reconfiguration during rollout.
//!
//! Called periodically (every `period` decoding iterations). For each
//! request whose measured acceptance rate fell below the batch average,
//! re-derive its best draft window under both coupled and decoupled
//! modelling at b = 1, and switch it to whichever is faster.

use crate::planner::costmodel::CostModel;
use crate::planner::tgs::{tgs_coupled, tgs_decoupled};

/// Speculation mode flag in a per-request plan (paper's `m_r`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Coupled,
    Decoupled,
}

/// Per-request draft plan `(w_r, m_r)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestPlan {
    pub w: usize,
    pub mode: Mode,
    pub tgs: f64,
}

/// argmax_w TGS for one mode at batch 1.
fn best_window(
    m: &CostModel,
    method: &str,
    g_v: usize,
    p: f64,
    max_w: usize,
    mode: Mode,
) -> (usize, f64) {
    let mut best = (1usize, f64::MIN);
    for w in 1..=max_w {
        let t = match mode {
            Mode::Coupled => tgs_coupled(m, method, g_v, w, 1, p),
            Mode::Decoupled => tgs_decoupled(m, method, g_v, w, 1, p),
        };
        if t > best.1 {
            best = (w, t);
        }
    }
    best
}

/// Algorithm 2 for one request: profile → model both modes → SelectBetter.
pub fn reconfigure_request(
    m: &CostModel,
    method: &str,
    g_v: usize,
    measured_p: f64,
    max_w: usize,
) -> RequestPlan {
    let (wc, tc) = best_window(m, method, g_v, measured_p, max_w, Mode::Coupled);
    let (wd, td) = best_window(m, method, g_v, measured_p, max_w, Mode::Decoupled);
    if tc >= td {
        RequestPlan { w: wc, mode: Mode::Coupled, tgs: tc }
    } else {
        RequestPlan { w: wd, mode: Mode::Decoupled, tgs: td }
    }
}

/// Algorithm 2 over a batch: reconfigure every request whose acceptance is
/// below the batch average. Returns (request index, plan) pairs.
pub fn reconfigure_batch(
    m: &CostModel,
    method: &str,
    g_v: usize,
    accept_rates: &[f64],
    max_w: usize,
) -> Vec<(usize, RequestPlan)> {
    if accept_rates.is_empty() {
        return Vec::new();
    }
    let avg = accept_rates.iter().sum::<f64>() / accept_rates.len() as f64;
    accept_rates
        .iter()
        .enumerate()
        .filter(|(_, &p)| p < avg)
        .map(|(i, &p)| (i, reconfigure_request(m, method, g_v, p, max_w)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite::check;

    #[test]
    fn low_acceptance_gets_smaller_window() {
        let m = CostModel::paper_32b();
        let hi = reconfigure_request(&m, "draft_small", 4, 0.95, 12);
        let lo = reconfigure_request(&m, "draft_small", 4, 0.25, 12);
        assert!(lo.w <= hi.w, "low-p window {} > high-p window {}", lo.w, hi.w);
    }

    #[test]
    fn only_below_average_requests_reconfigured() {
        let m = CostModel::paper_32b();
        let rates = [0.9, 0.8, 0.4, 0.95];
        let plans = reconfigure_batch(&m, "draft_small", 4, &rates, 8);
        let touched: Vec<usize> = plans.iter().map(|(i, _)| *i).collect();
        assert_eq!(touched, vec![2]);
    }

    #[test]
    fn select_better_really_selects_better() {
        let m = CostModel::paper_32b();
        check("reconfig-selects-max", 100, |g| {
            let p = 0.05 + 0.9 * g.prob();
            let plan = reconfigure_request(&m, "draft_mid", 4, p, 10);
            for w in 1..=10 {
                let tc = tgs_coupled(&m, "draft_mid", 4, w, 1, p);
                let td = tgs_decoupled(&m, "draft_mid", 4, w, 1, p);
                prop_assert!(
                    plan.tgs >= tc - 1e-12 && plan.tgs >= td - 1e-12,
                    "p={p}: picked {:?} but w={w} gives C={tc} D={td}",
                    plan
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_batch_is_noop() {
        let m = CostModel::paper_32b();
        assert!(reconfigure_batch(&m, "ngram", 4, &[], 8).is_empty());
    }
}
