//! Global scheduler over the *real* engine (used by `examples/e2e_serving`
//! and `examples/fon_demo`): the CPU-scale analogue of Figure 8.
//!
//! * Partitions a request batch across worker threads (each thread owns
//!   its own PJRT client — the process topology the paper uses for
//!   drafter/verifier separation).
//! * Selects the initial draft method with the ladder and plans the draft
//!   window with Algorithm 1; each worker receives it as the engine's
//!   [`SlotPlan`] currency (the same type Algorithm 2 rewrites per slot
//!   and the serve loop applies on admission).
//! * When workers finish their batches, Algorithm 3 ([`fon::assign`])
//!   maps next-best draft methods for the lowest-acceptance requests onto
//!   the freed workers, the assignment is routed into racing [`SlotPlan`]
//!   replicas ([`fon::slot_plans`]) and the races are **executed
//!   in-process** ([`race::race_in_process`]): the straggler's primary
//!   method and its replicas share one fused worker and the first
//!   finisher wins, so `fon_wins` is measured. Losslessness makes the
//!   race safe — every replica generates the identical sequence, so
//!   "fastest of N" can never change the rollout output (asserted both
//!   here and in the race arbiter).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::{fon, race};
use crate::drafter::DraftMethod;
use crate::engine::{EngineConfig, EngineReport, Request, SlotPlan, Worker};
use crate::ladder::Ladder;
use crate::planner::costmodel::CostModel;
use crate::planner::plan::{search, PlanInput};
use crate::runtime::Runtime;

/// Per-request final outcome.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Which replica finished it ("worker<k>" or "fon:<method>").
    pub finished_by: String,
    /// Lifetime acceptance rate under the primary method (FoN's ordering
    /// signal).
    pub accept_rate: f64,
}

#[derive(Clone, Debug, Default)]
pub struct RolloutSummary {
    /// Wall time of the worker rollout itself. The CPU-scale FoN race
    /// phase (which re-runs stragglers that a real cluster would still
    /// have in flight) is timed separately in [`fon_race_s`] so rollout
    /// throughput/speedup numbers are not diluted by the measurement.
    ///
    /// [`fon_race_s`]: RolloutSummary::fon_race_s
    pub wall_s: f64,
    /// Wall time spent executing the in-process Fastest-of-N races.
    pub fon_race_s: f64,
    pub outcomes: Vec<RequestOutcome>,
    pub per_worker: Vec<EngineReport>,
    /// Racing replicas actually forked by the in-process races.
    pub fon_launches: usize,
    /// Races a replica (a next-best method) finished strictly before the
    /// straggler's primary method — **measured** by the race arbiter, not
    /// planned.
    pub fon_wins: usize,
    /// Replicas cancelled when their race resolved.
    pub fon_cancelled_replicas: usize,
    /// Engine rounds burned by cancelled replicas (the speculation waste
    /// racing pays for its tail win).
    pub fon_wasted_replica_rounds: u64,
    /// Racing replicas Algorithm 3 assigned: (request, freed worker,
    /// plan). Each plan is then executed in-process by
    /// [`race::race_in_process`] — the counters above measure the result.
    ///
    /// [`race::race_in_process`]: crate::coordinator::race::race_in_process
    pub fon_plans: Vec<(u64, usize, SlotPlan)>,
}

/// Global scheduler configuration.
#[derive(Clone, Debug)]
pub struct GlobalConfig {
    pub artifacts: PathBuf,
    pub n_workers: usize,
    /// Speculation window (planned via Algorithm 1 when None).
    pub window: Option<usize>,
    pub temperature: f32,
    pub seed: u64,
    /// Enable the FoN phase.
    pub fon: bool,
}

/// Select the initial method + window from ladder + Algorithm 1
/// (CPU-scale: the cost model is the paper-calibrated one, the decision
/// logic is shared with the simulator).
pub fn plan_initial(
    m: &CostModel,
    profiled: &[(String, f64)],
    global_batch: usize,
    gpus: usize,
    tp: usize,
) -> (String, usize) {
    let ladder = Ladder::build(m, global_batch.div_ceil((gpus / tp).max(1)), 4, profiled);
    let sel = ladder.select_initial().method.clone();
    let p = profiled
        .iter()
        .find(|(n, _)| *n == sel)
        .map(|(_, p)| *p)
        .unwrap_or(0.7);
    let plan = search(
        m,
        &PlanInput {
            global_batch,
            gpus,
            verifier_configs: vec![tp],
            accept_p: p,
            method: sel.clone(),
            max_window: 7,
            fixed_batch: None,
            fused_windows: vec![],
        },
    );
    (sel, plan.map(|p| p.w).unwrap_or(3).clamp(1, 7))
}

/// Map a planner method name to an engine draft method. The engine's model
/// family uses the same names; "ngram"/"sam" are token drafters.
fn to_engine_method(name: &str) -> DraftMethod {
    DraftMethod::parse(name)
}

/// Run one batch through `n_workers` worker threads with coupled
/// speculation, then (optionally) plan Fastest-of-N races for the
/// lowest-acceptance requests on the freed workers.
pub fn rollout(
    cfg: &GlobalConfig,
    prompts: Vec<(u64, Vec<i32>)>,
    budget: usize,
    method_rank: &[String],
    window: usize,
) -> Result<RolloutSummary> {
    let t0 = Instant::now();
    let n = prompts.len();
    let per = n.div_ceil(cfg.n_workers.max(1));
    let chunks: Vec<Vec<(u64, Vec<i32>)>> =
        prompts.chunks(per).map(|c| c.to_vec()).collect();

    let primary = method_rank.first().cloned().unwrap_or_else(|| "draft_small".into());
    type WorkerOut = (usize, Vec<(u64, Vec<i32>, String, f64)>, EngineReport);
    let (tx, rx) = channel::<WorkerOut>();
    // done flags per request id: FoN racers poll these to stop early
    let done: Arc<BTreeMap<u64, AtomicBool>> = Arc::new(
        prompts.iter().map(|(id, _)| (*id, AtomicBool::new(false))).collect(),
    );

    let mut handles = Vec::new();
    for (widx, chunk) in chunks.into_iter().enumerate() {
        let tx = tx.clone();
        let art = cfg.artifacts.clone();
        let method = primary.clone();
        let done = done.clone();
        let (seed, temp) = (cfg.seed, cfg.temperature);
        let h = std::thread::Builder::new()
            .name(format!("worker{widx}"))
            .spawn(move || -> Result<()> {
                let rt = Runtime::load(&art)?;
                let reqs: Vec<Request> = chunk
                    .iter()
                    .map(|(id, p)| Request::new(*id, p.clone(), budget))
                    .collect();
                let ecfg = EngineConfig {
                    plan: SlotPlan::coupled(to_engine_method(&method), window),
                    verify: Default::default(),
                    temperature: temp,
                    seed,
                    draft_seed: seed.wrapping_add(1000),
                    overlap: false,
                };
                let mut w = Worker::new(&rt, ecfg, reqs)?;
                let rep = w.rollout_planned()?;
                let outs: Vec<(u64, Vec<i32>, String, f64)> = w
                    .iter_requests()
                    .map(|(_, r)| {
                        done.get(&r.id).map(|f| f.store(true, Ordering::SeqCst));
                        (
                            r.id,
                            r.seq[r.prompt.len()..].to_vec(),
                            format!("worker{widx}"),
                            r.accept.rate(),
                        )
                    })
                    .collect();
                tx.send((widx, outs, rep)).map_err(|e| anyhow!("send: {e}"))?;
                Ok(())
            })
            .map_err(|e| anyhow!("spawn: {e}"))?;
        handles.push(h);
    }
    drop(tx);

    let mut outcomes: BTreeMap<u64, RequestOutcome> = BTreeMap::new();
    let mut per_worker = Vec::new();
    let mut freed_workers: Vec<usize> = Vec::new();
    while let Ok((widx, outs, rep)) = rx.recv() {
        per_worker.push(rep);
        freed_workers.push(widx);
        for (id, tokens, by, accept_rate) in outs {
            outcomes
                .entry(id)
                .or_insert(RequestOutcome { id, tokens, finished_by: by, accept_rate });
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    // FoN phase (Algorithm 3): plan races for the lowest-acceptance
    // requests on the freed workers, then EXECUTE them in-process — each
    // straggler raced under its primary method plus the assigned
    // next-best methods inside one fused worker (`race::race_in_process`),
    // first finisher wins. On real clusters this fires while stragglers
    // are still decoding; at CPU scale every batch has drained by the
    // time workers report, so the race re-runs the straggler from its
    // prompt — losslessness makes the re-run token-identical to the
    // recorded outcome (asserted below), and the round counts make
    // `fon_wins` a measurement, not a plan.
    let rollout_wall_s = t0.elapsed().as_secs_f64();
    let race_t0 = Instant::now();
    let mut fon_launches = 0usize;
    let mut fon_wins = 0usize;
    let mut fon_cancelled_replicas = 0usize;
    let mut fon_wasted_replica_rounds = 0u64;
    let mut fon_plans = Vec::new();
    if cfg.fon && method_rank.len() > 1 && !outcomes.is_empty() {
        let mean_p = outcomes.values().map(|o| o.accept_rate).sum::<f64>()
            / outcomes.len() as f64;
        let mut stragglers: Vec<fon::Straggler> = outcomes
            .values()
            .filter(|o| o.accept_rate < mean_p)
            .map(|o| fon::Straggler {
                request: o.id,
                accept_rate: o.accept_rate,
                methods: vec![primary.clone()],
            })
            .collect();
        let mut free: Vec<fon::FreeWorker> = freed_workers
            .iter()
            .map(|&id| fon::FreeWorker { id, capacity: per.max(1), method: None, load: 0 })
            .collect();
        let assignment = fon::assign(&mut stragglers, method_rank, &mut free, per.max(1));
        fon_plans = fon::slot_plans(&assignment, method_rank, window);

        let mut by_req: BTreeMap<u64, Vec<SlotPlan>> = BTreeMap::new();
        for (req, _wid, plan) in &fon_plans {
            by_req.entry(*req).or_default().push(plan.clone());
        }
        if !by_req.is_empty() {
            let rt = Runtime::load(&cfg.artifacts)?;
            let ecfg = EngineConfig {
                plan: SlotPlan::coupled(to_engine_method(&primary), window),
                verify: Default::default(),
                temperature: cfg.temperature,
                seed: cfg.seed,
                draft_seed: cfg.seed.wrapping_add(1000),
                overlap: false,
            };
            for (id, replicas) in by_req {
                let prompt = prompts
                    .iter()
                    .find(|(pid, _)| *pid == id)
                    .map(|(_, p)| p.clone())
                    .ok_or_else(|| anyhow!("raced request {id} has no prompt"))?;
                let out = race::race_in_process(
                    &rt,
                    id,
                    &prompt,
                    budget,
                    ecfg.plan.clone(),
                    &replicas,
                    &ecfg,
                )?;
                fon_launches += out.launches;
                fon_cancelled_replicas += out.cancelled_replicas;
                fon_wasted_replica_rounds += out.wasted_replica_rounds;
                let o = outcomes.get_mut(&id).expect("raced request has an outcome");
                if out.tokens != o.tokens {
                    return Err(anyhow!(
                        "losslessness violated: FoN race output diverged for request {id}"
                    ));
                }
                if out.replica_won {
                    fon_wins += 1;
                    o.finished_by = format!("fon:{}", out.winner_method);
                }
            }
        }
    }

    Ok(RolloutSummary {
        wall_s: rollout_wall_s,
        fon_race_s: race_t0.elapsed().as_secs_f64(),
        outcomes: outcomes.into_values().collect(),
        per_worker,
        fon_launches,
        fon_wins,
        fon_cancelled_replicas,
        fon_wasted_replica_rounds,
        fon_plans,
    })
}

/// Race `methods` on the same request **sequentially** — one single-slot
/// worker per method, returning (winning method, tokens, per-method wall
/// seconds). Kept as the measurement baseline for per-method wall times
/// (the in-process concurrent race, [`race::race_in_process`], cancels
/// losers early and therefore cannot report their full times).
/// Losslessness means every replica yields identical tokens; the "win" is
/// purely about speed — exactly the paper's fastest-of-N semantics.
pub fn race_methods(
    art: &Path,
    id: u64,
    prompt: &[i32],
    budget: usize,
    methods: &[String],
    window: usize,
    seed: u64,
) -> Result<(String, Vec<i32>, Vec<(String, f64)>)> {
    let rt = Runtime::load(art)?;
    let mut best: Option<(String, f64, Vec<i32>)> = None;
    let mut times = Vec::new();
    for meth in methods {
        let cfg = EngineConfig {
            plan: SlotPlan::coupled(to_engine_method(meth), window),
            verify: Default::default(),
            temperature: 1.0,
            seed,
            draft_seed: seed.wrapping_add(1000),
            overlap: false,
        };
        let reqs = vec![Request::new(id, prompt.to_vec(), budget)];
        let mut w = Worker::new(&rt, cfg, reqs)?;
        let rep = w.rollout_planned()?;
        let out = w.outputs().pop().unwrap();
        times.push((meth.clone(), rep.wall_s));
        match &best {
            Some((_, t, prev)) => {
                if !prev.is_empty() && *prev != out {
                    return Err(anyhow!("losslessness violated: {meth} diverged"));
                }
                if rep.wall_s < *t {
                    best = Some((meth.clone(), rep.wall_s, out));
                }
            }
            None => best = Some((meth.clone(), rep.wall_s, out)),
        }
    }
    let (m, _, toks) = best.ok_or_else(|| anyhow!("no methods raced"))?;
    Ok((m, toks, times))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_initial_picks_method_and_window() {
        let m = CostModel::paper_32b();
        let profiled = vec![
            ("draft_mid".to_string(), 0.82),
            ("draft_small".to_string(), 0.74),
            ("ngram".to_string(), 0.40),
        ];
        let (method, w) = plan_initial(&m, &profiled, 8192, 256, 4);
        assert!(profiled.iter().any(|(n, _)| *n == method));
        assert!((1..=7).contains(&w));
    }

    #[test]
    fn to_engine_method_maps() {
        assert_eq!(to_engine_method("ngram"), DraftMethod::Ngram);
        assert!(matches!(to_engine_method("draft_mid"), DraftMethod::Model(_)));
    }
}
