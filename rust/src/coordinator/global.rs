//! Global scheduler over the *real* engine (used by `examples/e2e_serving`
//! and `examples/fon_demo`): the CPU-scale analogue of Figure 8.
//!
//! * Partitions a request batch across worker threads (each thread owns
//!   its own PJRT client — the process topology the paper uses for
//!   drafter/verifier separation).
//! * Selects the initial draft method with the ladder and plans the draft
//!   window with Algorithm 1; each worker receives it as the engine's
//!   [`SlotPlan`] currency (the same type Algorithm 2 rewrites per slot
//!   and the serve loop applies on admission).
//! * When workers finish their batches, Algorithm 3 ([`fon::assign`])
//!   maps next-best draft methods for the lowest-acceptance requests onto
//!   the freed workers and the resulting assignment is routed into racing
//!   [`SlotPlan`] replicas ([`fon::slot_plans`]): the first replica to
//!   finish wins. Losslessness makes the race safe — both replicas
//!   generate the identical sequence, so "fastest of N" can never change
//!   the rollout output (asserted in the coordinator integration test).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::fon;
use crate::drafter::DraftMethod;
use crate::engine::{EngineConfig, EngineReport, Request, SlotPlan, Worker};
use crate::ladder::Ladder;
use crate::planner::costmodel::CostModel;
use crate::planner::plan::{search, PlanInput};
use crate::runtime::Runtime;

/// Per-request final outcome.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Which replica finished it ("worker<k>" or "fon:<method>").
    pub finished_by: String,
    /// Lifetime acceptance rate under the primary method (FoN's ordering
    /// signal).
    pub accept_rate: f64,
}

#[derive(Clone, Debug, Default)]
pub struct RolloutSummary {
    pub wall_s: f64,
    pub outcomes: Vec<RequestOutcome>,
    pub per_worker: Vec<EngineReport>,
    pub fon_launches: usize,
    pub fon_wins: usize,
    /// Racing replicas Algorithm 3 planned: (request, freed worker, plan).
    /// At CPU scale the race itself is exercised by `race_methods` /
    /// `fon_demo`; the plans are what a GPU deployment would launch.
    pub fon_plans: Vec<(u64, usize, SlotPlan)>,
}

/// Global scheduler configuration.
#[derive(Clone, Debug)]
pub struct GlobalConfig {
    pub artifacts: PathBuf,
    pub n_workers: usize,
    /// Speculation window (planned via Algorithm 1 when None).
    pub window: Option<usize>,
    pub temperature: f32,
    pub seed: u64,
    /// Enable the FoN phase.
    pub fon: bool,
}

/// Select the initial method + window from ladder + Algorithm 1
/// (CPU-scale: the cost model is the paper-calibrated one, the decision
/// logic is shared with the simulator).
pub fn plan_initial(
    m: &CostModel,
    profiled: &[(String, f64)],
    global_batch: usize,
    gpus: usize,
    tp: usize,
) -> (String, usize) {
    let ladder = Ladder::build(m, global_batch.div_ceil((gpus / tp).max(1)), 4, profiled);
    let sel = ladder.select_initial().method.clone();
    let p = profiled
        .iter()
        .find(|(n, _)| *n == sel)
        .map(|(_, p)| *p)
        .unwrap_or(0.7);
    let plan = search(
        m,
        &PlanInput {
            global_batch,
            gpus,
            verifier_configs: vec![tp],
            accept_p: p,
            method: sel.clone(),
            max_window: 7,
            fixed_batch: None,
            fused_windows: vec![],
        },
    );
    (sel, plan.map(|p| p.w).unwrap_or(3).clamp(1, 7))
}

/// Map a planner method name to an engine draft method. The engine's model
/// family uses the same names; "ngram"/"sam" are token drafters.
fn to_engine_method(name: &str) -> DraftMethod {
    DraftMethod::parse(name)
}

/// Run one batch through `n_workers` worker threads with coupled
/// speculation, then (optionally) plan Fastest-of-N races for the
/// lowest-acceptance requests on the freed workers.
pub fn rollout(
    cfg: &GlobalConfig,
    prompts: Vec<(u64, Vec<i32>)>,
    budget: usize,
    method_rank: &[String],
    window: usize,
) -> Result<RolloutSummary> {
    let t0 = Instant::now();
    let n = prompts.len();
    let per = n.div_ceil(cfg.n_workers.max(1));
    let chunks: Vec<Vec<(u64, Vec<i32>)>> =
        prompts.chunks(per).map(|c| c.to_vec()).collect();

    let primary = method_rank.first().cloned().unwrap_or_else(|| "draft_small".into());
    type WorkerOut = (usize, Vec<(u64, Vec<i32>, String, f64)>, EngineReport);
    let (tx, rx) = channel::<WorkerOut>();
    // done flags per request id: FoN racers poll these to stop early
    let done: Arc<BTreeMap<u64, AtomicBool>> = Arc::new(
        prompts.iter().map(|(id, _)| (*id, AtomicBool::new(false))).collect(),
    );

    let mut handles = Vec::new();
    for (widx, chunk) in chunks.into_iter().enumerate() {
        let tx = tx.clone();
        let art = cfg.artifacts.clone();
        let method = primary.clone();
        let done = done.clone();
        let (seed, temp) = (cfg.seed, cfg.temperature);
        let h = std::thread::Builder::new()
            .name(format!("worker{widx}"))
            .spawn(move || -> Result<()> {
                let rt = Runtime::load(&art)?;
                let reqs: Vec<Request> = chunk
                    .iter()
                    .map(|(id, p)| Request::new(*id, p.clone(), budget))
                    .collect();
                let ecfg = EngineConfig {
                    plan: SlotPlan::coupled(to_engine_method(&method), window),
                    verify: Default::default(),
                    temperature: temp,
                    seed,
                    draft_seed: seed.wrapping_add(1000),
                };
                let mut w = Worker::new(&rt, ecfg, reqs)?;
                let rep = w.rollout_planned()?;
                let outs: Vec<(u64, Vec<i32>, String, f64)> = w
                    .iter_requests()
                    .map(|(_, r)| {
                        done.get(&r.id).map(|f| f.store(true, Ordering::SeqCst));
                        (
                            r.id,
                            r.seq[r.prompt.len()..].to_vec(),
                            format!("worker{widx}"),
                            r.accept.rate(),
                        )
                    })
                    .collect();
                tx.send((widx, outs, rep)).map_err(|e| anyhow!("send: {e}"))?;
                Ok(())
            })
            .map_err(|e| anyhow!("spawn: {e}"))?;
        handles.push(h);
    }
    drop(tx);

    let mut outcomes: BTreeMap<u64, RequestOutcome> = BTreeMap::new();
    let mut per_worker = Vec::new();
    let mut freed_workers: Vec<usize> = Vec::new();
    while let Ok((widx, outs, rep)) = rx.recv() {
        per_worker.push(rep);
        freed_workers.push(widx);
        for (id, tokens, by, accept_rate) in outs {
            outcomes
                .entry(id)
                .or_insert(RequestOutcome { id, tokens, finished_by: by, accept_rate });
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    // FoN phase (Algorithm 3): on real clusters this fires while stragglers
    // are still decoding; at CPU scale every batch has drained by the time
    // workers report, so we plan the races the deployment *would* launch —
    // lowest-acceptance requests first, next-best methods from the given
    // rank — and surface them as SlotPlans. `race_methods` / `fon_demo`
    // exercise the race itself.
    let mut fon_launches = 0usize;
    let fon_wins = 0usize;
    let mut fon_plans = Vec::new();
    if cfg.fon && method_rank.len() > 1 && !outcomes.is_empty() {
        let mean_p = outcomes.values().map(|o| o.accept_rate).sum::<f64>()
            / outcomes.len() as f64;
        let mut stragglers: Vec<fon::Straggler> = outcomes
            .values()
            .filter(|o| o.accept_rate < mean_p)
            .map(|o| fon::Straggler {
                request: o.id,
                accept_rate: o.accept_rate,
                methods: vec![primary.clone()],
            })
            .collect();
        let mut free: Vec<fon::FreeWorker> = freed_workers
            .iter()
            .map(|&id| fon::FreeWorker { id, capacity: per.max(1), method: None, load: 0 })
            .collect();
        let assignment = fon::assign(&mut stragglers, method_rank, &mut free, per.max(1));
        fon_launches = assignment.len();
        fon_plans = fon::slot_plans(&assignment, method_rank, window);
    }

    Ok(RolloutSummary {
        wall_s: t0.elapsed().as_secs_f64(),
        outcomes: outcomes.into_values().collect(),
        per_worker,
        fon_launches,
        fon_wins,
        fon_plans,
    })
}

/// Race `methods` on the same request (sequentially at CPU scale),
/// returning (winning method, tokens, per-method wall seconds). Each
/// replica is a single-slot worker on its own coupled [`SlotPlan`].
/// Losslessness means every replica yields identical tokens; the "win" is
/// purely about speed — exactly the paper's fastest-of-N semantics.
pub fn race_methods(
    art: &Path,
    id: u64,
    prompt: &[i32],
    budget: usize,
    methods: &[String],
    window: usize,
    seed: u64,
) -> Result<(String, Vec<i32>, Vec<(String, f64)>)> {
    let rt = Runtime::load(art)?;
    let mut best: Option<(String, f64, Vec<i32>)> = None;
    let mut times = Vec::new();
    for meth in methods {
        let cfg = EngineConfig {
            plan: SlotPlan::coupled(to_engine_method(meth), window),
            verify: Default::default(),
            temperature: 1.0,
            seed,
            draft_seed: seed.wrapping_add(1000),
        };
        let reqs = vec![Request::new(id, prompt.to_vec(), budget)];
        let mut w = Worker::new(&rt, cfg, reqs)?;
        let rep = w.rollout_planned()?;
        let out = w.outputs().pop().unwrap();
        times.push((meth.clone(), rep.wall_s));
        match &best {
            Some((_, t, prev)) => {
                if !prev.is_empty() && *prev != out {
                    return Err(anyhow!("losslessness violated: {meth} diverged"));
                }
                if rep.wall_s < *t {
                    best = Some((meth.clone(), rep.wall_s, out));
                }
            }
            None => best = Some((meth.clone(), rep.wall_s, out)),
        }
    }
    let (m, _, toks) = best.ok_or_else(|| anyhow!("no methods raced"))?;
    Ok((m, toks, times))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_initial_picks_method_and_window() {
        let m = CostModel::paper_32b();
        let profiled = vec![
            ("draft_mid".to_string(), 0.82),
            ("draft_small".to_string(), 0.74),
            ("ngram".to_string(), 0.40),
        ];
        let (method, w) = plan_initial(&m, &profiled, 8192, 256, 4);
        assert!(profiled.iter().any(|(n, _)| *n == method));
        assert!((1..=7).contains(&w));
    }

    #[test]
    fn to_engine_method_maps() {
        assert_eq!(to_engine_method("ngram"), DraftMethod::Ngram);
        assert!(matches!(to_engine_method("draft_mid"), DraftMethod::Model(_)));
    }
}
