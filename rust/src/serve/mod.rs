//! Continuous-batching rollout server.
//!
//! The engine below this layer is slot-dynamic (`Worker::admit` /
//! `Worker::retire`); this module turns it into a *server*: requests
//! arrive open-loop, wait in a bounded priority [`AdmissionQueue`], get
//! prefill-joined into free KV slots ([`SlotAllocator`]), and leave as
//! they finish — so batch occupancy tracks offered load instead of being
//! fixed at construction. Because occupancy is the variable the paper's
//! TGS model keys on (§4.1), the loop replans speculation — window via
//! Algorithm 1, method via the ladder, both **applied** to the live
//! slots' `SlotPlan`s — whenever occupancy crosses a bucket boundary
//! ([`Replanner`]), re-specialises individual below-average slots with
//! Algorithm 2 (`coordinator::reconfig::Reconfigurator`, every
//! `--reconfig-period` rounds), races tail stragglers in-process with
//! Algorithm 3 (`coordinator::race::RaceArbiter`, `--fon-race`: idle
//! slots host forked replicas under next-best draft methods, the first
//! finisher wins, admissions preempt), and reports rolling
//! latency/throughput/occupancy/race telemetry ([`ServeMetrics`]).
//!
//! Losslessness survives continuous batching: the sampling tape is keyed
//! by (seed, request id, position), never by slot or batch composition,
//! so a request's tokens are identical whether it ran in a static batch
//! or joined mid-flight (`rust/tests/serve_lossless.rs`).
//!
//! Observability (`crate::obs`, PERF.md §Observability): the loop can
//! carry a per-phase span [`Tracer`](crate::obs::Tracer) (flight
//! recorder + chrome://tracing export via `--trace-out`) and publish a
//! Prometheus scrape snapshot (`Batcher::collect_registry` →
//! [`MetricsExporter`](crate::obs::MetricsExporter), `--metrics-addr`) —
//! both assembled from the same counters `ServeMetrics::to_json` renders.
//!
//! Wave-global online draft learning (`--corpus`,
//! [`DraftCorpus`](crate::drafter::corpus::DraftCorpus)): the loop
//! harvests every finished request's verified tokens into a shared
//! corpus, folds the harvest into an immutable snapshot at round
//! boundaries (epoch publication — the per-token draft hot path reads
//! the snapshot lock-free), seeds new admissions' token drafters from
//! the latest epoch, and feeds measured per-method acceptance back into
//! the [`Replanner`]'s and Reconfigurator's priors. A weight-update
//! invalidation decays the corpus and re-widens the priors; under
//! `--workers N` one MASTER corpus is shared by every worker through
//! per-worker taps ([`Cluster::with_corpus`](cluster::Cluster)).
//!
//! Multi-worker serving (`--workers N`): [`cluster::Cluster`] puts N of
//! these loops behind one global queue with heartbeat supervision,
//! work-stealing slot migration over checksummed
//! [`RowTransport`](crate::runtime::RowTransport) frames, cross-worker
//! Fastest-of-N race forks, and WorkerFatal recovery by slot evacuation
//! (capacity degrades to N−1; no request is ever lost).
//!
//! Entry points: `specactor serve` (open-loop arrivals from
//! `sim::traces::ArrivalProcess`), `examples/serve_demo.rs`, and
//! `benches/serve_throughput.rs` (BENCH_serve.json). See PERF.md
//! §Serving for the slot lifecycle and the occupancy→replan policy.

pub mod batcher;
pub mod chaos;
pub mod cluster;
pub mod metrics;
pub mod queue;
pub mod replan;
pub mod slots;

pub use batcher::{
    drive_open_loop, Batcher, EvacKind, Evacuee, FinishedRequest, OpenLoopReport, ServeEngine,
    SyntheticEngine, TickReport,
};
pub use cluster::{drive_cluster_open_loop, Cluster, ClusterMetrics, WorkerHealth};
pub use chaos::{ChaosEngine, FaultPlan};
pub use metrics::ServeMetrics;
pub use queue::{AdmissionQueue, Priority, RejectReason};
pub use replan::{Replanner, ServePlan};
pub use slots::SlotAllocator;
