//! Per-sequence KV slot allocator.
//!
//! The engine's `KvCache` is a fixed `[L, bucket, S, h, dh]` arena; each
//! live request owns one batch row ("slot"). This allocator hands slots
//! out and takes them back with a LIFO free list, so a freshly retired
//! slot — whose cache row was just touched and is hot in the host's
//! caches — is the first one reused by the next admission. The engine
//! layer (`Worker::admit`/`retire`) does the actual row writes; this type
//! only decides *which* row.

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct SlotAllocator {
    /// Free slot indices, LIFO (last freed = first reused).
    free: Vec<usize>,
    live: Vec<bool>,
    /// Peak concurrent occupancy observed.
    pub high_water: usize,
}

impl SlotAllocator {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "slot capacity must be positive");
        SlotAllocator {
            // reversed so initial allocation order is 0, 1, 2, ...
            free: (0..capacity).rev().collect(),
            live: vec![false; capacity],
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.live.len()
    }

    pub fn occupancy(&self) -> usize {
        self.live.len() - self.free.len()
    }

    pub fn is_full(&self) -> bool {
        self.free.is_empty()
    }

    pub fn is_live(&self, slot: usize) -> bool {
        self.live.get(slot).copied().unwrap_or(false)
    }

    /// Claim a free slot (None when the batch is full).
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.live[slot] = true;
        self.high_water = self.high_water.max(self.occupancy());
        Some(slot)
    }

    /// Return a slot to the free list.
    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.live.len() {
            bail!("slot {slot} out of range (capacity {})", self.live.len());
        }
        if !self.live[slot] {
            bail!("slot {slot} double-released");
        }
        self.live[slot] = false;
        self.free.push(slot);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_in_order_and_exhausts() {
        let mut s = SlotAllocator::new(3);
        assert_eq!(s.alloc(), Some(0));
        assert_eq!(s.alloc(), Some(1));
        assert_eq!(s.alloc(), Some(2));
        assert_eq!(s.alloc(), None);
        assert!(s.is_full());
        assert_eq!(s.occupancy(), 3);
        assert_eq!(s.high_water, 3);
    }

    #[test]
    fn lifo_reuse_of_freed_slots() {
        let mut s = SlotAllocator::new(4);
        for _ in 0..3 {
            s.alloc();
        }
        s.release(1).unwrap();
        s.release(0).unwrap();
        // last freed first reused
        assert_eq!(s.alloc(), Some(0));
        assert_eq!(s.alloc(), Some(1));
        assert_eq!(s.alloc(), Some(3));
    }

    #[test]
    fn release_errors() {
        let mut s = SlotAllocator::new(2);
        assert!(s.release(0).is_err()); // never allocated
        assert!(s.release(9).is_err()); // out of range
        let slot = s.alloc().unwrap();
        s.release(slot).unwrap();
        assert!(s.release(slot).is_err()); // double release
    }

    #[test]
    fn occupancy_tracks_high_water() {
        let mut s = SlotAllocator::new(8);
        let a = s.alloc().unwrap();
        let _b = s.alloc().unwrap();
        s.release(a).unwrap();
        assert_eq!(s.occupancy(), 1);
        assert_eq!(s.high_water, 2);
        assert!(s.is_live(1));
        assert!(!s.is_live(a));
    }
}
