//! Seeded chaos harness: deterministic fault injection for the serve
//! loop's recovery ladder.
//!
//! [`FaultPlan`] is parsed from a compact `key=value` spec (the
//! `specactor serve --chaos` flag) and drawn from xoshiro streams keyed
//! by `(seed, site, round)` — like `ArrivalProcess`, the same spec
//! always injects the same faults at the same rounds, so chaos runs are
//! replayable and CI-stable. [`ChaosEngine`] wraps any [`ServeEngine`]
//! and injects **before** delegating: a faulted round never reaches the
//! inner engine, so no partial state is left behind and losslessness is
//! preserved by construction — exactly the contract of the real fault
//! sites (a dead drafter thread, a failed catch-up) that the taxonomy in
//! `engine::fault` classifies.
//!
//! Injected faults:
//!
//! * `step` — per-round probability of a Degradable draft-cache fault
//!   scoped to one live slot ([`SpecError::DraftCatchUp`]),
//! * `drafter` — per-round probability the decoupled drafter thread dies
//!   ([`SpecError::DrafterDead`], batch-wide Degradable),
//! * `slot` — per-round probability of a SlotFatal KV-row fault on one
//!   live slot ([`SpecError::KvRowInvalid`] → quarantine + re-prefill),
//! * `fork` — per-fork probability a racing replica fork fails
//!   ([`SpecError::ForkFailed`], the race degrades, the primary lives),
//! * `prefetch` — per-round probability the overlapped engine's prefetch
//!   thread dies ([`SpecError::PrefetchDead`], batch-wide Degradable:
//!   overlap is an accelerator, so recovery is "lose the overlap, keep
//!   every token" — the ladder degrades and re-promotes, never aborts),
//! * `pause` — every `pause` rounds a mid-wave weight-update pause
//!   fires: the round boundary has already drained verification, so the
//!   pause just invalidates every draft-side cache
//!   ([`ServeEngine::invalidate_draft_state`]) and resumes — the
//!   per-wave invalidation protocol online draft learning needs,
//! * `worker` — per-round probability the whole engine dies
//!   ([`SpecError::Worker`], WorkerFatal). Fires at most once — death is
//!   permanent — and leaves a `killed` scar that makes the subsequent
//!   evacuation extract path flaky (the cluster's salvage fallback),
//! * `transport` — per-frame probability an outbound migration frame is
//!   bit-flipped in flight ([`ServeEngine::corrupt_frame`] → a typed
//!   `SpecError::TransportCorrupt` on decode, retried by `RowTransport`).

use anyhow::{bail, Result};

use crate::drafter::corpus::CorpusHandle;
use crate::engine::{EngineReport, Request, SlotPlan, SpecError, VerifyDiscipline};
use crate::runtime::MigrationPayload;
use crate::util::rng::{splitmix64, Rng};

use super::batcher::ServeEngine;

/// Injection-site keys for the per-(site, round) fault streams: distinct
/// constants so the sites draw from independent tapes.
const SITE_STEP: u64 = 0x5345_5250;
const SITE_DRAFTER: u64 = 0x4452_4654;
const SITE_SLOT: u64 = 0x534C_4F54;
const SITE_FORK: u64 = 0x464F_524B;
const SITE_PICK: u64 = 0x5049_434B;
const SITE_PREFETCH: u64 = 0x5052_4654;
const SITE_WORKER: u64 = 0x574F_524B;
const SITE_TRANSPORT: u64 = 0x5452_4E53;

/// A deterministic fault-injection schedule (see module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-round probability of a Degradable step fault on a live slot.
    pub step: f64,
    /// Per-round probability the decoupled drafter thread dies.
    pub drafter: f64,
    /// Per-round probability of a SlotFatal KV fault on a live slot.
    pub slot: f64,
    /// Per-fork probability a racing replica fork fails.
    pub fork: f64,
    /// Per-round probability the overlapped prefetch thread dies.
    pub prefetch: f64,
    /// Weight-update pause period in rounds (0 = never).
    pub pause: u64,
    /// Per-round probability the whole engine dies (fires at most once).
    pub worker: f64,
    /// Per-frame probability an outbound migration frame is corrupted.
    pub transport: f64,
}

fn rate(key: &str, v: &str) -> Result<f64> {
    let p: f64 = v
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("chaos rate `{key}={v}`: {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("chaos rate `{key}={v}` outside [0, 1]");
    }
    Ok(p)
}

impl FaultPlan {
    /// Parse a `--chaos` spec: comma-separated `key=value` pairs, e.g.
    /// `seed=7,step=0.05,drafter=0.02,slot=0.01,fork=0.05,pause=40`.
    /// Omitted keys default to off (rate 0 / pause never); unknown keys
    /// are errors, not silently ignored faults.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut p = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((k, v)) = part.split_once('=') else {
                bail!("chaos spec entry `{part}` is not key=value");
            };
            match k.trim() {
                "seed" => {
                    p.seed = v
                        .trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("chaos seed `{v}`: {e}"))?
                }
                "step" => p.step = rate("step", v)?,
                "drafter" => p.drafter = rate("drafter", v)?,
                "slot" => p.slot = rate("slot", v)?,
                "fork" => p.fork = rate("fork", v)?,
                "prefetch" => p.prefetch = rate("prefetch", v)?,
                "worker" => p.worker = rate("worker", v)?,
                "transport" => p.transport = rate("transport", v)?,
                "pause" => {
                    p.pause = v
                        .trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("chaos pause `{v}`: {e}"))?
                }
                other => bail!(
                    "unknown chaos key `{other}` (expected seed, step, drafter, slot, \
                     fork, prefetch, worker, transport or pause)"
                ),
            }
        }
        Ok(p)
    }

    /// Compact one-line rendering for serve summaries and bench JSON.
    pub fn label(&self) -> String {
        format!(
            "seed={} step={} drafter={} slot={} fork={} prefetch={} worker={} transport={} \
             pause={}",
            self.seed, self.step, self.drafter, self.slot, self.fork, self.prefetch,
            self.worker, self.transport, self.pause
        )
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.step > 0.0 || self.drafter > 0.0 || self.slot > 0.0 || self.fork > 0.0
            || self.prefetch > 0.0 || self.worker > 0.0 || self.transport > 0.0
            || self.pause > 0
    }

    /// Derive the per-worker plan for cluster serving: same rates, a
    /// worker-unique seed — so workers draw from independent fault tapes
    /// instead of dying in lockstep, while the whole cluster run is still
    /// replayable from the one CLI seed.
    pub fn for_worker(&self, worker: usize) -> FaultPlan {
        FaultPlan { seed: self.seed ^ splitmix64(worker as u64 + 1), ..self.clone() }
    }
}

/// [`ServeEngine`] wrapper that injects the [`FaultPlan`]'s faults ahead
/// of the wrapped engine (see module docs). Per-site injection counters
/// are public so tests and benches can assert the schedule actually
/// fired.
pub struct ChaosEngine<E: ServeEngine> {
    pub inner: E,
    pub plan: FaultPlan,
    rounds: u64,
    forks: u64,
    frames: u64,
    extracts: u64,
    pub injected_step: u64,
    pub injected_drafter: u64,
    pub injected_slot: u64,
    pub injected_fork: u64,
    pub injected_prefetch: u64,
    pub injected_worker: u64,
    pub injected_transport: u64,
    /// Weight-update pauses fired (each one invalidated draft state).
    pub pauses: u64,
    /// Set once the `worker` site fired: death is permanent, and a dead
    /// runtime's row-extract path answers only *sometimes* — the flaky
    /// half exercises the cluster's salvage (re-prefill) fallback.
    pub killed: bool,
}

impl<E: ServeEngine> ChaosEngine<E> {
    pub fn new(inner: E, plan: FaultPlan) -> Self {
        ChaosEngine {
            inner,
            plan,
            rounds: 0,
            forks: 0,
            frames: 0,
            extracts: 0,
            injected_step: 0,
            injected_drafter: 0,
            injected_slot: 0,
            injected_fork: 0,
            injected_prefetch: 0,
            injected_worker: 0,
            injected_transport: 0,
            pauses: 0,
            killed: false,
        }
    }

    /// Faults injected across all sites.
    pub fn injected(&self) -> u64 {
        self.injected_step + self.injected_drafter + self.injected_slot + self.injected_fork
            + self.injected_prefetch + self.injected_worker + self.injected_transport
    }

    /// The deterministic draw stream for `(site, n)`: same plan seed,
    /// site and sequence number → same draw, whatever else happened.
    fn stream(&self, site: u64, n: u64) -> Rng {
        Rng::new(splitmix64(
            self.plan.seed ^ splitmix64(site.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ n),
        ))
    }

    /// Pick a deterministic victim among the currently live, unfinished
    /// slots (None when nothing is live — the fault has no target).
    fn pick_live_slot(&self, n: u64) -> Option<usize> {
        let live: Vec<usize> = (0..self.inner.capacity())
            .filter(|&s| self.inner.request(s).is_some() && !self.inner.is_done(s))
            .collect();
        if live.is_empty() {
            return None;
        }
        let i = self.stream(SITE_PICK, n).below(live.len() as u64) as usize;
        Some(live[i])
    }
}

impl<E: ServeEngine> ServeEngine for ChaosEngine<E> {
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn validate(&self, req: &Request) -> Result<()> {
        self.inner.validate(req)
    }

    fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
        self.inner.admit(slot, req, plan)
    }

    fn retire(&mut self, slot: usize) -> Result<Request> {
        self.inner.retire(slot)
    }

    fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
        self.rounds += 1;
        let n = self.rounds;
        // Worker kill first — a dead engine runs nothing else. At most
        // one injection per engine (death is permanent): the supervisor
        // either evacuates the worker or, as the last survivor, refuses
        // the kill and keeps serving; the `killed` scar stays either way.
        if !self.killed
            && self.plan.worker > 0.0
            && self.stream(SITE_WORKER, n).bernoulli(self.plan.worker)
        {
            self.killed = true;
            self.injected_worker += 1;
            return Err(SpecError::Worker {
                detail: format!("chaos injection: worker killed, round {n}"),
            }
            .into());
        }
        // Weight-update pause next: at a round boundary verification is
        // already drained (the batcher retired before calling round), so
        // the pause is exactly "invalidate draft caches, resume".
        if self.plan.pause > 0 && n % self.plan.pause == 0 {
            self.inner.invalidate_draft_state()?;
            self.pauses += 1;
        }
        if self.plan.drafter > 0.0 && self.stream(SITE_DRAFTER, n).bernoulli(self.plan.drafter)
        {
            self.injected_drafter += 1;
            return Err(SpecError::DrafterDead {
                detail: format!("chaos injection, round {n}"),
            }
            .into());
        }
        if self.plan.step > 0.0 && self.stream(SITE_STEP, n).bernoulli(self.plan.step) {
            if let Some(s) = self.pick_live_slot(n) {
                self.injected_step += 1;
                return Err(SpecError::DraftCatchUp {
                    slot: s,
                    detail: format!("chaos injection, round {n}"),
                }
                .into());
            }
        }
        if self.plan.slot > 0.0 && self.stream(SITE_SLOT, n).bernoulli(self.plan.slot) {
            if let Some(s) = self.pick_live_slot(n ^ SITE_SLOT) {
                self.injected_slot += 1;
                return Err(SpecError::KvRowInvalid {
                    slot: s,
                    detail: format!("chaos injection, round {n}"),
                }
                .into());
            }
        }
        if self.plan.prefetch > 0.0
            && self.stream(SITE_PREFETCH, n).bernoulli(self.plan.prefetch)
        {
            self.injected_prefetch += 1;
            return Err(SpecError::PrefetchDead {
                detail: format!("chaos injection, round {n}"),
            }
            .into());
        }
        self.inner.round(rep)
    }

    fn is_done(&self, slot: usize) -> bool {
        self.inner.is_done(slot)
    }

    fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
        self.inner.slot_plan(slot)
    }

    fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
        self.inner.set_slot_plan(slot, plan)
    }

    fn verify_discipline(&self) -> VerifyDiscipline {
        self.inner.verify_discipline()
    }

    fn request(&self, slot: usize) -> Option<&Request> {
        self.inner.request(slot)
    }

    fn fork(&mut self, src: usize, dst: usize, plan: SlotPlan) -> Result<()> {
        self.forks += 1;
        if self.plan.fork > 0.0 && self.stream(SITE_FORK, self.forks).bernoulli(self.plan.fork)
        {
            self.injected_fork += 1;
            return Err(SpecError::ForkFailed {
                src,
                dst,
                detail: format!("chaos injection, fork {}", self.forks),
            }
            .into());
        }
        self.inner.fork(src, dst, plan)
    }

    fn invalidate_draft_state(&mut self) -> Result<()> {
        self.inner.invalidate_draft_state()
    }

    fn set_corpus(&mut self, h: CorpusHandle) {
        self.inner.set_corpus(h)
    }

    fn invalidations(&self) -> u64 {
        self.inner.invalidations()
    }

    fn extract_payload(&mut self, slot: usize) -> Result<MigrationPayload> {
        self.extracts += 1;
        // A killed runtime answers the extract path only half the time:
        // the failing half drives the cluster's clone-and-salvage
        // fallback (front-of-lane re-prefill under the retry budget).
        if self.killed && self.stream(SITE_WORKER, self.extracts ^ 0x4558_5452).bernoulli(0.5) {
            return Err(SpecError::Worker {
                detail: format!("dead runtime refused row extract for slot {slot}"),
            }
            .into());
        }
        self.inner.extract_payload(slot)
    }

    fn snapshot_payload(&self, slot: usize) -> Result<MigrationPayload> {
        self.inner.snapshot_payload(slot)
    }

    fn insert_payload(&mut self, slot: usize, p: MigrationPayload, plan: SlotPlan) -> Result<()> {
        self.inner.insert_payload(slot, p, plan)
    }

    fn corrupt_frame(&mut self, frame: &mut [u8]) -> bool {
        self.frames += 1;
        if self.plan.transport > 0.0
            && self.stream(SITE_TRANSPORT, self.frames).bernoulli(self.plan.transport)
        {
            self.injected_transport += 1;
            if !frame.is_empty() {
                let i = self.stream(SITE_TRANSPORT, self.frames ^ 0x464C_4950)
                    .below(frame.len() as u64) as usize;
                frame[i] ^= 0x40;
            }
            return true;
        }
        self.inner.corrupt_frame(frame)
    }

    fn attach_tracer(&mut self, t: crate::obs::Tracer) {
        self.inner.attach_tracer(t)
    }

    fn collect_metrics(&self, reg: &mut crate::obs::MetricRegistry) {
        let sites: [(&str, u64); 7] = [
            ("step", self.injected_step),
            ("drafter", self.injected_drafter),
            ("slot", self.injected_slot),
            ("fork", self.injected_fork),
            ("prefetch", self.injected_prefetch),
            ("worker", self.injected_worker),
            ("transport", self.injected_transport),
        ];
        for (site, v) in sites {
            reg.counter_l(
                "specactor_chaos_injected",
                "Chaos faults injected",
                &[("site", site)],
                v as f64,
            );
        }
        reg.counter(
            "specactor_chaos_pauses",
            "Weight-update pauses fired (each invalidated draft state)",
            self.pauses as f64,
        );
        self.inner.collect_metrics(reg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::SyntheticEngine;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7, step=0.05,drafter=0.02,slot=0.01,fork=0.5,worker=0.03,transport=0.2,pause=40",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.step, 0.05);
        assert_eq!(p.drafter, 0.02);
        assert_eq!(p.slot, 0.01);
        assert_eq!(p.fork, 0.5);
        assert_eq!(p.worker, 0.03);
        assert_eq!(p.transport, 0.2);
        assert_eq!(p.pause, 40);
        assert!(p.is_active());
        assert!(p.label().contains("worker=0.03"));
        assert!(p.label().contains("transport=0.2"));
        // per-worker derivation varies the seed, nothing else
        let w1 = p.for_worker(1);
        assert_ne!(w1.seed, p.seed);
        assert_ne!(w1.seed, p.for_worker(2).seed);
        assert_eq!(w1.worker, p.worker);
        assert_eq!(w1.transport, p.transport);
        // omitted keys default to off
        let q = FaultPlan::parse("seed=3").unwrap();
        assert_eq!(q.seed, 3);
        assert!(!q.is_active());
        assert!(q.label().contains("seed=3"));
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("bogus=1").is_err(), "unknown keys must error");
        assert!(FaultPlan::parse("step").is_err(), "missing `=` must error");
        assert!(FaultPlan::parse("step=1.5").is_err(), "rates beyond 1 must error");
        assert!(FaultPlan::parse("step=-0.1").is_err(), "negative rates must error");
        assert!(FaultPlan::parse("seed=x").is_err(), "non-numeric seed must error");
    }

    #[test]
    fn injection_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let plan = FaultPlan { seed, step: 0.3, drafter: 0.1, slot: 0.1, ..Default::default() };
            let mut e = ChaosEngine::new(SyntheticEngine::new(2, 5), plan);
            e.admit(0, Request::new(1, vec![1, 2], 64), SlotPlan::vanilla()).unwrap();
            let mut rep = EngineReport::default();
            let mut pattern = Vec::new();
            for _ in 0..64 {
                pattern.push(e.round(&mut rep).is_err());
            }
            (pattern, e.injected())
        };
        let (a, na) = run(9);
        let (b, nb) = run(9);
        assert_eq!(a, b, "same seed must inject the same schedule");
        assert_eq!(na, nb);
        assert!(na > 0, "rates this high must inject something in 64 rounds");
        let (c, _) = run(10);
        assert_ne!(a, c, "different seeds must differ (with overwhelming probability)");
    }

    #[test]
    fn faulted_rounds_never_reach_the_inner_engine() {
        // drafter=1: every round faults before delegation, so the inner
        // engine generates nothing and no partial state can exist
        let plan = FaultPlan { seed: 1, drafter: 1.0, ..Default::default() };
        let mut e = ChaosEngine::new(SyntheticEngine::new(1, 5), plan);
        e.admit(0, Request::new(1, vec![1, 2], 8), SlotPlan::vanilla()).unwrap();
        let mut rep = EngineReport::default();
        for _ in 0..5 {
            assert!(e.round(&mut rep).is_err());
        }
        assert_eq!(rep.total_generated, 0);
        assert_eq!(e.request(0).unwrap().seq, vec![1, 2]);
        assert_eq!(e.injected_drafter, 5);
    }

    #[test]
    fn pause_fires_on_schedule_and_invalidates() {
        let plan = FaultPlan { seed: 1, pause: 3, ..Default::default() };
        let mut e = ChaosEngine::new(SyntheticEngine::new(1, 5), plan);
        e.admit(0, Request::new(1, vec![1, 2], 64), SlotPlan::vanilla()).unwrap();
        let mut rep = EngineReport::default();
        for _ in 0..9 {
            e.round(&mut rep).unwrap();
        }
        assert_eq!(e.pauses, 3, "rounds 3, 6, 9");
        assert_eq!(e.inner.invalidations, 3, "each pause must invalidate draft state");
    }

    #[test]
    fn prefetch_faults_are_batchwide_degradable() {
        let plan = FaultPlan::parse("seed=2,prefetch=1").unwrap();
        assert!(plan.is_active());
        assert!(plan.label().contains("prefetch=1"));
        let mut e = ChaosEngine::new(SyntheticEngine::new(2, 5).with_overlap(), plan);
        e.admit(0, Request::new(1, vec![1, 2], 8), SlotPlan::vanilla()).unwrap();
        let mut rep = EngineReport::default();
        let err = e.round(&mut rep).unwrap_err();
        let se = err.downcast_ref::<SpecError>().expect("typed");
        assert_eq!(se.severity(), crate::engine::Severity::Degradable);
        assert_eq!(se.slot(), None, "a dead prefetch thread is batch-wide, not slot-scoped");
        assert_eq!(e.injected_prefetch, 1);
        assert_eq!(e.injected(), 1);
    }

    #[test]
    fn worker_site_kills_once_and_scars_the_extract_path() {
        let plan = FaultPlan { seed: 3, worker: 1.0, ..Default::default() };
        let mut e = ChaosEngine::new(SyntheticEngine::new(2, 5), plan);
        e.admit(0, Request::new(1, vec![1, 2], 64), SlotPlan::vanilla()).unwrap();
        e.admit(1, Request::new(2, vec![3, 4], 64), SlotPlan::vanilla()).unwrap();
        let mut rep = EngineReport::default();
        let err = e.round(&mut rep).unwrap_err();
        let se = err.downcast_ref::<SpecError>().expect("typed");
        assert_eq!(se.severity(), crate::engine::Severity::WorkerFatal);
        assert!(e.killed);
        assert_eq!(e.injected_worker, 1);
        // death is permanent: the site never re-fires, so the only
        // further failures come from the scarred extract path
        for _ in 0..5 {
            let _ = e.round(&mut rep);
        }
        assert_eq!(e.injected_worker, 1, "the worker site fires at most once");
        // a dead runtime's extract path is flaky, not gone: over many
        // draws both halves (payload served / refused) must appear
        let (mut served, mut refused) = (0, 0);
        for _ in 0..64 {
            match e.extract_payload(0) {
                Ok(p) => {
                    served += 1;
                    // non-destructive re-install so the next draw has a target
                    e.insert_payload(0, p, SlotPlan::vanilla()).unwrap();
                }
                Err(_) => refused += 1,
            }
        }
        assert!(served > 0, "salvageable extracts must sometimes succeed");
        assert!(refused > 0, "a dead runtime must sometimes refuse");
    }

    #[test]
    fn transport_site_flips_frames_deterministically() {
        let plan = FaultPlan { seed: 11, transport: 0.5, ..Default::default() };
        let run = |plan: FaultPlan| {
            let mut e = ChaosEngine::new(SyntheticEngine::new(1, 5), plan);
            let mut pattern = Vec::new();
            for _ in 0..32 {
                let mut frame = vec![0u8; 64];
                let hit = e.corrupt_frame(&mut frame);
                assert_eq!(hit, frame.iter().any(|&b| b != 0), "hit must mean a real flip");
                pattern.push(hit);
            }
            (pattern, e.injected_transport)
        };
        let (a, na) = run(plan.clone());
        let (b, nb) = run(plan);
        assert_eq!(a, b, "same seed, same corruption schedule");
        assert_eq!(na, nb);
        assert!(na > 0 && na < 32, "rate 0.5 must corrupt some frames, not all");
        // an inactive site never touches frames
        let (c, nc) = run(FaultPlan { seed: 11, ..Default::default() });
        assert!(c.iter().all(|&h| !h));
        assert_eq!(nc, 0);
    }

    #[test]
    fn slot_faults_target_live_slots_only() {
        let plan = FaultPlan { seed: 4, slot: 1.0, ..Default::default() };
        let mut e = ChaosEngine::new(SyntheticEngine::new(4, 5), plan);
        let mut rep = EngineReport::default();
        // nothing live: the fault has no victim and the round proceeds
        assert!(e.round(&mut rep).is_ok());
        assert_eq!(e.injected_slot, 0);
        e.admit(2, Request::new(1, vec![1, 2], 8), SlotPlan::vanilla()).unwrap();
        let err = e.round(&mut rep).unwrap_err();
        let se = err.downcast_ref::<SpecError>().expect("typed");
        assert_eq!(se.slot(), Some(2), "the only live slot must be the victim");
        assert_eq!(e.injected_slot, 1);
    }
}
