//! Concurrency-aware replanning (the serving-side analogue of §4.1).
//!
//! The paper's central observation is that the profitable draft window —
//! and even the profitable draft *method* — depends on the per-worker
//! batch size: verification cost grows with batch (affine `V_w(b)`), so
//! larger live batches want smaller windows. Under continuous batching
//! the live batch (occupancy) changes every round, so the serve loop
//! re-runs Algorithm 1 ([`search`]) and re-consults the [`Ladder`] — but
//! only when occupancy crosses a *bucket boundary*, the same hysteresis
//! trick the AOT bucket table uses: replanning on every ±1 occupancy
//! change would thrash, while bucket-granular replanning is at most
//! `O(log capacity)` plan switches per load swing.
//!
//! Both the planned **window** and the planned **method** are *applied*:
//! the batcher converts [`ServePlan`] into the engine's per-slot
//! `SlotPlan` on every admission and — at bucket crossings — rewrites
//! every live slot (drafter state is rebuilt from the slot's verified
//! prefix by `Worker::set_plan`, so a mid-flight method switch costs one
//! catch-up pass, not a batch restart). Algorithm 2's reconfigurator then
//! re-specialises individual slots from that common baseline.

use crate::engine::VerifyDiscipline;
use crate::ladder::Ladder;
use crate::planner::costmodel::CostModel;
use crate::planner::plan::{search, PlanInput};
use crate::runtime::Manifest;
use crate::sim::TraceConfig;

/// Minimum drafted-token evidence before a measured acceptance rate is
/// allowed to move a prior (below this the rate is mostly noise).
pub const MIN_MEASURED_DRAFTED: u64 = 64;

/// The replanner's current decision for the live occupancy bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct ServePlan {
    /// Ladder-selected draft method for this occupancy (applied to slots
    /// on admission and at bucket crossings).
    pub method: String,
    /// Draft window the engine runs next rounds with (applied).
    /// `0` means Algorithm 1 found no speculative plan beating vanilla at
    /// this occupancy — the batcher runs plain decode rounds.
    pub window: usize,
    /// Occupancy bucket (upper bound) this plan was computed for.
    pub bucket: usize,
    /// Modelled speedup over vanilla decoding at this occupancy.
    pub modelled_speedup: f64,
}

/// Occupancy-bucketed replanner over the analytic cost model.
#[derive(Debug)]
pub struct Replanner {
    cost: CostModel,
    profiled: Vec<(String, f64)>,
    /// The static profiled priors as constructed — the re-widening target
    /// a weight-update decay restores ([`Replanner::note_decay`]) and the
    /// anchor measured acceptance is blended against (so repeated feeds
    /// of cumulative measurements stay idempotent, never compounding).
    profiled0: Vec<(String, f64)>,
    /// Sorted occupancy bucket upper bounds (last one is open-ended).
    buckets: Vec<usize>,
    /// Draft windows the runtime can actually verify (lowered step window
    /// minus the bonus position), ascending.
    allowed_windows: Vec<usize>,
    max_window: usize,
    /// Verify discipline of the engine the plan lands on: fused engines
    /// run any window up to the grid's maximum (rounding up at verify
    /// time, priced by the search); grouped engines get the searched
    /// window snapped DOWN onto the grid so the common plan sits on a
    /// group Algorithm 2's own snapping can coalesce stragglers into.
    discipline: VerifyDiscipline,
    current: Option<usize>,
    pub plan: ServePlan,
}

impl Replanner {
    /// `buckets` are occupancy boundaries (e.g. the manifest's batch
    /// buckets); `allowed_windows` the verifiable draft windows (from the
    /// manifest's lowered step windows: `w - 1` for each `w >= 2`).
    pub fn new(
        cost: CostModel,
        profiled: Vec<(String, f64)>,
        buckets: Vec<usize>,
        allowed_windows: Vec<usize>,
        max_window: usize,
    ) -> Self {
        let mut buckets: Vec<usize> = buckets.into_iter().filter(|&b| b > 0).collect();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            buckets.push(1);
        }
        // an empty list (no verifiable speculative window lowered) is kept
        // empty: plan_for then always emits window 0 — vanilla rounds —
        // instead of a window the engine would refuse to verify
        let mut allowed_windows: Vec<usize> =
            allowed_windows.into_iter().filter(|&w| w > 0).collect();
        allowed_windows.sort_unstable();
        allowed_windows.dedup();
        let mut r = Replanner {
            cost,
            profiled0: profiled.clone(),
            profiled,
            buckets,
            allowed_windows,
            max_window: max_window.max(1),
            discipline: VerifyDiscipline::Fused,
            current: None,
            plan: ServePlan {
                method: String::new(),
                window: 1,
                bucket: 0,
                modelled_speedup: 1.0,
            },
        };
        // seed an initial plan for the smallest bucket (the first
        // on_occupancy call establishes the real bucket)
        r.plan = r.plan_for(r.buckets[0]);
        r
    }

    /// Plan for a grouped-verify engine (`--grouped-verify` A/B): the
    /// applied window snaps down onto the verifiable grid instead of
    /// running at the search's exact argmax. A no-op when the discipline
    /// is unchanged (the common case — `Batcher::new` always aligns the
    /// replanner to its engine), so established bucket state and the
    /// seeded plan are kept.
    pub fn for_discipline(mut self, d: VerifyDiscipline) -> Self {
        if d == self.discipline {
            return self;
        }
        self.discipline = d;
        self.current = None;
        self.plan = self.plan_for(self.buckets[0]);
        self
    }

    /// Replanner wired to a lowered artifact set: occupancy buckets from
    /// the manifest's batch buckets, verifiable draft windows from its
    /// lowered step windows (`w - 1` for each `w >= 2`). Because the
    /// selected method is *applied* to slots (not advisory), profiled
    /// methods the artifact set cannot serve — model drafters absent from
    /// the manifest — are dropped up front; token drafters (ngram/sam)
    /// run on any artifact set. An empty result falls back to n-gram so
    /// the ladder always has a servable rung.
    pub fn for_manifest(
        m: &Manifest,
        cost: CostModel,
        profiled: Vec<(String, f64)>,
        max_window: usize,
    ) -> Self {
        let mut profiled: Vec<(String, f64)> = profiled
            .into_iter()
            .filter(|(name, _)| {
                matches!(name.as_str(), "ngram" | "sam") || m.models.contains_key(name)
            })
            .collect();
        if profiled.is_empty() {
            profiled.push(("ngram".to_string(), 0.5));
        }
        Self::new(cost, profiled, m.batch_buckets.clone(), m.draft_windows(), max_window)
    }

    /// Default replanner for engines without a manifest (the synthetic
    /// smoke engine and artifact-less bench fallback): the default AOT
    /// bucket/window grid with the paper-profiled 32B acceptance table.
    pub fn synthetic() -> Self {
        Self::new(
            CostModel::paper_32b(),
            TraceConfig::grpo_32b_20k().profiled_acceptance(),
            vec![1, 2, 4, 8, 16, 32],
            vec![1, 3, 7],
            7,
        )
    }

    fn bucket_of(&self, occ: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= occ)
            .unwrap_or(*self.buckets.last().unwrap())
    }

    /// Fold measured per-method acceptance (rate over `drafted` drafted
    /// tokens, from `ServeMetrics::method_acceptance` deltas) into the
    /// ladder priors, so Algorithm 1/2 start from measured rates instead
    /// of static profiles once the wave has produced evidence. Each prior
    /// is re-blended from its ORIGINAL profiled value with a pseudo-count
    /// (`ladder::blend_measured`), so feeding cumulative measurements
    /// repeatedly converges instead of compounding. Methods without a
    /// profiled prior (e.g. a corpus-warmed sam) are added outright.
    /// Returns true when any prior moved — the current bucket is then
    /// invalidated so the next occupancy report replans.
    pub fn feed_measured(&mut self, measured: &[(String, f64, u64, u64)]) -> bool {
        let mut moved = false;
        for (method, rate, _accepted, drafted) in measured {
            if *drafted < MIN_MEASURED_DRAFTED || method == "vanilla" {
                continue;
            }
            let prior = self
                .profiled0
                .iter()
                .find(|(m, _)| m == method)
                .map(|(_, p)| *p)
                .unwrap_or(*rate);
            let blended = crate::ladder::blend_measured(prior, *rate, *drafted);
            match self.profiled.iter_mut().find(|(m, _)| m == method) {
                Some((_, p)) => {
                    if (*p - blended).abs() > 1e-3 {
                        *p = blended;
                        moved = true;
                    }
                }
                None => {
                    self.profiled.push((method.clone(), blended));
                    moved = true;
                }
            }
        }
        if moved {
            self.current = None;
        }
        moved
    }

    /// Weight-update re-widening: the measured evidence described the OLD
    /// policy's acceptance, so restore the static profiled priors and
    /// force a replan at the next occupancy report. (The caller resets
    /// its measurement baseline at the same boundary, so post-update
    /// feeds blend fresh evidence only.)
    pub fn note_decay(&mut self) {
        self.profiled = self.profiled0.clone();
        self.current = None;
    }

    /// Report the live occupancy. Returns the fresh plan when the
    /// occupancy crossed a bucket boundary (replan), None otherwise.
    pub fn on_occupancy(&mut self, occ: usize) -> Option<&ServePlan> {
        let b = self.bucket_of(occ.max(1));
        if self.current == Some(b) {
            return None;
        }
        self.current = Some(b);
        self.plan = self.plan_for(b);
        Some(&self.plan)
    }

    /// Ladder selection + Algorithm 1 window search at batch `b`.
    fn plan_for(&self, b: usize) -> ServePlan {
        // representative profiling window for the ladder curves (the
        // search below picks the actually-run window)
        let ladder = Ladder::build(&self.cost, b, 4, &self.profiled);
        let sel = ladder.select_initial();
        let method = sel.method.clone();
        let accept_p = sel.profiled_p;
        // Enumerate only runnable windows: above the verifiable grid
        // `step_up` has no step size to round into, so a larger candidate
        // would be priced with NO padding waste — an optimistic phantom
        // that could displace the fairly-priced argmax before the clamp
        // below (same cap as `Reconfigurator::on_round`).
        let max_window = match self.allowed_windows.last() {
            Some(&m) => self.max_window.min(m),
            None => self.max_window,
        };
        let plan = search(
            &self.cost,
            &PlanInput {
                global_batch: b,
                // single-replica serving: one drafter + one verifier slice
                gpus: 2 * self.cost.g_ref,
                verifier_configs: vec![self.cost.g_ref],
                accept_p,
                method: method.clone(),
                max_window,
                fixed_batch: Some(b),
                // price candidate windows as the fused engine runs them:
                // rounded up into the verifiable grid, padding-waste term
                fused_windows: self.allowed_windows.clone(),
            },
        );
        let (window, speedup) = match plan {
            Some(p) => match self.allowed_windows.last() {
                // fused engine: any window up to the grid's maximum runs
                // (rounding up at verify time), and the search priced
                // exactly that padding (fused_windows) — apply the argmax
                // as chosen instead of snapping it back onto the grid
                Some(&max) if self.discipline == VerifyDiscipline::Fused => {
                    (p.w.min(max), p.speedup)
                }
                // grouped engine: every distinct window is a β-paying
                // verify step, so the common plan snaps DOWN onto the
                // grid (when even the smallest grid window exceeds the
                // plan, vanilla is closer to the planner's intent)
                Some(_) => (
                    self.allowed_windows.iter().copied().filter(|&w| w <= p.w).max().unwrap_or(0),
                    p.speedup,
                ),
                None => (0, 1.0),
            },
            // Algorithm 1 found no speculative plan beating vanilla
            // ("w = 0 encoded as None"): run plain decode rounds.
            None => (0, 1.0),
        };
        ServePlan { method, window, bucket: b, modelled_speedup: speedup }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiled() -> Vec<(String, f64)> {
        vec![
            ("draft_mid".to_string(), 0.82),
            ("draft_small".to_string(), 0.74),
            ("ngram".to_string(), 0.40),
        ]
    }

    fn mk() -> Replanner {
        Replanner::new(
            CostModel::paper_32b(),
            profiled(),
            vec![1, 4, 8, 16, 32],
            vec![1, 3, 7],
            7,
        )
    }

    #[test]
    fn replans_only_on_bucket_crossings() {
        let mut r = mk();
        assert!(r.on_occupancy(1).is_some()); // establishes bucket 1
        assert!(r.on_occupancy(1).is_none());
        assert!(r.on_occupancy(2).is_some()); // 1 -> 4
        assert!(r.on_occupancy(3).is_none()); // still bucket 4
        assert!(r.on_occupancy(4).is_none());
        assert!(r.on_occupancy(5).is_some()); // 4 -> 8
        assert!(r.on_occupancy(2).is_some()); // back down
    }

    #[test]
    fn windows_are_verifiable_and_bounded() {
        let mut r = mk();
        for occ in [1usize, 3, 7, 12, 30, 100] {
            r.on_occupancy(occ);
            // 0 = vanilla (no profitable speculative plan); otherwise any
            // window up to the largest verifiable draft window runs (the
            // fused engine rounds intermediate windows up to the next
            // lowered step size, and the search priced that padding)
            assert!(
                r.plan.window <= 7,
                "occ {occ}: window {} beyond the verifiable grid",
                r.plan.window
            );
            assert!(r.plan.bucket >= occ.min(32));
            assert!(r.plan.modelled_speedup.is_finite());
        }
    }

    #[test]
    fn picks_a_model_drafter_at_paper_acceptances() {
        let mut r = mk();
        r.on_occupancy(8);
        assert_ne!(r.plan.method, "ngram");
        assert!(r.plan.modelled_speedup > 1.0);
    }

    #[test]
    fn beyond_largest_bucket_clamps() {
        let mut r = mk();
        r.on_occupancy(32);
        let b32 = r.plan.clone();
        // occupancy above every bucket maps to the last bucket: no replan
        assert!(r.on_occupancy(1000).is_none());
        assert_eq!(r.plan, b32);
    }

    #[test]
    fn grouped_discipline_snaps_the_common_plan_onto_the_grid() {
        let mut r = mk().for_discipline(VerifyDiscipline::Grouped);
        for occ in [1usize, 3, 7, 12, 30] {
            r.on_occupancy(occ);
            assert!(
                [0usize, 1, 3, 7].contains(&r.plan.window),
                "occ {occ}: grouped window {} off the lowered grid",
                r.plan.window
            );
        }
    }

    #[test]
    fn search_never_picks_a_phantom_above_grid_window() {
        // A small verifiable grid with a large max_window: candidates
        // above the grid would be priced with no padding waste (step_up
        // identity) — the search domain must be capped so the applied
        // window and its modelled speedup belong to a runnable plan.
        let mut r = Replanner::new(
            CostModel::paper_32b(),
            profiled(),
            vec![1, 4, 8],
            vec![1, 3],
            7,
        );
        for occ in [1usize, 2, 5, 9] {
            r.on_occupancy(occ);
            assert!(
                r.plan.window <= 3,
                "occ {occ}: window {} beyond the verifiable grid",
                r.plan.window
            );
        }
    }

    #[test]
    fn no_verifiable_window_means_vanilla() {
        // artifacts lowering only the vanilla window (allowed = []) must
        // plan window 0 — plain decode rounds — never a window the engine
        // would refuse to verify
        let mut r = Replanner::new(CostModel::paper_32b(), profiled(), vec![], vec![], 4);
        r.on_occupancy(5);
        assert_eq!(r.plan.window, 0);
        assert!(!r.plan.method.is_empty());
    }

    #[test]
    fn measured_feed_moves_priors_and_forces_replan() {
        let mut r = mk();
        r.on_occupancy(8);
        let before = r.plan.clone();
        // strong measured evidence that ngram accepts far better than its
        // 0.40 profile (the corpus-warmed wave), plus a brand-new sam rate
        let fed = r.feed_measured(&[
            ("ngram".to_string(), 0.9, 900, 1000),
            ("sam".to_string(), 0.8, 400, 500),
        ]);
        assert!(fed, "priors must move on strong evidence");
        assert!(r.profiled.iter().any(|(m, p)| m == "ngram" && *p > 0.40));
        assert!(r.profiled.iter().any(|(m, p)| m == "sam" && *p > 0.0), "sam prior added");
        // bucket invalidated: the same occupancy replans
        assert!(r.on_occupancy(8).is_some());
        // feeding the SAME cumulative evidence again is idempotent
        let again = r.feed_measured(&[("ngram".to_string(), 0.9, 900, 1000)]);
        assert!(!again, "re-feeding identical cumulative evidence must not move priors");
        let _ = before;
    }

    #[test]
    fn tiny_evidence_is_ignored() {
        let mut r = mk();
        r.on_occupancy(8);
        assert!(!r.feed_measured(&[("ngram".to_string(), 1.0, 10, 10)]));
    }

    #[test]
    fn decay_restores_profiled_priors() {
        let mut r = mk();
        r.on_occupancy(8);
        r.feed_measured(&[("ngram".to_string(), 0.95, 950, 1000)]);
        let moved: Vec<_> = r.profiled.clone();
        r.note_decay();
        assert_ne!(r.profiled, moved, "decay must re-widen the priors");
        assert!(r.profiled.iter().any(|(m, p)| m == "ngram" && (*p - 0.40).abs() < 1e-9));
        assert!(r.on_occupancy(8).is_some(), "decay must force a replan");
    }

    #[test]
    fn synthetic_replanner_plans() {
        let mut r = Replanner::synthetic();
        r.on_occupancy(4);
        assert!(r.plan.window <= 7);
        assert!(!r.plan.method.is_empty());
    }
}
