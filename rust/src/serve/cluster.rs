//! Multi-worker cluster serving: N [`ServeEngine`] workers — each wrapped
//! in its own [`Batcher`] — behind ONE global [`AdmissionQueue`], under a
//! supervisor that makes worker death a degraded mode instead of an
//! abort.
//!
//! The single-worker serve loop already survives everything below
//! `Severity::WorkerFatal` (degrade ladder, quarantine + re-prefill);
//! this layer closes the last gap. Per tick the [`Cluster`]:
//!
//! 1. **commits** the cross-worker race frame staged last tick (the
//!    destination adopts the row only if the source is still the same
//!    live, unfinished request — stamp/rollback, `engine/overlap.rs`'s
//!    discipline at cluster scale),
//! 2. **routes** global admissions to the least-loaded alive worker
//!    (only while that worker has genuine headroom, so per-worker queues
//!    never shed what the global queue could hold),
//! 3. **ticks** every alive worker. A `WorkerFatal` error no longer
//!    propagates: the worker is declared [`WorkerHealth::Dead`] and
//!    every live request is *evacuated* — the full migration payload
//!    (request + verified-prefix KV row) is pulled where the runtime
//!    still answers and shipped through [`RowTransport`] (checksummed
//!    frames, bounded exponential-backoff retries on corruption);
//!    where extraction fails the request state is salvaged by cloning
//!    and re-prefilled front-of-lane under the existing quarantine
//!    retry budget. Zero requests are lost; capacity degrades to N−1.
//!    The LAST alive worker is never killed — the kill is refused and
//!    the worker held in `Suspect` (`last_survivor_holds`), so a chaos
//!    schedule can never abort the wave,
//! 4. **supervises** heartbeats: a worker that is occupied but made no
//!    token progress for `suspect_after` consecutive ticks turns
//!    `Suspect` (progress clears it); `dead_after` further stalled ticks
//!    lapse the deadline and the worker is declared dead via
//!    [`SpecError::WorkerDead`] — same evacuation path, plus a flight-
//!    recorder post-mortem,
//! 5. **resolves** cross-worker Fastest-of-N races (first finisher wins,
//!    the loser's slot is cancelled — both sides generated identical
//!    tokens because the sampling tape is keyed by (seed, request,
//!    position), never by worker), **stages** a new race fork of the
//!    worst-acceptance straggler onto a remote idle slot, and
//! 6. **balances**: when a worker drains while another still holds a
//!    deep batch, one slot is work-stolen per tick through the same
//!    transport path, and
//! 7. **folds the wave-global draft corpus** (`with_corpus`): every
//!    worker tap's harvest drains into the MASTER corpus, decay flags
//!    from weight-update pauses relay cluster-wide (one worker's pause
//!    stales the shared epochs for everyone), and ONE snapshot epoch is
//!    published per boundary — the shared handle is the replication
//!    mechanism, so migrated and forked slots always land on the same
//!    warm corpus their source was drafting from.
//!
//! Completion is deduplicated by request id at [`Cluster::drain_finished`]
//! — belt-and-braces for the one race where both sides of a cross-worker
//! fork retire in the same tick.

use std::collections::BTreeSet;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::race::cross_race_candidate;
use crate::drafter::corpus::DraftCorpus;
use crate::engine::{Request, Severity, SpecError};
use crate::obs::MetricRegistry;
use crate::runtime::{MigrationPayload, RowTransport};

use super::batcher::{Batcher, EvacKind, Evacuee, FinishedRequest, OpenLoopReport, ServeEngine};
use super::queue::{AdmissionQueue, Priority};

/// Prometheus family prefix for cluster-level series.
const PROM_CLUSTER: &str = "specactor_cluster_";

/// Skip cross-worker race forks for requests with fewer remaining tokens
/// than this (the same floor `RaceConfig::min_remaining` applies
/// in-process — a fork cannot pay for itself on an almost-done request).
const MIN_RACE_REMAINING: usize = 4;

/// Worker health as the heartbeat supervisor sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerHealth {
    Healthy,
    /// Missed its progress deadline (or survived a refused kill); still
    /// serving, watched closely — progress restores `Healthy`.
    Suspect,
    /// Declared dead: slots evacuated, never ticked again, cluster
    /// capacity degraded to the survivors.
    Dead,
}

impl WorkerHealth {
    /// Gauge encoding for scrapes: 0 healthy, 1 suspect, 2 dead.
    pub fn code(self) -> f64 {
        match self {
            WorkerHealth::Healthy => 0.0,
            WorkerHealth::Suspect => 1.0,
            WorkerHealth::Dead => 2.0,
        }
    }
}

/// Cluster-level counters. Per-worker series are indexed by worker id
/// (the `{worker="i"}` label on scrapes); `counter_series` /
/// `worker_series` are the single source both `to_json` and `register`
/// render from, so the scrape and the summary reconcile by construction.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// Slots migrated off each worker (work-stealing + evacuation rows).
    pub migrations_out: Vec<u64>,
    /// Migrated payloads adopted by each worker.
    pub migrations_in: Vec<u64>,
    /// Requests evacuated off each worker at death.
    pub evacuations: Vec<u64>,
    /// Stalled-tick heartbeat misses observed per worker.
    pub heartbeat_misses: Vec<u64>,
    pub worker_deaths: u64,
    /// Evacuees whose full payload (row included) moved over transport.
    pub evac_extracted: u64,
    /// Evacuees salvaged by cloning → front-of-lane re-prefill.
    pub evac_salvaged: u64,
    /// Evacuees that were still queued on the dead worker → re-routed.
    pub evac_requeued: u64,
    /// Cross-worker race forks staged.
    pub cross_races: u64,
    /// Races the remote replica won (finished before the source).
    pub cross_race_wins: u64,
    /// Race sides cancelled at resolution (losers + invalidated sides).
    pub cross_race_cancels: u64,
    /// Staged race frames rolled back (source finished/moved/died, frame
    /// corrupt, or the destination slot was taken by an admission).
    pub stage_rollbacks: u64,
    /// Kills refused because the victim was the last alive worker.
    pub last_survivor_holds: u64,
    /// Unique requests completed across the cluster.
    pub completed: u64,
    /// Duplicate completions dropped at drain (same-tick race ties).
    pub dup_completions: u64,
    /// Accepted tokens folded into the MASTER corpus' published epochs.
    pub corpus_tokens: u64,
    /// Token-drafter admissions seeded from the shared snapshot, summed
    /// over every worker's tap.
    pub corpus_seeds: u64,
    /// Master corpus snapshot epochs published (cluster-wide: taps never
    /// publish, so this is the single epoch lineage all workers see).
    pub corpus_publishes: u64,
    /// Segments evicted from the master corpus ring under its cap.
    pub corpus_evictions: u64,
    /// Master corpus decays (a weight-update pause on ANY worker decays
    /// the shared corpus and re-widens every worker's priors).
    pub corpus_decays: u64,
}

impl ClusterMetrics {
    fn new(n: usize) -> Self {
        ClusterMetrics {
            migrations_out: vec![0; n],
            migrations_in: vec![0; n],
            evacuations: vec![0; n],
            heartbeat_misses: vec![0; n],
            ..Default::default()
        }
    }

    /// Cluster-wide counters as (key, value) pairs — transport counters
    /// ride along so one series covers the whole migration path.
    pub fn counter_series(&self, t: &RowTransport) -> [(&'static str, u64); 21] {
        [
            ("worker_deaths", self.worker_deaths),
            ("evac_extracted", self.evac_extracted),
            ("evac_salvaged", self.evac_salvaged),
            ("evac_requeued", self.evac_requeued),
            ("cross_races", self.cross_races),
            ("cross_race_wins", self.cross_race_wins),
            ("cross_race_cancels", self.cross_race_cancels),
            ("stage_rollbacks", self.stage_rollbacks),
            ("last_survivor_holds", self.last_survivor_holds),
            ("completed", self.completed),
            ("dup_completions", self.dup_completions),
            ("transport_frames", t.frames),
            ("transport_retries", t.retries),
            ("transport_corruptions", t.corruptions),
            ("transport_escalations", t.escalations),
            ("transport_backoff_ticks", t.backoff_ticks),
            ("corpus_tokens", self.corpus_tokens),
            ("corpus_seeds", self.corpus_seeds),
            ("corpus_publishes", self.corpus_publishes),
            ("corpus_evictions", self.corpus_evictions),
            ("corpus_decays", self.corpus_decays),
        ]
    }

    /// Per-worker counters as (key, per-worker values) pairs.
    pub fn worker_series(&self) -> [(&'static str, &[u64]); 4] {
        [
            ("migrations_out", &self.migrations_out),
            ("migrations_in", &self.migrations_in),
            ("evacuations", &self.evacuations),
            ("heartbeat_misses", &self.heartbeat_misses),
        ]
    }

    fn help(key: &str) -> &'static str {
        match key {
            "worker_deaths" => "Workers declared dead (fault or heartbeat lapse)",
            "evac_extracted" => "Evacuees migrated with their KV row over transport",
            "evac_salvaged" => "Evacuees salvaged by cloning (front-of-lane re-prefill)",
            "evac_requeued" => "Evacuees re-routed straight from the dead worker's queue",
            "cross_races" => "Cross-worker Fastest-of-N race forks staged",
            "cross_race_wins" => "Cross-worker races won by the remote replica",
            "cross_race_cancels" => "Cross-worker race sides cancelled at resolution",
            "stage_rollbacks" => "Staged race frames rolled back before commit",
            "last_survivor_holds" => "Worker kills refused to keep the last survivor",
            "completed" => "Unique requests completed across the cluster",
            "dup_completions" => "Duplicate race completions dropped at drain",
            "transport_frames" => "Migration frames put on the wire",
            "transport_retries" => "Corrupt frames retried under backoff",
            "transport_corruptions" => "Migration frames that failed integrity checks",
            "transport_escalations" => "Deliveries abandoned after the retry budget",
            "transport_backoff_ticks" => "Ticks spent in transport retry backoff",
            "corpus_tokens" => "Accepted tokens in the master corpus' published epochs",
            "corpus_seeds" => "Token-drafter admissions seeded from the shared snapshot",
            "corpus_publishes" => "Master corpus snapshot epochs published",
            "corpus_evictions" => "Segments evicted from the master corpus ring",
            "corpus_decays" => "Master corpus decays relayed from worker weight updates",
            "migrations_out" => "Slots migrated off this worker",
            "migrations_in" => "Migrated payloads adopted by this worker",
            "evacuations" => "Requests evacuated off this worker at death",
            "heartbeat_misses" => "Stalled ticks observed on this worker",
            _ => "Cluster counter",
        }
    }

    /// Compact JSON rendering (same numbers the scrape publishes).
    pub fn to_json(&self, t: &RowTransport, health: &[WorkerHealth]) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.counter_series(t).iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{k}\":{v}"));
        }
        for (k, vs) in self.worker_series() {
            s.push_str(&format!(",\"{k}\":["));
            for (i, v) in vs.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&v.to_string());
            }
            s.push(']');
        }
        s.push_str(",\"health\":[");
        for (i, h) in health.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&(h.code() as u64).to_string());
        }
        s.push_str("]}");
        s
    }

    /// Contribute every cluster series to a scrape snapshot.
    pub fn register(&self, reg: &mut MetricRegistry, t: &RowTransport, health: &[WorkerHealth]) {
        for (k, v) in self.counter_series(t) {
            reg.counter(&format!("{PROM_CLUSTER}{k}"), Self::help(k), v as f64);
        }
        for (k, vs) in self.worker_series() {
            let name = format!("{PROM_CLUSTER}{k}_worker");
            for (w, &v) in vs.iter().enumerate() {
                reg.counter_l(&name, Self::help(k), &[("worker", &w.to_string())], v as f64);
            }
        }
        for (w, h) in health.iter().enumerate() {
            reg.gauge_l(
                "specactor_cluster_worker_health",
                "Worker health (0 healthy, 1 suspect, 2 dead)",
                &[("worker", &w.to_string())],
                h.code(),
            );
        }
        let alive = health.iter().filter(|h| **h != WorkerHealth::Dead).count();
        reg.gauge(
            "specactor_cluster_workers_alive",
            "Workers currently serving (not Dead)",
            alive as f64,
        );
        reg.gauge(
            "specactor_cluster_workers",
            "Workers the cluster was built with",
            health.len() as f64,
        );
    }
}

/// A cross-worker race frame staged last tick, committed (or rolled
/// back) at the start of this one.
struct StagedFork {
    /// The encoded (possibly chaos-corrupted) migration frame.
    frame: Vec<u8>,
    /// Source (worker, slot) still running the primary.
    src: (usize, usize),
    /// Destination worker holding the idle slot.
    dst: usize,
    id: u64,
    prio: Priority,
    arrival_s: f64,
}

/// A live cross-worker Fastest-of-N race: the same request decoding on
/// two workers; the first finisher wins.
struct CrossRace {
    id: u64,
    src: (usize, usize),
    dst: (usize, usize),
}

/// The multi-worker supervisor (see module docs).
pub struct Cluster<E: ServeEngine> {
    workers: Vec<Batcher<E>>,
    health: Vec<WorkerHealth>,
    /// Consecutive occupied-but-zero-progress ticks per worker.
    stalls: Vec<u64>,
    /// `report.total_generated` at the last observed beat.
    last_gen: Vec<u64>,
    /// The one global admission queue all arrivals enter through.
    pub queue: AdmissionQueue,
    /// The migration codec + its retry/corruption ledger.
    pub transport: RowTransport,
    pub metrics: ClusterMetrics,
    staged: Option<StagedFork>,
    races: Vec<CrossRace>,
    /// Wave-global MASTER draft corpus (`with_corpus`): the single
    /// publisher behind every worker's tap.
    corpus: Option<DraftCorpus>,
    /// Cross-worker racing enabled (`with_cross_racing`).
    racing: bool,
    /// Ids already drained as finished (the dedup set).
    done_ids: BTreeSet<u64>,
    ticks: u64,
    /// Stalled ticks on an occupied worker before it turns Suspect.
    pub suspect_after: u64,
    /// Further stalled ticks before a Suspect worker's deadline lapses.
    pub dead_after: u64,
}

impl<E: ServeEngine> Cluster<E> {
    /// Build a cluster over pre-configured per-worker batchers and a
    /// global admission queue bound.
    pub fn new(workers: Vec<Batcher<E>>, queue_cap: usize) -> Self {
        assert!(!workers.is_empty(), "cluster needs at least one worker");
        let n = workers.len();
        Cluster {
            health: vec![WorkerHealth::Healthy; n],
            stalls: vec![0; n],
            last_gen: vec![0; n],
            queue: AdmissionQueue::new(queue_cap),
            transport: RowTransport::default(),
            metrics: ClusterMetrics::new(n),
            staged: None,
            races: Vec::new(),
            corpus: None,
            racing: false,
            done_ids: BTreeSet::new(),
            ticks: 0,
            suspect_after: 4,
            dead_after: 4,
            workers,
        }
    }

    /// Enable cross-worker Fastest-of-N race forks.
    pub fn with_cross_racing(mut self) -> Self {
        self.racing = true;
        self
    }

    /// Attach a wave-global MASTER draft corpus: each worker's batcher
    /// gets a tap of the master's snapshot handle ([`DraftCorpus::tap`]),
    /// so every worker's completions fold into ONE epoch lineage and
    /// every worker's engine — including migrated and forked slots,
    /// which admit through those same engines — seeds new token drafters
    /// from the same snapshot. The shared handle IS the replication
    /// mechanism: one master publish per tick and all workers observe
    /// the new epoch at their next admission.
    pub fn with_corpus(mut self, master: DraftCorpus) -> Self {
        for b in &mut self.workers {
            b.install_corpus(DraftCorpus::tap(master.handle()));
        }
        self.corpus = Some(master);
        self
    }

    /// Override the heartbeat policy (stalled ticks to Suspect, further
    /// stalled ticks to Dead).
    pub fn with_heartbeat(mut self, suspect_after: u64, dead_after: u64) -> Self {
        self.suspect_after = suspect_after.max(1);
        self.dead_after = dead_after.max(1);
        self
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn workers(&self) -> &[Batcher<E>] {
        &self.workers
    }

    pub fn worker_mut(&mut self, w: usize) -> &mut Batcher<E> {
        &mut self.workers[w]
    }

    pub fn health(&self) -> &[WorkerHealth] {
        &self.health
    }

    /// Workers currently serving (not Dead).
    pub fn alive(&self) -> usize {
        self.health.iter().filter(|h| **h != WorkerHealth::Dead).count()
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Offer a request to the global queue (false = backpressure).
    pub fn enqueue(&mut self, req: Request, prio: Priority, now_s: f64) -> bool {
        self.queue.push(req, prio, now_s)
    }

    /// Nothing queued anywhere, nothing in flight, nothing staged.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
            && self.staged.is_none()
            && self.workers.iter().all(|b| b.idle())
    }

    /// Typed rejections across the cluster: global-queue sheds plus every
    /// worker's sheds and retry exhaustions. Together with completions
    /// and invalid screens this accounts for every offered request —
    /// nothing is ever silently lost.
    pub fn rejected(&self) -> u64 {
        self.queue.rejected + self.workers.iter().map(|b| b.queue.rejected).sum::<u64>()
    }

    /// Completed requests drained off every worker, deduplicated by
    /// request id (a cross-worker race tie can retire both sides in the
    /// same tick; the copies are token-identical, so the second is
    /// dropped and counted, never double-delivered).
    pub fn drain_finished(&mut self) -> Vec<FinishedRequest> {
        let mut out = Vec::new();
        for b in &mut self.workers {
            for f in b.drain_finished() {
                if self.done_ids.insert(f.req.id) {
                    self.metrics.completed += 1;
                    out.push(f);
                } else {
                    self.metrics.dup_completions += 1;
                }
            }
        }
        out
    }

    /// One cluster round (see module docs for the phase order).
    pub fn tick(&mut self, now_s: f64) -> Result<()> {
        self.ticks += 1;
        self.commit_staged()?;
        self.route();
        for w in 0..self.workers.len() {
            if self.health[w] == WorkerHealth::Dead {
                continue;
            }
            match self.workers[w].tick(now_s) {
                Ok(_) => self.observe_beat(w),
                Err(e) => {
                    let fatal = e
                        .downcast_ref::<SpecError>()
                        .map(|s| s.severity() == Severity::WorkerFatal)
                        .unwrap_or(false);
                    if !fatal {
                        // sub-fatal severities are recovered inside the
                        // batcher; anything escaping is a real bug
                        return Err(e);
                    }
                    // already captured by the batcher's on_round_error
                    self.on_worker_fatal(w, e, true)?;
                }
            }
        }
        self.check_heartbeats()?;
        self.resolve_races()?;
        if self.racing && self.workers.len() > 1 {
            self.stage_race();
        }
        self.balance()?;
        self.corpus_roundup();
        Ok(())
    }

    /// MASTER-corpus round boundary (no-op without `with_corpus`): drain
    /// every worker tap's harvest into the master, relay decay flags (a
    /// weight-update pause on ONE worker decays the SHARED corpus — its
    /// epochs are stale against the new weights for everyone — and
    /// re-widens every worker's planner priors), reseed the fresh
    /// lineage from the live slots' verified prefixes, and publish one
    /// epoch for the whole cluster. Worker taps never publish; measured
    /// acceptance feeds into each worker's replanner at the master's
    /// publish/decay boundaries.
    fn corpus_roundup(&mut self) {
        if self.corpus.is_none() {
            return;
        }
        let mut decay = false;
        let mut segs: Vec<Vec<i32>> = Vec::new();
        let mut seeds = 0u64;
        for b in &mut self.workers {
            if let Some(tap) = b.corpus_mut() {
                decay |= tap.take_decay_flag();
                segs.extend(tap.drain_pending());
                seeds += tap.stats.seeds;
            }
        }
        if decay {
            // the drained harvest was accepted under the OLD weights
            // (completions from workers that never paused, queued before
            // the decay relayed) — folding it into the fresh lineage
            // would defeat the staleness purge the decay performs, so
            // drop it wholesale and re-sweep below
            segs.clear();
            // live verified prefixes survive the weight update
            // (verification owns them) — they reseed the fresh lineage,
            // and this sweep is the SOLE reseed source (taps skip their
            // local reseed, so nothing is duplicated)
            for w in 0..self.workers.len() {
                if self.health[w] == WorkerHealth::Dead {
                    continue;
                }
                let b = &self.workers[w];
                for s in 0..b.slots.capacity() {
                    if b.slots.is_live(s) {
                        if let Some(r) = b.engine().request(s) {
                            segs.push(r.seq.clone());
                        }
                    }
                }
            }
            self.corpus.as_mut().unwrap().decay();
            for b in &mut self.workers {
                b.note_prior_decay();
            }
        }
        let master = self.corpus.as_mut().unwrap();
        for s in &segs {
            master.add_segment(s);
        }
        let mut published = false;
        if master.publish_due() {
            master.publish();
            published = true;
        }
        self.metrics.corpus_tokens = master.stats.tokens;
        self.metrics.corpus_publishes = master.stats.publishes;
        self.metrics.corpus_evictions = master.stats.evictions;
        self.metrics.corpus_decays = master.stats.decays;
        self.metrics.corpus_seeds = seeds;
        if published || decay {
            for b in &mut self.workers {
                b.feed_measured_deltas();
            }
        }
    }

    /// Per-tick heartbeat observation: token progress (or an empty
    /// worker) is a beat; an occupied worker that generated nothing
    /// accumulates stall ticks and heartbeat misses.
    fn observe_beat(&mut self, w: usize) {
        let gen = self.workers[w].report.total_generated;
        let occupied = self.workers[w].slots.occupancy() > 0;
        if gen > self.last_gen[w] || !occupied {
            self.last_gen[w] = gen;
            self.stalls[w] = 0;
            if self.health[w] == WorkerHealth::Suspect {
                self.health[w] = WorkerHealth::Healthy;
            }
        } else {
            self.stalls[w] += 1;
            self.metrics.heartbeat_misses[w] += 1;
        }
    }

    /// Deadline supervision: `suspect_after` stalls → Suspect;
    /// `dead_after` more → declared dead ([`SpecError::WorkerDead`]) and
    /// evacuated exactly like an in-band WorkerFatal.
    fn check_heartbeats(&mut self) -> Result<()> {
        for w in 0..self.workers.len() {
            match self.health[w] {
                WorkerHealth::Dead => {}
                WorkerHealth::Healthy => {
                    if self.stalls[w] >= self.suspect_after {
                        self.health[w] = WorkerHealth::Suspect;
                    }
                }
                WorkerHealth::Suspect => {
                    if self.stalls[w] >= self.suspect_after + self.dead_after {
                        let e: anyhow::Error = SpecError::WorkerDead { worker: w }.into();
                        self.on_worker_fatal(w, e, false)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// A worker-fatal event: evacuate and degrade — unless the victim is
    /// the last alive worker, in which case the kill is REFUSED (there
    /// is nowhere to evacuate to): the worker is held in Suspect and
    /// keeps serving, so no chaos schedule can abort the wave. `dumped`
    /// says whether the batcher already captured the post-mortem.
    fn on_worker_fatal(&mut self, w: usize, e: anyhow::Error, dumped: bool) -> Result<()> {
        if !dumped {
            self.workers[w].record_fault(&e);
        }
        if self.alive() <= 1 {
            self.health[w] = WorkerHealth::Suspect;
            self.stalls[w] = 0;
            self.metrics.last_survivor_holds += 1;
            return Ok(());
        }
        self.kill_worker(w)
    }

    /// Declare `w` dead and run the evacuation protocol: cancel races
    /// touching it (the surviving side carries the request alone), roll
    /// back any staged frame involving it, then strip every live slot
    /// and queued request off it and redistribute to the survivors.
    pub fn kill_worker(&mut self, w: usize) -> Result<()> {
        if self.health[w] == WorkerHealth::Dead {
            return Ok(());
        }
        if self.alive() <= 1 {
            bail!("refusing to kill worker {w}: it is the last one alive");
        }
        self.health[w] = WorkerHealth::Dead;
        self.metrics.worker_deaths += 1;
        // Cross-worker races with a side on the dead worker: the
        // surviving side keeps decoding the request alone; the dead
        // side's copy must be skipped during evacuation so the request
        // is neither double-served nor lost.
        let mut skip: BTreeSet<u64> = BTreeSet::new();
        let cancels = &mut self.metrics.cross_race_cancels;
        self.races.retain(|r| {
            if r.src.0 == w || r.dst.0 == w {
                skip.insert(r.id);
                *cancels += 1;
                false
            } else {
                true
            }
        });
        if let Some(s) = &self.staged {
            if s.src.0 == w || s.dst == w {
                self.metrics.stage_rollbacks += 1;
                self.staged = None;
            }
        }
        let evacuees = self.workers[w].evacuate();
        for e in evacuees {
            if skip.contains(&e.payload.req.id) {
                continue;
            }
            self.metrics.evacuations[w] += 1;
            self.place_evacuee(w, e)?;
        }
        Ok(())
    }

    /// Re-home one evacuee according to how it left the dead worker.
    fn place_evacuee(&mut self, from: usize, e: Evacuee) -> Result<()> {
        match e.kind {
            // Never admitted: plain re-route, no retry charge. If every
            // survivor is saturated it parks on the global queue.
            EvacKind::Queued => {
                self.metrics.evac_requeued += 1;
                match self.pick_route_worker() {
                    Some(w) => {
                        self.workers[w].enqueue(e.payload.req, e.prio, e.arrival_s);
                    }
                    None => {
                        self.queue.requeue_front(e.payload.req, e.prio, e.arrival_s);
                    }
                }
                Ok(())
            }
            // The dead runtime would not give the row back: clone-based
            // salvage → front-of-lane re-prefill, charged one retry.
            EvacKind::Salvaged => {
                let w = self
                    .least_loaded_alive()
                    .ok_or_else(|| anyhow!("no surviving worker for salvage"))?;
                self.metrics.evac_salvaged += 1;
                self.workers[w].readmit(e.payload.req, e.prio, e.arrival_s, e.retries, true);
                Ok(())
            }
            // Full payload: ship the row over the wire to a survivor
            // with a free slot. Transport escalation (budget exhausted)
            // falls back to the charged re-prefill path; a full cluster
            // re-queues the intact state uncharged.
            EvacKind::Extracted => {
                if let Some(w) = self.pick_adopt_worker() {
                    match self.transfer(w, &e.payload) {
                        Ok(p) => {
                            let adopted = Evacuee { payload: p, ..e.clone() };
                            if self.workers[w].adopt(&adopted).is_ok() {
                                self.metrics.evac_extracted += 1;
                                self.metrics.migrations_out[from] += 1;
                                self.metrics.migrations_in[w] += 1;
                                return Ok(());
                            }
                        }
                        Err(_) => {
                            let w2 = self
                                .least_loaded_alive()
                                .ok_or_else(|| anyhow!("no surviving worker"))?;
                            self.metrics.evac_salvaged += 1;
                            self.workers[w2].readmit(
                                e.payload.req,
                                e.prio,
                                e.arrival_s,
                                e.retries,
                                true,
                            );
                            return Ok(());
                        }
                    }
                }
                // no survivor has a free slot right now (or the adopt
                // refused): the extracted state is intact and replayable,
                // so it re-queues front-of-lane uncharged
                let w = self
                    .least_loaded_alive()
                    .ok_or_else(|| anyhow!("no surviving worker"))?;
                self.metrics.evac_requeued += 1;
                self.workers[w].readmit(e.payload.req, e.prio, e.arrival_s, e.retries, false);
                Ok(())
            }
        }
    }

    /// One frame over the wire to worker `to`: encode → (chaos) corrupt
    /// → decode, retried by [`RowTransport::deliver`] under exponential
    /// backoff within its budget. The destination engine's
    /// `corrupt_frame` hook models in-flight corruption.
    fn transfer(&mut self, to: usize, p: &MigrationPayload) -> Result<MigrationPayload> {
        let (transport, workers) = (&mut self.transport, &mut self.workers);
        let engine = workers[to].engine_mut();
        transport.deliver(p, &mut |mut f: Vec<u8>| {
            engine.corrupt_frame(&mut f);
            f
        })
    }

    /// Route global admissions: pop while some alive worker has genuine
    /// headroom (load strictly under slot capacity, so its local queue
    /// can never shed what the global queue would have held).
    fn route(&mut self) {
        loop {
            let Some(w) = self.pick_route_worker() else {
                break;
            };
            let Some(q) = self.queue.pop() else {
                break;
            };
            // arrival time is preserved: queue-wait latency measures
            // from the global enqueue, not the hop
            self.workers[w].enqueue(q.req, q.prio, q.enqueued_s);
        }
    }

    /// Least-loaded alive worker with headroom (load < slot capacity).
    fn pick_route_worker(&self) -> Option<usize> {
        (0..self.workers.len())
            .filter(|&w| self.health[w] != WorkerHealth::Dead)
            .filter(|&w| self.workers[w].load() < self.workers[w].slots.capacity())
            .min_by_key(|&w| self.workers[w].load())
    }

    /// Least-loaded alive worker, headroom or not.
    fn least_loaded_alive(&self) -> Option<usize> {
        (0..self.workers.len())
            .filter(|&w| self.health[w] != WorkerHealth::Dead)
            .min_by_key(|&w| self.workers[w].load())
    }

    /// Least-loaded alive worker with a free slot right now.
    fn pick_adopt_worker(&self) -> Option<usize> {
        (0..self.workers.len())
            .filter(|&w| self.health[w] != WorkerHealth::Dead)
            .filter(|&w| self.workers[w].slots.occupancy() < self.workers[w].slots.capacity())
            .min_by_key(|&w| self.workers[w].load())
    }

    /// Is (worker, slot) a side of a live cross-worker race or the
    /// staged fork?
    fn in_cross_race(&self, w: usize, s: usize) -> bool {
        self.races.iter().any(|r| r.src == (w, s) || r.dst == (w, s))
            || self.staged.as_ref().is_some_and(|f| f.src == (w, s))
    }

    /// A race side is valid while its worker is alive, the slot is live,
    /// and the slot still holds the raced request.
    fn side_valid(&self, w: usize, s: usize, id: u64) -> bool {
        self.health[w] != WorkerHealth::Dead
            && self.workers[w].slots.is_live(s)
            && self.workers[w].engine().request(s).map(|r| r.id) == Some(id)
    }

    /// Resolve cross-worker races: first finisher wins, the loser's slot
    /// is cancelled (identical tokens — the tape is keyed by (seed,
    /// request, position)). A side that left its slot (finished and
    /// retired, quarantined, or migrated) forfeits: the OTHER side is
    /// cancelled so exactly one copy of the request survives.
    fn resolve_races(&mut self) -> Result<()> {
        let races = std::mem::take(&mut self.races);
        for r in races {
            let sv = self.side_valid(r.src.0, r.src.1, r.id);
            let dv = self.side_valid(r.dst.0, r.dst.1, r.id);
            match (sv, dv) {
                (true, true) => {
                    let sd = self.workers[r.src.0].engine().is_done(r.src.1);
                    let dd = self.workers[r.dst.0].engine().is_done(r.dst.1);
                    if sd || dd {
                        // tie goes to the source (either copy is correct)
                        let (lw, ls) = if sd { r.dst } else { r.src };
                        if dd && !sd {
                            self.metrics.cross_race_wins += 1;
                        }
                        self.workers[lw].cancel_slot(ls)?;
                        self.metrics.cross_race_cancels += 1;
                    } else {
                        self.races.push(r);
                    }
                }
                (true, false) => {
                    self.workers[r.src.0].cancel_slot(r.src.1)?;
                    self.metrics.cross_race_cancels += 1;
                }
                (false, true) => {
                    self.workers[r.dst.0].cancel_slot(r.dst.1)?;
                    self.metrics.cross_race_cancels += 1;
                }
                (false, false) => {
                    self.metrics.cross_race_cancels += 1;
                }
            }
        }
        Ok(())
    }

    /// Stage a cross-worker race fork: snapshot the worst-acceptance
    /// straggler's payload, put the frame on the wire NOW, commit next
    /// tick — the source verifies one more round while the frame
    /// travels, exactly the overlap discipline `engine/overlap.rs` uses
    /// in-process. One race at a time; racing never displaces
    /// admissions (the destination must be idle-slotted with no
    /// backlog and the global queue empty).
    fn stage_race(&mut self) {
        if self.staged.is_some() || !self.races.is_empty() || !self.queue.is_empty() {
            return;
        }
        let Some(dst) = (0..self.workers.len())
            .filter(|&w| self.health[w] == WorkerHealth::Healthy)
            .filter(|&w| self.workers[w].queue.is_empty())
            .filter(|&w| self.workers[w].slots.occupancy() < self.workers[w].slots.capacity())
            .min_by_key(|&w| self.workers[w].load())
        else {
            return;
        };
        let mut cand: Option<(usize, usize, f64)> = None;
        for w in 0..self.workers.len() {
            if w == dst || self.health[w] == WorkerHealth::Dead {
                continue;
            }
            let b = &self.workers[w];
            let member = |s: usize| b.is_race_member(s) || self.in_cross_race(w, s);
            let Some(s) = cross_race_candidate(b.engine(), member, MIN_RACE_REMAINING) else {
                continue;
            };
            let rate = b.engine().request(s).map(|r| r.accept.rate()).unwrap_or(1.0);
            let better = match cand {
                None => true,
                Some((_, _, c)) => rate < c,
            };
            if better {
                cand = Some((w, s, rate));
            }
        }
        let Some((sw, ss, _)) = cand else {
            return;
        };
        let Some((prio, arrival_s)) = self.workers[sw].slot_meta(ss) else {
            return;
        };
        let Ok(p) = self.workers[sw].engine().snapshot_payload(ss) else {
            return;
        };
        let id = p.req.id;
        let frame = {
            let (transport, workers) = (&mut self.transport, &mut self.workers);
            transport.frames += 1;
            let mut f = transport.encode(&p);
            workers[dst].engine_mut().corrupt_frame(&mut f);
            f
        };
        self.staged = Some(StagedFork { frame, src: (sw, ss), dst, id, prio, arrival_s });
        self.metrics.cross_races += 1;
    }

    /// Commit (or roll back) the race frame staged last tick. Rollback
    /// cases: the source finished/moved/died while the frame travelled
    /// (stale stamp), the destination died or its slot was taken by an
    /// admission, or the frame arrived corrupt — the source still has
    /// everything, so a corrupt frame just counts a transport retry and
    /// the next stage re-snapshots (a re-transmission).
    fn commit_staged(&mut self) -> Result<()> {
        let Some(s) = self.staged.take() else {
            return Ok(());
        };
        if !self.side_valid(s.src.0, s.src.1, s.id)
            || self.workers[s.src.0].engine().is_done(s.src.1)
            || self.health[s.dst] == WorkerHealth::Dead
        {
            self.metrics.stage_rollbacks += 1;
            return Ok(());
        }
        let payload = match self.transport.decode(&s.frame) {
            Ok(p) => p,
            Err(_) => {
                self.transport.corruptions += 1;
                self.transport.retries += 1;
                self.metrics.stage_rollbacks += 1;
                return Ok(());
            }
        };
        let ev = Evacuee {
            payload,
            prio: s.prio,
            arrival_s: s.arrival_s,
            retries: 0,
            kind: EvacKind::Extracted,
        };
        match self.workers[s.dst].adopt(&ev) {
            Ok(rslot) => {
                self.races.push(CrossRace { id: s.id, src: s.src, dst: (s.dst, rslot) });
            }
            Err(_) => {
                // destination full (an admission won the slot): the
                // primary is untouched, the race just didn't launch
                self.metrics.stage_rollbacks += 1;
            }
        }
        Ok(())
    }

    /// Work-stealing balance: when a worker sits fully idle while
    /// another still holds two or more live slots, migrate ONE slot per
    /// tick through the transport path (a control-plane cost: one frame,
    /// one row insert).
    fn balance(&mut self) -> Result<()> {
        if !self.queue.is_empty() || self.workers.len() < 2 {
            return Ok(());
        }
        let Some(dw) = (0..self.workers.len())
            .filter(|&w| self.health[w] == WorkerHealth::Healthy)
            .find(|&w| self.workers[w].load() == 0)
        else {
            return Ok(());
        };
        let Some(sw) = (0..self.workers.len())
            .filter(|&w| w != dw && self.health[w] != WorkerHealth::Dead)
            .filter(|&w| self.workers[w].slots.occupancy() >= 2)
            .max_by_key(|&w| self.workers[w].slots.occupancy())
        else {
            return Ok(());
        };
        // steal the live slot with the most remaining work (it benefits
        // most from a dedicated worker), skipping race members
        let cap = self.workers[sw].slots.capacity();
        let mut pick: Option<(usize, usize)> = None;
        for s in 0..cap {
            if !self.workers[sw].slots.is_live(s)
                || self.workers[sw].engine().is_done(s)
                || self.workers[sw].is_race_member(s)
                || self.in_cross_race(sw, s)
            {
                continue;
            }
            let Some(r) = self.workers[sw].engine().request(s) else {
                continue;
            };
            let remaining = r.budget.saturating_sub(r.generated());
            if remaining == 0 {
                continue;
            }
            let better = match pick {
                None => true,
                Some((_, best)) => remaining > best,
            };
            if better {
                pick = Some((s, remaining));
            }
        }
        let Some((slot, _)) = pick else {
            return Ok(());
        };
        let Some(ev) = self.workers[sw].extract_slot(slot)? else {
            return Ok(());
        };
        match self.transfer(dw, &ev.payload) {
            Ok(p) => {
                let adopted = Evacuee { payload: p, ..ev.clone() };
                if self.workers[dw].adopt(&adopted).is_ok() {
                    self.metrics.migrations_out[sw] += 1;
                    self.metrics.migrations_in[dw] += 1;
                } else {
                    // destination refused: re-prefill there, uncharged
                    // (the extracted state is intact and replayable)
                    self.workers[dw].readmit(
                        ev.payload.req,
                        ev.prio,
                        ev.arrival_s,
                        ev.retries,
                        false,
                    );
                }
            }
            Err(_) => {
                // transport escalated past its budget (counted in the
                // transport ledger): charged re-prefill at the source's
                // side of the wire never happens — the state was already
                // extracted — so it re-prefills at the destination
                self.workers[dw].readmit(ev.payload.req, ev.prio, ev.arrival_s, ev.retries, true);
            }
        }
        Ok(())
    }

    /// Assemble the cluster scrape snapshot: cluster + transport series,
    /// per-worker health gauges, and the global queue's counters.
    pub fn collect_registry(&self) -> MetricRegistry {
        let mut reg = MetricRegistry::new();
        self.metrics.register(&mut reg, &self.transport, &self.health);
        self.queue.register_metrics(&mut reg);
        reg
    }

    /// Compact JSON rendering of the cluster counters (same numbers the
    /// scrape publishes).
    pub fn to_json(&self) -> String {
        self.metrics.to_json(&self.transport, &self.health)
    }
}

/// Drive a cluster through an open-loop arrival schedule — the
/// multi-worker sibling of [`drive_open_loop`]; same contract: arrivals
/// are (absolute seconds, request, priority) ascending by time, `dt`
/// fixes virtual time per tick (None = measured wall time).
///
/// [`drive_open_loop`]: super::batcher::drive_open_loop
pub fn drive_cluster_open_loop<E: ServeEngine>(
    c: &mut Cluster<E>,
    arrivals: Vec<(f64, Request, Priority)>,
    dt: Option<f64>,
) -> Result<OpenLoopReport> {
    if arrivals.windows(2).any(|w| w[1].0 < w[0].0) {
        bail!("arrivals must be sorted by time");
    }
    let mut rep = OpenLoopReport { offered: arrivals.len(), ..Default::default() };
    let rejected0 = c.rejected();
    let mut now = 0.0f64;
    let mut pending = arrivals.into_iter().peekable();
    loop {
        while pending.peek().map(|(t, _, _)| *t <= now).unwrap_or(false) {
            let (t, req, prio) = pending.next().unwrap();
            c.enqueue(req, prio, t);
        }
        if c.idle() {
            match pending.peek() {
                Some((t, _, _)) => {
                    now = *t;
                    continue;
                }
                None => break,
            }
        }
        let t0 = std::time::Instant::now();
        c.tick(now)?;
        rep.ticks += 1;
        now += dt.unwrap_or_else(|| t0.elapsed().as_secs_f64());
    }
    rep.elapsed_s = now;
    rep.rejected = (c.rejected() - rejected0) as usize;
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::engine::{EngineReport, SlotPlan};
    use crate::serve::batcher::{drive_open_loop, SyntheticEngine};
    use crate::serve::replan::Replanner;

    fn mk_batcher(cap: usize, seed: u64) -> Batcher<SyntheticEngine> {
        Batcher::new(SyntheticEngine::new(cap, seed), 64, Replanner::synthetic(), true)
    }

    fn arrivals(n: usize, budget: usize) -> Vec<(f64, Request, Priority)> {
        (0..n)
            .map(|i| {
                (i as f64 * 1e-3, Request::new(i as u64, vec![0; 8], budget), Priority::Batch)
            })
            .collect()
    }

    fn by_id(done: Vec<FinishedRequest>) -> Vec<(u64, Vec<i32>)> {
        let mut v: Vec<(u64, Vec<i32>)> =
            done.into_iter().map(|f| (f.req.id, f.req.seq.clone())).collect();
        v.sort_by_key(|x| x.0);
        v
    }

    #[test]
    fn cluster_tokens_match_single_worker() {
        let mut b = mk_batcher(4, 7);
        drive_open_loop(&mut b, arrivals(12, 16), Some(1e-3)).unwrap();
        let want = by_id(b.drain_finished());
        assert_eq!(want.len(), 12);

        let mut c = Cluster::new((0..3).map(|_| mk_batcher(4, 7)).collect(), 64);
        let rep = drive_cluster_open_loop(&mut c, arrivals(12, 16), Some(1e-3)).unwrap();
        assert_eq!(rep.rejected, 0);
        let got = by_id(c.drain_finished());
        assert_eq!(got, want);
        assert_eq!(c.metrics.completed, 12);
        assert_eq!(c.metrics.dup_completions, 0);
    }

    #[test]
    fn mid_wave_kill_is_lossless() {
        let mut b = mk_batcher(4, 7);
        drive_open_loop(&mut b, arrivals(12, 16), Some(1e-3)).unwrap();
        let want = by_id(b.drain_finished());

        let mut c = Cluster::new((0..3).map(|_| mk_batcher(4, 7)).collect(), 64);
        for (t, r, p) in arrivals(12, 16) {
            assert!(c.enqueue(r, p, t));
        }
        for _ in 0..3 {
            c.tick(0.0).unwrap();
        }
        c.kill_worker(0).unwrap();
        assert_eq!(c.health()[0], WorkerHealth::Dead);
        let mut guard = 0;
        while !c.idle() {
            c.tick(0.0).unwrap();
            guard += 1;
            assert!(guard < 10_000, "cluster failed to drain after a worker kill");
        }
        let got = by_id(c.drain_finished());
        assert_eq!(got, want, "a mid-wave worker kill must stay token-identical");
        assert_eq!(c.metrics.worker_deaths, 1);
        assert_eq!(c.rejected(), 0, "zero requests lost to the kill");
        // every evacuee left through exactly one typed path
        assert_eq!(
            c.metrics.evacuations[0],
            c.metrics.evac_extracted + c.metrics.evac_salvaged + c.metrics.evac_requeued
        );
    }

    #[test]
    fn last_survivor_is_held_not_killed() {
        let mut c = Cluster::new((0..2).map(|_| mk_batcher(2, 3)).collect(), 16);
        c.kill_worker(0).unwrap();
        assert!(c.kill_worker(1).is_err(), "direct kill of the last survivor must refuse");
        let e: anyhow::Error = SpecError::WorkerDead { worker: 1 }.into();
        c.on_worker_fatal(1, e, true).unwrap();
        assert_eq!(c.metrics.last_survivor_holds, 1);
        assert_eq!(c.health()[1], WorkerHealth::Suspect);
        assert_eq!(c.alive(), 1);
    }

    #[test]
    fn balance_steals_work_onto_an_idle_worker() {
        let mut b = mk_batcher(4, 7);
        drive_open_loop(&mut b, arrivals(6, 16), Some(1e-3)).unwrap();
        let want = by_id(b.drain_finished());

        let mut c = Cluster::new((0..2).map(|_| mk_batcher(4, 7)).collect(), 64);
        // load every request onto worker 0's local queue so worker 1
        // starts fully idle — the balancer must work-steal
        for (t, r, p) in arrivals(6, 16) {
            c.worker_mut(0).enqueue(r, p, t);
        }
        let mut guard = 0;
        while !c.idle() {
            c.tick(0.0).unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(c.metrics.migrations_in[1] > 0, "expected at least one stolen slot");
        assert_eq!(c.transport.frames, c.metrics.migrations_in[1]);
        assert_eq!(c.transport.corruptions, 0);
        let got = by_id(c.drain_finished());
        assert_eq!(got, want, "work-stealing migration must stay token-identical");
    }

    /// Replanner profiled so the ngram token drafter wins selection (the
    /// wave-global corpus seeds token drafters only, so this test needs
    /// the serve plans to actually carry one).
    fn ngram_replanner() -> crate::serve::replan::Replanner {
        Replanner::new(
            crate::planner::costmodel::CostModel::paper_32b(),
            vec![("ngram".to_string(), 0.90), ("draft_small".to_string(), 0.60)],
            vec![1, 2, 4],
            vec![1, 3, 7],
            7,
        )
    }

    #[test]
    fn cluster_shares_one_corpus_and_stays_lossless() {
        // reference: plain single worker, no corpus at all
        let mut b = mk_batcher(4, 7);
        drive_open_loop(&mut b, arrivals(12, 16), Some(1e-3)).unwrap();
        let want = by_id(b.drain_finished());
        assert_eq!(want.len(), 12);

        let mut master = DraftCorpus::new();
        master.add_segment(&want[0].1);
        assert!(master.publish() > 0, "pre-warming the master must fold tokens");
        let mk = || Batcher::new(SyntheticEngine::new(4, 7), 64, ngram_replanner(), true);
        let mut c = Cluster::new((0..3).map(|_| mk()).collect(), 64).with_corpus(master);
        let rep = drive_cluster_open_loop(&mut c, arrivals(12, 16), Some(1e-3)).unwrap();
        assert_eq!(rep.rejected, 0);
        let got = by_id(c.drain_finished());
        assert_eq!(got, want, "a shared warm corpus must stay token-identical");
        assert!(
            c.metrics.corpus_seeds > 0,
            "workers must seed token-drafter admissions from the shared snapshot"
        );
        assert!(
            c.metrics.corpus_publishes >= 2,
            "the pre-warm epoch plus at least one wave publish"
        );
        assert!(c.metrics.corpus_tokens > 0, "wave completions must fold into the master");
        assert_eq!(c.metrics.corpus_decays, 0);
        // epoch replication: every worker tap shares the master's handle,
        // so each observes the same (advanced) epoch lineage
        for w in 0..c.len() {
            let e = c.worker_mut(w).corpus_mut().unwrap().epoch();
            assert!(e >= 2, "worker {w} tap stuck at epoch {e}");
        }
    }

    /// A decay relayed from one worker must purge the whole tick's
    /// pre-decay harvest: completions drained from workers that never
    /// paused were accepted under the OLD weights and must not fold
    /// into the fresh post-decay lineage.
    #[test]
    fn relayed_decay_discards_predecay_harvest() {
        let mut master = DraftCorpus::new();
        master.add_segment(&[1, 2, 3, 1, 2, 3]);
        assert!(master.publish() > 0);
        let mk = || Batcher::new(SyntheticEngine::new(4, 7), 64, ngram_replanner(), true);
        let mut c = Cluster::new((0..2).map(|_| mk()).collect(), 64).with_corpus(master);
        // worker 0 harvested a completion under the old weights...
        c.worker_mut(0).corpus_mut().unwrap().add_segment(&[9, 9, 9, 9]);
        // ...and worker 1 saw the weight-update pause the same tick
        c.worker_mut(1).corpus_mut().unwrap().decay();
        c.tick(0.0).unwrap();
        assert_eq!(c.metrics.corpus_decays, 1, "tap decay must relay to the master");
        // no live slots → nothing reseeds: the master must come out
        // COLD, not warmed by the stale pre-decay completion
        assert_eq!(
            c.metrics.corpus_tokens, 0,
            "stale pre-decay harvest leaked past the relayed decay"
        );
        assert!(!c.worker_mut(0).corpus_mut().unwrap().is_warm());
        // epoch replication after decay: every tap reads the master's
        // lineage (pre-warm publish + decay epoch)
        for w in 0..c.len() {
            assert_eq!(c.worker_mut(w).corpus_mut().unwrap().epoch(), 2);
        }
    }

    #[test]
    fn cross_worker_race_is_lossless() {
        let mut b = mk_batcher(4, 7);
        drive_open_loop(&mut b, arrivals(4, 24), Some(1e-3)).unwrap();
        let want = by_id(b.drain_finished());

        let mut c = Cluster::new((0..2).map(|_| mk_batcher(4, 7)).collect(), 64)
            .with_cross_racing();
        // park everything on worker 0: worker 1 stays an idle race host
        for (t, r, p) in arrivals(4, 24) {
            c.worker_mut(0).enqueue(r, p, t);
        }
        let mut guard = 0;
        while !c.idle() {
            c.tick(0.0).unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        let got = by_id(c.drain_finished());
        assert_eq!(got, want, "cross-worker racing must stay token-identical");
        assert_eq!(c.metrics.completed, 4);
        assert_eq!(c.metrics.dup_completions, 0);
        // id 3 is the synthetic tail straggler: with an idle remote
        // worker and an empty queue at least one fork must have staged
        // (work-stealing may still beat racing to the idle slot)
        assert!(
            c.metrics.cross_races + c.metrics.migrations_in[1] > 0,
            "neither a race nor a steal reached the idle worker"
        );
    }

    /// Minimal engine whose slots stop making progress on demand — the
    /// heartbeat supervisor's quarry.
    struct StallEngine {
        slots: Vec<Option<Request>>,
        stalled: bool,
    }

    impl StallEngine {
        fn new(cap: usize, stalled: bool) -> Self {
            StallEngine { slots: (0..cap).map(|_| None).collect(), stalled }
        }
    }

    impl ServeEngine for StallEngine {
        fn capacity(&self) -> usize {
            self.slots.len()
        }

        fn admit(&mut self, slot: usize, req: Request, _plan: SlotPlan) -> Result<()> {
            if self.slots[slot].is_some() {
                bail!("slot {slot} occupied");
            }
            self.slots[slot] = Some(req);
            Ok(())
        }

        fn retire(&mut self, slot: usize) -> Result<Request> {
            self.slots[slot].take().ok_or_else(|| anyhow!("slot {slot} empty"))
        }

        fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
            let stalled = self.stalled;
            let mut active = 0;
            for r in self.slots.iter_mut().flatten() {
                if r.done {
                    continue;
                }
                active += 1;
                if stalled {
                    continue;
                }
                let t = (r.id as i32).wrapping_mul(31).wrapping_add(r.seq.len() as i32) & 0x7fff;
                r.seq.push(t);
                rep.total_generated += 1;
                if r.generated() >= r.budget {
                    r.done = true;
                }
            }
            Ok(active)
        }

        fn is_done(&self, slot: usize) -> bool {
            self.slots.get(slot).and_then(|s| s.as_ref()).map(|r| r.done).unwrap_or(false)
        }

        fn slot_plan(&self, _slot: usize) -> Option<SlotPlan> {
            Some(SlotPlan::vanilla())
        }

        fn set_slot_plan(&mut self, _slot: usize, _plan: SlotPlan) -> Result<()> {
            Ok(())
        }

        fn request(&self, slot: usize) -> Option<&Request> {
            self.slots.get(slot).and_then(|s| s.as_ref())
        }
    }

    #[test]
    fn heartbeat_lapse_declares_death_and_relocates_the_request() {
        let mk = |stalled| {
            // tracing on: the death must leave a flight-recorder dump
            Batcher::new(StallEngine::new(2, stalled), 16, Replanner::synthetic(), false)
                .with_tracing(64)
        };
        let mut c = Cluster::new(vec![mk(true), mk(false)], 16).with_heartbeat(3, 2);
        // worker 0 is less loaded at route time, so the request lands on
        // the staller and wedges there
        c.worker_mut(0).enqueue(Request::new(0, vec![0; 4], 8), Priority::Batch, 0.0);
        let mut guard = 0;
        while !c.idle() {
            c.tick(0.0).unwrap();
            guard += 1;
            assert!(guard < 1_000, "stalled request never relocated");
        }
        assert_eq!(c.health()[0], WorkerHealth::Dead);
        assert_eq!(c.metrics.worker_deaths, 1);
        assert!(c.metrics.heartbeat_misses[0] >= 5);
        let done = c.drain_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].req.id, 0);
        assert_eq!(done[0].req.seq.len(), 4 + 8);
        // the heartbeat death left a post-mortem in the flight recorder
        assert_eq!(c.workers()[0].fault_dumps.len(), 1);
        assert_eq!(c.rejected(), 0);
    }
}
