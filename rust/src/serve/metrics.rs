//! Rolling serve-loop telemetry: latency quantiles, throughput and
//! occupancy.
//!
//! Everything here is O(1) per event — latency percentiles come from the
//! fixed-state P² estimator ([`P2Quantile`]), occupancy and queue wait
//! from Welford accumulators — so telemetry never grows with the number
//! of requests served (a serving loop can't afford per-request sample
//! vectors).

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats::{P2Quantile, Welford};

/// Telemetry accumulated by the batcher.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    /// Requests admitted into slots.
    pub admitted: u64,
    /// Requests finished and retired.
    pub completed: u64,
    /// Tokens generated across all rounds.
    pub tokens: u64,
    /// Engine rounds executed (ticks with at least one active slot).
    pub rounds: u64,
    /// Plans applied by the replanner (bucket crossings, including the
    /// initial plan establishment) — the single replan counter.
    pub replans: u64,
    /// Queued requests rejected at admission because the engine cannot
    /// serve them at all (bad prompt geometry, oversized budget).
    pub invalid: u64,
    /// Algorithm 2 firings that rewrote at least one slot plan.
    pub reconfigs: u64,
    /// Individual slot plans rewritten by Algorithm 2.
    pub reconfigured_slots: u64,
    /// Fastest-of-N races started (Algorithm 3 in-process).
    pub races: u64,
    /// Racing replicas forked across all races.
    pub race_launches: u64,
    /// Races a replica finished strictly before the primary.
    pub race_wins: u64,
    /// Replica wins keyed by draft-method label (bounded by the ladder
    /// size, so the telemetry block stays O(1) in requests served).
    pub race_wins_by_method: BTreeMap<String, u64>,
    /// Replicas cancelled (race lost or preempted by admissions).
    pub race_cancelled_replicas: u64,
    /// Engine rounds spent by replicas that were then cancelled — the
    /// speculation waste racing pays for its tail-latency win.
    pub race_wasted_rounds: u64,
    /// Slots demoted to vanilla decode by a recovered `Degradable` fault
    /// (the degradation ladder — speculation lost, tokens preserved).
    pub degradations: u64,
    /// Degraded slots re-promoted to a speculative plan after their
    /// exponential backoff expired.
    pub repromotions: u64,
    /// Slots retired by a `SlotFatal` fault (KV row / request state
    /// untrustworthy in place).
    pub quarantines: u64,
    /// Quarantined requests re-enqueued at the front of their lane with
    /// verified output preserved (`quarantines - requeues` exhausted
    /// their retry budget and were rejected with a typed reason).
    pub requeues: u64,
    /// Quarantined requests successfully re-admitted via re-prefill.
    pub recoveries: u64,
    /// Requests that vanished without completing OR being rejected with
    /// a typed reason. Recovery guarantees this stays 0; the chaos bench
    /// and fault-tolerance tests assert it.
    pub lost: u64,
    queue_wait: Welford,
    latency_p50: P2Quantile,
    latency_p99: P2Quantile,
    latency_mean: Welford,
    occupancy: Welford,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            admitted: 0,
            completed: 0,
            tokens: 0,
            rounds: 0,
            replans: 0,
            invalid: 0,
            reconfigs: 0,
            reconfigured_slots: 0,
            races: 0,
            race_launches: 0,
            race_wins: 0,
            race_wins_by_method: BTreeMap::new(),
            race_cancelled_replicas: 0,
            race_wasted_rounds: 0,
            degradations: 0,
            repromotions: 0,
            quarantines: 0,
            requeues: 0,
            recoveries: 0,
            lost: 0,
            queue_wait: Welford::default(),
            latency_p50: P2Quantile::new(0.5),
            latency_p99: P2Quantile::new(0.99),
            latency_mean: Welford::default(),
            occupancy: Welford::default(),
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A request left the queue for a slot after waiting `wait_s`.
    pub fn on_admit(&mut self, wait_s: f64) {
        self.admitted += 1;
        self.queue_wait.add(wait_s.max(0.0));
    }

    /// A request finished `latency_s` after arrival. (Tokens are counted
    /// per-round by [`ServeMetrics::on_round`].)
    pub fn on_finish(&mut self, latency_s: f64) {
        self.completed += 1;
        let l = latency_s.max(0.0);
        self.latency_p50.add(l);
        self.latency_p99.add(l);
        self.latency_mean.add(l);
    }

    /// One engine round ran at `occupancy` live slots and generated
    /// `generated` tokens.
    pub fn on_round(&mut self, occupancy: usize, generated: u64) {
        self.rounds += 1;
        self.tokens += generated;
        self.occupancy.add(occupancy as f64);
    }

    /// One race launched with `replicas` forked replicas.
    pub fn on_race_launch(&mut self, replicas: usize) {
        self.races += 1;
        self.race_launches += replicas as u64;
    }

    /// A race resolved: `replica_won` with `winner_method`, cancelling
    /// `cancelled` replicas that had burned `wasted_rounds` rounds.
    pub fn on_race_finish(
        &mut self,
        replica_won: bool,
        winner_method: &str,
        cancelled: usize,
        wasted_rounds: u64,
    ) {
        if replica_won {
            self.race_wins += 1;
            *self
                .race_wins_by_method
                .entry(winner_method.to_string())
                .or_insert(0) += 1;
        }
        self.race_cancelled_replicas += cancelled as u64;
        self.race_wasted_rounds += wasted_rounds;
    }

    /// A race was preempted for admissions: `cancelled` replicas freed
    /// after `wasted_rounds` rounds.
    pub fn on_race_cancel(&mut self, cancelled: usize, wasted_rounds: u64) {
        self.race_cancelled_replicas += cancelled as u64;
        self.race_wasted_rounds += wasted_rounds;
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        self.queue_wait.mean()
    }

    pub fn latency_p50_s(&self) -> f64 {
        self.latency_p50.value()
    }

    pub fn latency_p99_s(&self) -> f64 {
        self.latency_p99.value()
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.latency_mean.mean()
    }

    /// Round-weighted mean live batch size.
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    /// Sustained throughput over `wall_s` seconds of serving.
    pub fn tokens_per_second(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.tokens as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Machine-readable snapshot (BENCH_serve.json rows, demo output).
    pub fn to_json(&self, wall_s: f64) -> Json {
        Json::obj(vec![
            ("admitted", Json::num(self.admitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("replans", Json::num(self.replans as f64)),
            ("invalid", Json::num(self.invalid as f64)),
            ("reconfigs", Json::num(self.reconfigs as f64)),
            ("reconfigured_slots", Json::num(self.reconfigured_slots as f64)),
            ("races", Json::num(self.races as f64)),
            ("race_launches", Json::num(self.race_launches as f64)),
            ("race_wins", Json::num(self.race_wins as f64)),
            (
                "race_wins_by_method",
                Json::Obj(
                    self.race_wins_by_method
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            ("race_cancelled_replicas", Json::num(self.race_cancelled_replicas as f64)),
            ("race_wasted_rounds", Json::num(self.race_wasted_rounds as f64)),
            ("degradations", Json::num(self.degradations as f64)),
            ("repromotions", Json::num(self.repromotions as f64)),
            ("quarantines", Json::num(self.quarantines as f64)),
            ("requeues", Json::num(self.requeues as f64)),
            ("recoveries", Json::num(self.recoveries as f64)),
            ("lost", Json::num(self.lost as f64)),
            ("tokens_per_s", Json::num(self.tokens_per_second(wall_s))),
            ("mean_queue_wait_s", Json::num(self.mean_queue_wait_s())),
            ("latency_p50_s", Json::num(self.latency_p50_s())),
            ("latency_p99_s", Json::num(self.latency_p99_s())),
            ("mean_latency_s", Json::num(self.mean_latency_s())),
            ("mean_occupancy", Json::num(self.mean_occupancy())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = ServeMetrics::new();
        m.on_admit(0.1);
        m.on_admit(0.3);
        m.on_round(2, 5);
        m.on_round(1, 2);
        m.on_finish(1.0);
        assert_eq!(m.admitted, 2);
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens, 7);
        assert_eq!(m.rounds, 2);
        assert!((m.mean_queue_wait_s() - 0.2).abs() < 1e-12);
        assert!((m.mean_occupancy() - 1.5).abs() < 1e-12);
        assert!((m.tokens_per_second(7.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_quantiles_ordered() {
        let mut m = ServeMetrics::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..2000 {
            m.on_finish(rng.lognormal(-1.0, 0.7));
        }
        assert!(m.latency_p99_s() >= m.latency_p50_s());
        assert!(m.mean_latency_s() > 0.0);
    }

    #[test]
    fn json_snapshot_has_headline_fields() {
        let mut m = ServeMetrics::new();
        m.on_round(3, 12);
        let j = m.to_json(2.0);
        assert_eq!(j.get("tokens").as_f64(), Some(12.0));
        assert_eq!(j.get("tokens_per_s").as_f64(), Some(6.0));
        assert_eq!(j.get("mean_occupancy").as_f64(), Some(3.0));
    }

    #[test]
    fn race_counters_accumulate() {
        let mut m = ServeMetrics::new();
        m.on_race_launch(2);
        m.on_race_launch(1);
        m.on_race_finish(true, "sam", 1, 7);
        m.on_race_finish(false, "ngram", 1, 3);
        m.on_race_cancel(1, 2);
        assert_eq!(m.races, 2);
        assert_eq!(m.race_launches, 3);
        assert_eq!(m.race_wins, 1);
        assert_eq!(m.race_wins_by_method.get("sam"), Some(&1));
        assert_eq!(m.race_wins_by_method.get("ngram"), None, "losing methods score nothing");
        assert_eq!(m.race_cancelled_replicas, 3);
        assert_eq!(m.race_wasted_rounds, 12);
        let j = m.to_json(1.0);
        assert_eq!(j.get("race_wins").as_f64(), Some(1.0));
        assert_eq!(j.get("race_wins_by_method").get("sam").as_f64(), Some(1.0));
    }

    #[test]
    fn fault_counters_in_json_snapshot() {
        let mut m = ServeMetrics::new();
        m.degradations = 3;
        m.repromotions = 2;
        m.quarantines = 1;
        m.requeues = 1;
        m.recoveries = 1;
        let j = m.to_json(1.0);
        assert_eq!(j.get("degradations").as_f64(), Some(3.0));
        assert_eq!(j.get("repromotions").as_f64(), Some(2.0));
        assert_eq!(j.get("quarantines").as_f64(), Some(1.0));
        assert_eq!(j.get("requeues").as_f64(), Some(1.0));
        assert_eq!(j.get("recoveries").as_f64(), Some(1.0));
        assert_eq!(j.get("lost").as_f64(), Some(0.0));
    }

    #[test]
    fn negative_times_clamped() {
        let mut m = ServeMetrics::new();
        m.on_admit(-0.5);
        m.on_finish(-1.0);
        assert_eq!(m.mean_queue_wait_s(), 0.0);
        assert_eq!(m.latency_p50_s(), 0.0);
    }
}
