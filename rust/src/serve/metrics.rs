//! Rolling serve-loop telemetry: latency quantiles, throughput and
//! occupancy.
//!
//! Everything here is O(1) per event — latency percentiles come from the
//! fixed-state P² estimator ([`P2Quantile`]), occupancy and queue wait
//! from Welford accumulators — so telemetry never grows with the number
//! of requests served (a serving loop can't afford per-request sample
//! vectors).

use std::collections::BTreeMap;

use crate::drafter::corpus::CorpusStats;
use crate::obs::MetricRegistry;
use crate::util::json::Json;
use crate::util::stats::{P2Quantile, Welford};

/// Prometheus family-name prefix for every serve-telemetry series: the
/// scrape name of a `to_json` field `k` is `specactor_serve_<k>`, so the
/// two snapshots reconcile mechanically (asserted field-for-field by
/// `rust/tests/observability.rs`).
pub const PROM_PREFIX: &str = "specactor_serve_";

/// Telemetry accumulated by the batcher.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    /// Requests admitted into slots.
    pub admitted: u64,
    /// Requests finished and retired.
    pub completed: u64,
    /// Tokens generated across all rounds.
    pub tokens: u64,
    /// Engine rounds executed (ticks with at least one active slot).
    pub rounds: u64,
    /// Plans applied by the replanner (bucket crossings, including the
    /// initial plan establishment) — the single replan counter.
    pub replans: u64,
    /// Queued requests rejected at admission because the engine cannot
    /// serve them at all (bad prompt geometry, oversized budget).
    pub invalid: u64,
    /// Algorithm 2 firings that rewrote at least one slot plan.
    pub reconfigs: u64,
    /// Individual slot plans rewritten by Algorithm 2.
    pub reconfigured_slots: u64,
    /// Fastest-of-N races started (Algorithm 3 in-process).
    pub races: u64,
    /// Racing replicas forked across all races.
    pub race_launches: u64,
    /// Races a replica finished strictly before the primary.
    pub race_wins: u64,
    /// Races that ran to resolution (a member finished; replica or
    /// primary). Together with [`ServeMetrics::race_preemptions`] this
    /// reconciles the ledger: `races == race_resolutions +
    /// race_preemptions`, and primary wins are `race_resolutions -
    /// race_wins` — the "losses" the summary used to leave implicit.
    pub race_resolutions: u64,
    /// Races cancelled before resolution (admissions preempting replica
    /// slots). These used to bump only `race_cancelled_replicas`, leaving
    /// `races != wins + losses`.
    pub race_preemptions: u64,
    /// Replica wins keyed by draft-method label (bounded by the ladder
    /// size, so the telemetry block stays O(1) in requests served).
    pub race_wins_by_method: BTreeMap<String, u64>,
    /// Replicas cancelled (race lost or preempted by admissions).
    pub race_cancelled_replicas: u64,
    /// Engine rounds spent by replicas that were then cancelled — the
    /// speculation waste racing pays for its tail-latency win.
    pub race_wasted_rounds: u64,
    /// Slots demoted to vanilla decode by a recovered `Degradable` fault
    /// (the degradation ladder — speculation lost, tokens preserved).
    pub degradations: u64,
    /// Degraded slots re-promoted to a speculative plan after their
    /// exponential backoff expired.
    pub repromotions: u64,
    /// Slots retired by a `SlotFatal` fault (KV row / request state
    /// untrustworthy in place).
    pub quarantines: u64,
    /// Quarantined requests re-enqueued at the front of their lane with
    /// verified output preserved (`quarantines - requeues` exhausted
    /// their retry budget and were rejected with a typed reason).
    pub requeues: u64,
    /// Quarantined requests successfully re-admitted via re-prefill.
    pub recoveries: u64,
    /// Requests that vanished without completing OR being rejected with
    /// a typed reason. Recovery guarantees this stays 0; the chaos bench
    /// and fault-tolerance tests assert it.
    pub lost: u64,
    /// Overlapped-round prefetched draft chunks consumed in place of a
    /// serialized in-round draft — the rounds whose draft time the
    /// overlap engine hid behind the previous fused verify step.
    pub prefetch_hits: u64,
    /// Prefetch mirrors rolled back because the full-accept prediction
    /// mis-speculated (a partial accept landed instead).
    pub prefetch_rollbacks: u64,
    /// Tokens drafted, keyed by the drafting slot's plan-method label
    /// (window-0 slots count under "vanilla" with 0 drafted). Algorithm 2
    /// keys off per-method acceptance; these make it visible outside the
    /// engine. Bounded by ladder size, like `race_wins_by_method`.
    pub method_drafted: BTreeMap<String, u64>,
    /// Tokens accepted per plan-method label (see `method_drafted`).
    pub method_accepted: BTreeMap<String, u64>,
    /// Wave-global draft corpus: tokens indexed by the latest published
    /// snapshot (mirrored each tick from `drafter::corpus::CorpusStats`,
    /// like the prefetch counters mirror `EngineReport`).
    pub corpus_tokens: u64,
    /// Admissions whose token drafters were seeded from a warm snapshot.
    pub corpus_seeds: u64,
    /// Corpus snapshot epochs published (decay epochs included).
    pub corpus_publishes: u64,
    /// Corpus segments evicted by the retention cap.
    pub corpus_evictions: u64,
    /// Weight-update corpus decays (wave resets).
    pub corpus_decays: u64,
    queue_wait: Welford,
    latency_p50: P2Quantile,
    latency_p99: P2Quantile,
    latency_mean: Welford,
    occupancy: Welford,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            admitted: 0,
            completed: 0,
            tokens: 0,
            rounds: 0,
            replans: 0,
            invalid: 0,
            reconfigs: 0,
            reconfigured_slots: 0,
            races: 0,
            race_launches: 0,
            race_wins: 0,
            race_resolutions: 0,
            race_preemptions: 0,
            race_wins_by_method: BTreeMap::new(),
            race_cancelled_replicas: 0,
            race_wasted_rounds: 0,
            degradations: 0,
            repromotions: 0,
            quarantines: 0,
            requeues: 0,
            recoveries: 0,
            lost: 0,
            prefetch_hits: 0,
            prefetch_rollbacks: 0,
            method_drafted: BTreeMap::new(),
            method_accepted: BTreeMap::new(),
            corpus_tokens: 0,
            corpus_seeds: 0,
            corpus_publishes: 0,
            corpus_evictions: 0,
            corpus_decays: 0,
            queue_wait: Welford::default(),
            latency_p50: P2Quantile::new(0.5),
            latency_p99: P2Quantile::new(0.99),
            latency_mean: Welford::default(),
            occupancy: Welford::default(),
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A request left the queue for a slot after waiting `wait_s`.
    pub fn on_admit(&mut self, wait_s: f64) {
        self.admitted += 1;
        self.queue_wait.add(wait_s.max(0.0));
    }

    /// A request finished `latency_s` after arrival. (Tokens are counted
    /// per-round by [`ServeMetrics::on_round`].)
    pub fn on_finish(&mut self, latency_s: f64) {
        self.completed += 1;
        let l = latency_s.max(0.0);
        self.latency_p50.add(l);
        self.latency_p99.add(l);
        self.latency_mean.add(l);
    }

    /// One engine round ran at `occupancy` live slots and generated
    /// `generated` tokens.
    pub fn on_round(&mut self, occupancy: usize, generated: u64) {
        self.rounds += 1;
        self.tokens += generated;
        self.occupancy.add(occupancy as f64);
    }

    /// One race launched with `replicas` forked replicas.
    pub fn on_race_launch(&mut self, replicas: usize) {
        self.races += 1;
        self.race_launches += replicas as u64;
    }

    /// A race resolved: `replica_won` with `winner_method`, cancelling
    /// `cancelled` replicas that had burned `wasted_rounds` rounds.
    pub fn on_race_finish(
        &mut self,
        replica_won: bool,
        winner_method: &str,
        cancelled: usize,
        wasted_rounds: u64,
    ) {
        self.race_resolutions += 1;
        if replica_won {
            self.race_wins += 1;
            *self
                .race_wins_by_method
                .entry(winner_method.to_string())
                .or_insert(0) += 1;
        }
        self.race_cancelled_replicas += cancelled as u64;
        self.race_wasted_rounds += wasted_rounds;
    }

    /// A race was preempted for admissions: `cancelled` replicas freed
    /// after `wasted_rounds` rounds.
    pub fn on_race_cancel(&mut self, cancelled: usize, wasted_rounds: u64) {
        self.race_preemptions += 1;
        self.race_cancelled_replicas += cancelled as u64;
        self.race_wasted_rounds += wasted_rounds;
    }

    /// One round drafted `drafted` and accepted `accepted` tokens on a
    /// slot whose plan carries `method` — the per-method acceptance feed
    /// (the batcher attributes `EngineReport.per_slot` deltas here).
    pub fn on_method_tokens(&mut self, method: &str, drafted: u64, accepted: u64) {
        if drafted == 0 && accepted == 0 {
            return;
        }
        *self.method_drafted.entry(method.to_string()).or_insert(0) += drafted;
        *self.method_accepted.entry(method.to_string()).or_insert(0) += accepted;
    }

    /// Mirror the wave-global corpus telemetry into the serve snapshot
    /// (assignment, not accumulation — `CorpusStats` is itself monotone).
    pub fn set_corpus_stats(&mut self, s: &CorpusStats) {
        self.corpus_tokens = s.tokens;
        self.corpus_seeds = s.seeds;
        self.corpus_publishes = s.publishes;
        self.corpus_evictions = s.evictions;
        self.corpus_decays = s.decays;
    }

    /// Measured acceptance per method, `(method, accepted/drafted)`.
    pub fn method_acceptance(&self) -> Vec<(String, f64, u64, u64)> {
        self.method_drafted
            .iter()
            .map(|(m, &d)| {
                let a = self.method_accepted.get(m).copied().unwrap_or(0);
                let rate = if d > 0 { a as f64 / d as f64 } else { 0.0 };
                (m.clone(), rate, a, d)
            })
            .collect()
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        self.queue_wait.mean()
    }

    pub fn latency_p50_s(&self) -> f64 {
        self.latency_p50.value()
    }

    pub fn latency_p99_s(&self) -> f64 {
        self.latency_p99.value()
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.latency_mean.mean()
    }

    /// Round-weighted mean live batch size.
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    /// Sustained throughput over `wall_s` seconds of serving.
    pub fn tokens_per_second(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.tokens as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Monotone (counter-typed) series — the single enumeration both
    /// [`ServeMetrics::to_json`] and [`ServeMetrics::register`] render
    /// from, so the JSON summary and the `/metrics` scrape cannot drift.
    fn counter_series(&self) -> [(&'static str, u64); 28] {
        [
            ("admitted", self.admitted),
            ("completed", self.completed),
            ("tokens", self.tokens),
            ("rounds", self.rounds),
            ("replans", self.replans),
            ("invalid", self.invalid),
            ("reconfigs", self.reconfigs),
            ("reconfigured_slots", self.reconfigured_slots),
            ("races", self.races),
            ("race_launches", self.race_launches),
            ("race_wins", self.race_wins),
            ("race_resolutions", self.race_resolutions),
            ("race_preemptions", self.race_preemptions),
            ("race_cancelled_replicas", self.race_cancelled_replicas),
            ("race_wasted_rounds", self.race_wasted_rounds),
            ("degradations", self.degradations),
            ("repromotions", self.repromotions),
            ("quarantines", self.quarantines),
            ("requeues", self.requeues),
            ("recoveries", self.recoveries),
            ("lost", self.lost),
            ("prefetch_hits", self.prefetch_hits),
            ("prefetch_rollbacks", self.prefetch_rollbacks),
            ("corpus_tokens", self.corpus_tokens),
            ("corpus_seeds", self.corpus_seeds),
            ("corpus_publishes", self.corpus_publishes),
            ("corpus_evictions", self.corpus_evictions),
            ("corpus_decays", self.corpus_decays),
        ]
    }

    /// Derived point-in-time (gauge-typed) series; same sharing rule as
    /// [`ServeMetrics::counter_series`].
    fn gauge_series(&self, wall_s: f64) -> [(&'static str, f64); 6] {
        [
            ("tokens_per_s", self.tokens_per_second(wall_s)),
            ("mean_queue_wait_s", self.mean_queue_wait_s()),
            ("latency_p50_s", self.latency_p50_s()),
            ("latency_p99_s", self.latency_p99_s()),
            ("mean_latency_s", self.mean_latency_s()),
            ("mean_occupancy", self.mean_occupancy()),
        ]
    }

    /// Labeled (per-method) counter maps; shared like the series above.
    fn map_series(&self) -> [(&'static str, &BTreeMap<String, u64>); 3] {
        [
            ("race_wins_by_method", &self.race_wins_by_method),
            ("method_drafted", &self.method_drafted),
            ("method_accepted", &self.method_accepted),
        ]
    }

    /// Labeled (per-method) gauge maps — measured acceptance rates, the
    /// numbers the replanner/Reconfigurator priors are fed from; shared
    /// between renderers like every other series.
    fn rate_map_series(&self) -> [(&'static str, Vec<(String, f64)>); 1] {
        [(
            "method_acceptance_rate",
            self.method_acceptance().into_iter().map(|(m, rate, _, _)| (m, rate)).collect(),
        )]
    }

    /// Machine-readable snapshot (BENCH_serve.json rows, demo output).
    /// Rendered from the same series lists as [`ServeMetrics::register`].
    pub fn to_json(&self, wall_s: f64) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        for (k, v) in self.counter_series() {
            fields.push((k, Json::num(v as f64)));
        }
        for (k, map) in self.map_series() {
            fields.push((
                k,
                Json::Obj(map.iter().map(|(m, v)| (m.clone(), Json::num(*v as f64))).collect()),
            ));
        }
        for (k, map) in self.rate_map_series() {
            fields.push((
                k,
                Json::Obj(map.into_iter().map(|(m, v)| (m, Json::num(v))).collect()),
            ));
        }
        for (k, v) in self.gauge_series(wall_s) {
            fields.push((k, Json::num(v)));
        }
        Json::obj(fields)
    }

    /// Register every serve-telemetry series into a scrape snapshot under
    /// [`PROM_PREFIX`] — the other renderer of the shared series lists.
    pub fn register(&self, reg: &mut MetricRegistry, wall_s: f64) {
        for (k, v) in self.counter_series() {
            reg.counter(&format!("{PROM_PREFIX}{k}"), serve_help(k), v as f64);
        }
        for (k, map) in self.map_series() {
            let name = format!("{PROM_PREFIX}{k}");
            for (method, v) in map {
                reg.counter_l(&name, serve_help(k), &[("method", method)], *v as f64);
            }
        }
        for (k, map) in self.rate_map_series() {
            let name = format!("{PROM_PREFIX}{k}");
            for (method, v) in &map {
                reg.gauge_l(&name, serve_help(k), &[("method", method)], *v);
            }
        }
        for (k, v) in self.gauge_series(wall_s) {
            reg.gauge(&format!("{PROM_PREFIX}{k}"), serve_help(k), v);
        }
    }
}

/// HELP text per serve series (keys of the shared series lists).
fn serve_help(k: &str) -> &'static str {
    match k {
        "admitted" => "Requests admitted into slots",
        "completed" => "Requests finished and retired",
        "tokens" => "Tokens generated across all rounds",
        "rounds" => "Engine rounds executed",
        "replans" => "Plans applied by the occupancy-bucket replanner",
        "invalid" => "Requests rejected as unservable at admission",
        "reconfigs" => "Algorithm 2 firings that rewrote at least one slot plan",
        "reconfigured_slots" => "Individual slot plans rewritten by Algorithm 2",
        "races" => "Fastest-of-N races started",
        "race_launches" => "Racing replicas forked across all races",
        "race_wins" => "Races a replica finished strictly before the primary",
        "race_resolutions" => "Races that ran to resolution (replica or primary finished)",
        "race_preemptions" => "Races cancelled before resolution by admissions",
        "race_cancelled_replicas" => "Replicas cancelled (race lost or preempted)",
        "race_wasted_rounds" => "Replica rounds spent by cancelled replicas",
        "degradations" => "Slots demoted to vanilla by a Degradable fault",
        "repromotions" => "Degraded slots re-promoted after backoff",
        "quarantines" => "Slots retired by a SlotFatal fault",
        "requeues" => "Quarantined requests re-enqueued front-of-lane",
        "recoveries" => "Quarantined requests re-admitted via re-prefill",
        "lost" => "Requests lost without completion or typed rejection",
        "prefetch_hits" => "Rounds served from a prefetched draft chunk",
        "prefetch_rollbacks" => "Prefetch mirrors rolled back on mis-speculation",
        "corpus_tokens" => "Corpus tokens indexed by the latest published snapshot",
        "corpus_seeds" => "Admissions seeded from a warm corpus snapshot",
        "corpus_publishes" => "Corpus snapshot epochs published",
        "corpus_evictions" => "Corpus segments evicted by the retention cap",
        "corpus_decays" => "Weight-update corpus decays",
        "method_acceptance_rate" => "Measured acceptance rate per plan method",
        "race_wins_by_method" => "Replica wins per draft method",
        "method_drafted" => "Tokens drafted per plan method",
        "method_accepted" => "Tokens accepted per plan method",
        "tokens_per_s" => "Sustained generation throughput",
        "mean_queue_wait_s" => "Mean admission-queue wait",
        "latency_p50_s" => "Request latency p50 (P2 estimator)",
        "latency_p99_s" => "Request latency p99 (P2 estimator)",
        "mean_latency_s" => "Mean request latency",
        "mean_occupancy" => "Round-weighted mean live batch size",
        _ => "Serve telemetry",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = ServeMetrics::new();
        m.on_admit(0.1);
        m.on_admit(0.3);
        m.on_round(2, 5);
        m.on_round(1, 2);
        m.on_finish(1.0);
        assert_eq!(m.admitted, 2);
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens, 7);
        assert_eq!(m.rounds, 2);
        assert!((m.mean_queue_wait_s() - 0.2).abs() < 1e-12);
        assert!((m.mean_occupancy() - 1.5).abs() < 1e-12);
        assert!((m.tokens_per_second(7.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_quantiles_ordered() {
        let mut m = ServeMetrics::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..2000 {
            m.on_finish(rng.lognormal(-1.0, 0.7));
        }
        assert!(m.latency_p99_s() >= m.latency_p50_s());
        assert!(m.mean_latency_s() > 0.0);
    }

    #[test]
    fn json_snapshot_has_headline_fields() {
        let mut m = ServeMetrics::new();
        m.on_round(3, 12);
        let j = m.to_json(2.0);
        assert_eq!(j.get("tokens").as_f64(), Some(12.0));
        assert_eq!(j.get("tokens_per_s").as_f64(), Some(6.0));
        assert_eq!(j.get("mean_occupancy").as_f64(), Some(3.0));
    }

    #[test]
    fn race_counters_accumulate() {
        let mut m = ServeMetrics::new();
        m.on_race_launch(2);
        m.on_race_launch(1);
        m.on_race_finish(true, "sam", 1, 7);
        m.on_race_finish(false, "ngram", 1, 3);
        m.on_race_cancel(1, 2);
        assert_eq!(m.races, 2);
        assert_eq!(m.race_launches, 3);
        assert_eq!(m.race_wins, 1);
        assert_eq!(m.race_wins_by_method.get("sam"), Some(&1));
        assert_eq!(m.race_wins_by_method.get("ngram"), None, "losing methods score nothing");
        assert_eq!(m.race_cancelled_replicas, 3);
        assert_eq!(m.race_wasted_rounds, 12);
        // ledger reconciliation: every started race either resolved or
        // was preempted — no third way out
        assert_eq!(m.race_resolutions, 2);
        assert_eq!(m.race_preemptions, 1);
        m.on_race_launch(1); // the preempted race
        assert_eq!(m.races, m.race_resolutions + m.race_preemptions);
        let j = m.to_json(1.0);
        assert_eq!(j.get("race_wins").as_f64(), Some(1.0));
        assert_eq!(j.get("race_wins_by_method").get("sam").as_f64(), Some(1.0));
        assert_eq!(j.get("race_resolutions").as_f64(), Some(2.0));
        assert_eq!(j.get("race_preemptions").as_f64(), Some(1.0));
    }

    #[test]
    fn per_method_acceptance_accumulates() {
        let mut m = ServeMetrics::new();
        m.on_method_tokens("sam", 10, 8);
        m.on_method_tokens("sam", 10, 6);
        m.on_method_tokens("ngram", 5, 1);
        m.on_method_tokens("vanilla", 0, 0); // no-op: nothing drafted
        let acc = m.method_acceptance();
        assert_eq!(acc.len(), 2);
        let sam = acc.iter().find(|(name, ..)| name == "sam").unwrap();
        assert!((sam.1 - 0.7).abs() < 1e-12);
        assert_eq!((sam.2, sam.3), (14, 20));
        let j = m.to_json(1.0);
        assert_eq!(j.get("method_drafted").get("sam").as_f64(), Some(20.0));
        assert_eq!(j.get("method_accepted").get("ngram").as_f64(), Some(1.0));
    }

    #[test]
    fn registry_snapshot_matches_json_snapshot() {
        let mut m = ServeMetrics::new();
        m.on_admit(0.1);
        m.on_round(2, 9);
        m.on_finish(0.5);
        m.on_race_launch(2);
        m.on_race_finish(true, "sam", 1, 4);
        m.on_method_tokens("sam", 12, 7);
        let mut reg = MetricRegistry::new();
        m.register(&mut reg, 3.0);
        let j = m.to_json(3.0);
        for (k, v) in j.as_obj().unwrap() {
            let name = format!("{PROM_PREFIX}{k}");
            match v {
                Json::Num(n) => assert_eq!(reg.find(&name, &[]), Some(*n), "series {name}"),
                Json::Obj(o) => {
                    for (method, mv) in o {
                        assert_eq!(
                            reg.find(&name, &[("method", method)]),
                            mv.as_f64(),
                            "series {name}{{method={method}}}"
                        );
                    }
                }
                other => panic!("unexpected to_json field type for {k}: {other:?}"),
            }
        }
    }

    #[test]
    fn fault_counters_in_json_snapshot() {
        let mut m = ServeMetrics::new();
        m.degradations = 3;
        m.repromotions = 2;
        m.quarantines = 1;
        m.requeues = 1;
        m.recoveries = 1;
        let j = m.to_json(1.0);
        assert_eq!(j.get("degradations").as_f64(), Some(3.0));
        assert_eq!(j.get("repromotions").as_f64(), Some(2.0));
        assert_eq!(j.get("quarantines").as_f64(), Some(1.0));
        assert_eq!(j.get("requeues").as_f64(), Some(1.0));
        assert_eq!(j.get("recoveries").as_f64(), Some(1.0));
        assert_eq!(j.get("lost").as_f64(), Some(0.0));
    }

    #[test]
    fn prefetch_counters_in_json_snapshot() {
        let mut m = ServeMetrics::new();
        m.prefetch_hits = 9;
        m.prefetch_rollbacks = 2;
        let j = m.to_json(1.0);
        assert_eq!(j.get("prefetch_hits").as_f64(), Some(9.0));
        assert_eq!(j.get("prefetch_rollbacks").as_f64(), Some(2.0));
        // and through the registry renderer under the shared prefix
        let mut reg = MetricRegistry::new();
        m.register(&mut reg, 1.0);
        assert_eq!(reg.find("specactor_serve_prefetch_hits", &[]), Some(9.0));
        assert_eq!(reg.find("specactor_serve_prefetch_rollbacks", &[]), Some(2.0));
    }

    #[test]
    fn corpus_counters_and_rate_gauges_in_both_renderers() {
        let mut m = ServeMetrics::new();
        m.set_corpus_stats(&CorpusStats {
            tokens: 640,
            seeds: 5,
            publishes: 3,
            evictions: 1,
            decays: 2,
        });
        m.on_method_tokens("sam", 20, 15);
        let j = m.to_json(1.0);
        assert_eq!(j.get("corpus_tokens").as_f64(), Some(640.0));
        assert_eq!(j.get("corpus_seeds").as_f64(), Some(5.0));
        assert_eq!(j.get("corpus_publishes").as_f64(), Some(3.0));
        assert_eq!(j.get("corpus_evictions").as_f64(), Some(1.0));
        assert_eq!(j.get("corpus_decays").as_f64(), Some(2.0));
        assert_eq!(j.get("method_acceptance_rate").get("sam").as_f64(), Some(0.75));
        let mut reg = MetricRegistry::new();
        m.register(&mut reg, 1.0);
        assert_eq!(reg.find("specactor_serve_corpus_tokens", &[]), Some(640.0));
        assert_eq!(reg.find("specactor_serve_corpus_decays", &[]), Some(2.0));
        assert_eq!(
            reg.find("specactor_serve_method_acceptance_rate", &[("method", "sam")]),
            Some(0.75)
        );
    }

    #[test]
    fn negative_times_clamped() {
        let mut m = ServeMetrics::new();
        m.on_admit(-0.5);
        m.on_finish(-1.0);
        assert_eq!(m.mean_queue_wait_s(), 0.0);
        assert_eq!(m.latency_p50_s(), 0.0);
    }
}
