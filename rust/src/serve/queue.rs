//! Admission queue: bounded, priority-classed request intake with
//! backpressure.
//!
//! Three priority lanes drain strictly in order (interactive > batch >
//! background), FIFO within a lane. The queue is bounded: when full, a
//! newly arriving request either evicts the most recently queued entry of
//! a *strictly lower* priority class (so a burst of background work can
//! never lock out interactive traffic) or is rejected outright —
//! backpressure the open-loop driver surfaces to the caller instead of
//! letting queue wait grow without bound.

use std::collections::VecDeque;

use crate::engine::Request;
use crate::obs::MetricRegistry;

/// Admission priority class, highest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive traffic (drained first).
    Interactive,
    /// Normal rollout work.
    Batch,
    /// Best-effort filler (first to be shed under pressure).
    Background,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::Background];

    fn lane(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }
}

/// One queued request plus its admission metadata.
#[derive(Clone, Debug)]
pub struct Queued {
    pub req: Request,
    pub prio: Priority,
    /// Arrival time (caller clock, seconds) — queue wait is measured from
    /// here when the batcher admits the request.
    pub enqueued_s: f64,
}

/// Why a request was turned away — the typed split of the `rejected`
/// total (`rejected == rejected_shed + rejected_retry_exhausted`;
/// malformed requests are counted separately by `ServeMetrics::invalid`
/// because they are rejected at admission, after leaving the queue).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Backpressure: queue full, shed outright or evicted by a
    /// higher-priority arrival.
    Shed,
    /// The engine cannot serve the request at all (bad prompt geometry,
    /// oversized budget).
    Malformed,
    /// The quarantine retry budget ran out (fault recovery gave up).
    RetryExhausted,
}

/// Bounded multi-lane admission queue.
#[derive(Debug)]
pub struct AdmissionQueue {
    cap: usize,
    lanes: [VecDeque<Queued>; 3],
    /// Requests turned away — total across all typed reasons below.
    pub rejected: u64,
    /// `rejected` from backpressure (shed outright or evicted).
    pub rejected_shed: u64,
    /// `rejected` because the quarantine retry budget was exhausted.
    pub rejected_retry_exhausted: u64,
    /// Requests ever accepted into the queue.
    pub enqueued: u64,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        AdmissionQueue {
            cap,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            rejected: 0,
            rejected_shed: 0,
            rejected_retry_exhausted: 0,
            enqueued: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Waiting requests in `prio`'s lane.
    pub fn depth(&self, prio: Priority) -> usize {
        self.lanes[prio.lane()].len()
    }

    /// Offer a request. Returns `true` if it was queued; `false` when
    /// backpressure rejected it (queue full and nothing lower-priority to
    /// shed). Eviction counts the shed request as rejected.
    pub fn push(&mut self, req: Request, prio: Priority, now_s: f64) -> bool {
        if self.len() >= self.cap {
            // shed the *newest* entry of the lowest lane strictly below us
            let victim = (prio.lane() + 1..3).rev().find(|&l| !self.lanes[l].is_empty());
            match victim {
                Some(l) => {
                    self.lanes[l].pop_back();
                    self.note_reject(RejectReason::Shed);
                }
                None => {
                    self.note_reject(RejectReason::Shed);
                    return false;
                }
            }
        }
        self.lanes[prio.lane()].push_back(Queued { req, prio, enqueued_s: now_s });
        self.enqueued += 1;
        true
    }

    /// Re-enqueue a quarantined request at the FRONT of its original
    /// lane, bypassing the capacity check: the request was already
    /// admitted once (it holds verified output tokens), so fault
    /// recovery must never lose it to backpressure. The momentary
    /// over-capacity drains on the next shed. Does not count as a fresh
    /// `enqueued` — the request was offered exactly once.
    pub fn requeue_front(&mut self, req: Request, prio: Priority, enqueued_s: f64) {
        self.lanes[prio.lane()].push_front(Queued { req, prio, enqueued_s });
    }

    /// Record a typed rejection (quarantine gave up, backpressure shed).
    /// `Malformed` is tracked by `ServeMetrics::invalid`, not here —
    /// those requests already left the queue when validation rejected
    /// them, so counting them again would double-book the
    /// `completed + rejected + invalid == offered` reconciliation.
    pub fn note_reject(&mut self, reason: RejectReason) {
        match reason {
            RejectReason::Shed => {
                self.rejected += 1;
                self.rejected_shed += 1;
            }
            RejectReason::RetryExhausted => {
                self.rejected += 1;
                self.rejected_retry_exhausted += 1;
            }
            RejectReason::Malformed => {}
        }
    }

    /// Next request to admit: highest-priority non-empty lane, FIFO.
    pub fn pop(&mut self) -> Option<Queued> {
        self.lanes.iter_mut().find_map(|l| l.pop_front())
    }

    /// Register the rejection ledger and live lane depths into a scrape
    /// snapshot (`specactor_queue_*`).
    pub fn register_metrics(&self, reg: &mut MetricRegistry) {
        reg.counter(
            "specactor_queue_enqueued",
            "Requests accepted into the admission queue",
            self.enqueued as f64,
        );
        let rej = "specactor_queue_rejected";
        let help = "Requests turned away, by typed reason";
        reg.counter_l(rej, help, &[("reason", "shed")], self.rejected_shed as f64);
        reg.counter_l(
            rej,
            help,
            &[("reason", "retry_exhausted")],
            self.rejected_retry_exhausted as f64,
        );
        reg.gauge(
            "specactor_queue_capacity",
            "Admission queue bound",
            self.cap as f64,
        );
        for prio in Priority::ALL {
            let lane = match prio {
                Priority::Interactive => "interactive",
                Priority::Batch => "batch",
                Priority::Background => "background",
            };
            reg.gauge_l(
                "specactor_queue_depth",
                "Waiting requests per priority lane",
                &[("lane", lane)],
                self.depth(prio) as f64,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0; 4], 8)
    }

    #[test]
    fn drains_by_priority_then_fifo() {
        let mut q = AdmissionQueue::new(8);
        assert!(q.push(req(1), Priority::Batch, 0.0));
        assert!(q.push(req(2), Priority::Background, 0.1));
        assert!(q.push(req(3), Priority::Interactive, 0.2));
        assert!(q.push(req(4), Priority::Batch, 0.3));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|x| x.req.id).collect();
        assert_eq!(order, vec![3, 1, 4, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.push(req(1), Priority::Batch, 0.0));
        assert!(q.push(req(2), Priority::Batch, 0.0));
        // same priority, nothing lower to shed -> rejected
        assert!(!q.push(req(3), Priority::Batch, 0.0));
        assert_eq!(q.rejected, 1);
        assert_eq!(q.len(), 2);
        // higher priority than everything queued also can't exceed cap
        // without a victim... batch IS lower than interactive: evicts
        assert!(q.push(req(4), Priority::Interactive, 0.0));
        assert_eq!(q.rejected, 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().req.id, 4);
    }

    #[test]
    fn eviction_sheds_newest_lowest_lane() {
        let mut q = AdmissionQueue::new(3);
        q.push(req(1), Priority::Batch, 0.0);
        q.push(req(2), Priority::Background, 0.0);
        q.push(req(3), Priority::Background, 0.0);
        // full; interactive arrival sheds background id=3 (newest, lowest)
        assert!(q.push(req(4), Priority::Interactive, 0.0));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|x| x.req.id).collect();
        assert_eq!(order, vec![4, 1, 2]);
    }

    #[test]
    fn interactive_never_evicted_by_lower_classes() {
        let mut q = AdmissionQueue::new(1);
        q.push(req(1), Priority::Interactive, 0.0);
        assert!(!q.push(req(2), Priority::Background, 0.0));
        assert!(!q.push(req(3), Priority::Interactive, 0.0)); // equal class: no shed
        assert_eq!(q.pop().unwrap().req.id, 1);
    }

    #[test]
    fn requeue_front_jumps_its_lane_and_bypasses_capacity() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.push(req(1), Priority::Batch, 0.0));
        assert!(q.push(req(2), Priority::Batch, 0.1));
        // full — an ordinary push would shed, a quarantine requeue won't
        q.requeue_front(req(3), Priority::Batch, 0.05);
        assert_eq!(q.len(), 3);
        assert_eq!(q.rejected, 0, "requeue must never count as backpressure");
        assert_eq!(q.enqueued, 2, "requeue is not a fresh offer");
        // front of its lane, but still behind higher-priority traffic
        assert!(q.push(req(4), Priority::Interactive, 0.2));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|x| x.req.id).collect();
        assert_eq!(order, vec![4, 3, 1, 2]);
    }

    #[test]
    fn typed_rejection_reasons_split_the_total() {
        let mut q = AdmissionQueue::new(1);
        assert!(q.push(req(1), Priority::Batch, 0.0));
        assert!(!q.push(req(2), Priority::Batch, 0.1)); // shed
        q.note_reject(RejectReason::RetryExhausted);
        q.note_reject(RejectReason::Malformed); // tracked elsewhere: no-op
        assert_eq!(q.rejected_shed, 1);
        assert_eq!(q.rejected_retry_exhausted, 1);
        assert_eq!(q.rejected, q.rejected_shed + q.rejected_retry_exhausted);
    }

    #[test]
    fn counters_and_depths() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1), Priority::Batch, 0.5);
        q.push(req(2), Priority::Batch, 0.6);
        q.push(req(3), Priority::Background, 0.7);
        assert_eq!(q.enqueued, 3);
        assert_eq!(q.depth(Priority::Batch), 2);
        assert_eq!(q.depth(Priority::Background), 1);
        assert_eq!(q.depth(Priority::Interactive), 0);
        let first = q.pop().unwrap();
        assert_eq!(first.enqueued_s, 0.5);
        assert_eq!(first.prio, Priority::Batch);
    }

    #[test]
    fn registry_snapshot_carries_the_typed_split_and_depths() {
        let mut q = AdmissionQueue::new(1);
        q.push(req(1), Priority::Batch, 0.0);
        q.push(req(2), Priority::Batch, 0.1); // shed
        q.note_reject(RejectReason::RetryExhausted);
        let mut reg = MetricRegistry::new();
        q.register_metrics(&mut reg);
        assert_eq!(reg.find("specactor_queue_rejected", &[("reason", "shed")]), Some(1.0));
        assert_eq!(
            reg.find("specactor_queue_rejected", &[("reason", "retry_exhausted")]),
            Some(1.0)
        );
        assert_eq!(reg.find("specactor_queue_depth", &[("lane", "batch")]), Some(1.0));
        assert_eq!(reg.find("specactor_queue_enqueued", &[]), Some(1.0));
    }
}
