//! Continuous-batching serve loop.
//!
//! Each [`Batcher::tick`] is one serving round:
//!
//! 1. **retire** finished requests (free their KV slots, record latency),
//! 2. **replan** for the occupancy the pending admissions will produce
//!    ([`Replanner`], bucket-granular): on a crossing, a grouped-verify
//!    engine resets every live slot to the fresh common plan (β per plan
//!    group), while the default fused engine leaves live slots' plans
//!    standing — heterogeneity costs it nothing,
//! 3. **admit** waiting requests from the [`AdmissionQueue`] into free
//!    slots (prefill-join via `Worker::admit_with_plan` — the replanner's
//!    ladder-selected method and window are **applied** to the new slot,
//!    so a burst that causes the crossing is admitted directly on the
//!    crossing plan),
//! 4. **race** (Algorithm 3, optional): resolve finished Fastest-of-N
//!    races (first member wins; losers cancelled; losslessness asserted),
//!    preempt replicas when admissions need their slots, and — when the
//!    queue is empty and occupancy sits below the [`RaceArbiter`]'s
//!    threshold — fork the worst below-mean straggler into idle slots
//!    under the next-best draft methods (launches priced by
//!    `race::race_gain`: fork cost + extra fused verify rows vs expected
//!    rounds saved),
//! 5. run one engine **round** over the live slots under their per-slot
//!    plans (one fused ragged verify step — or one step per
//!    `(method, window)` group on grouped engines), and
//! 6. **reconfigure** (Algorithm 2, optional): every `period` rounds the
//!    [`Reconfigurator`] re-derives window/mode for slots whose measured
//!    acceptance fell below the live average and the new [`SlotPlan`]s are
//!    hot-swapped in place (race members excluded — the arbiter owns
//!    them).
//!
//! The batcher is generic over a [`ServeEngine`] so the loop's admission /
//! retirement / replanning / reconfiguration / telemetry logic is
//! unit-testable without AOT artifacts: the real backend is [`Worker`],
//! and [`SyntheticEngine`] is a deterministic stand-in used by those tests
//! and `specactor serve --smoke` (CI runs it artifact-free).
//!
//! Time is injected by the caller (`now_s`), never read from a wall
//! clock here — the open-loop drivers pass measured wall time for real
//! serving and a fixed virtual step for deterministic tests, and the
//! lossless test (`rust/tests/serve_lossless.rs`) replays identical
//! admission schedules under both static and continuous batching.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::race::RaceArbiter;
use crate::coordinator::reconfig::{LiveSlot, Reconfigurator};
use crate::drafter::corpus::{CorpusHandle, DraftCorpus};
use crate::drafter::DraftMethod;
use crate::engine::{
    same_group, EngineReport, PlanMode, Request, Severity, SlotAccept, SlotPlan, SpecError,
    VerifyDiscipline, Worker,
};
use crate::obs::{FaultDump, MetricRegistry, MetricsExporter, Phase, Tracer};
use crate::runtime::MigrationPayload;
use crate::util::rng::position_rng;

use super::metrics::ServeMetrics;
use super::queue::{AdmissionQueue, Priority, RejectReason};
use super::replan::Replanner;
use super::slots::SlotAllocator;

/// The engine surface the serve loop drives. Implemented by the real
/// [`Worker`] and by [`SyntheticEngine`].
pub trait ServeEngine {
    /// Number of batch slots.
    fn capacity(&self) -> usize;
    /// Is `req` admissible at all (prompt geometry, budget)? The batcher
    /// screens queued requests with this and *rejects* failures
    /// individually — only `admit`/`round` errors (infrastructure
    /// failures) abort the serve loop.
    fn validate(&self, _req: &Request) -> Result<()> {
        Ok(())
    }
    /// Prefill-join `req` into the free slot `slot` under `plan`.
    fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()>;
    /// Remove the (finished) request from `slot`, freeing it.
    fn retire(&mut self, slot: usize) -> Result<Request>;
    /// One decode round over active slots, driven by their slot plans.
    /// Returns the active-slot count.
    fn round(&mut self, rep: &mut EngineReport) -> Result<usize>;
    /// Did the request in `slot` finish? (false for empty slots)
    fn is_done(&self, slot: usize) -> bool;
    /// The plan the slot currently runs under (None for out-of-range).
    fn slot_plan(&self, slot: usize) -> Option<SlotPlan>;
    /// Hot-swap the slot's plan (replanning / Algorithm 2).
    fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()>;
    /// The verify discipline rounds run under. Fused engines pay the
    /// verify intercept once per round whatever the plan mix, so the
    /// serve loop lets heterogeneous per-slot plans stand at bucket
    /// crossings; grouped engines get the pre-fusion reset-to-common-plan
    /// behaviour (each extra plan group costs β again).
    fn verify_discipline(&self) -> VerifyDiscipline {
        VerifyDiscipline::Fused
    }
    /// Read access to the request occupying `slot` — the race arbiter's
    /// window into acceptance rates, remaining budget and generated
    /// tokens. Engines that return `None` simply never race.
    fn request(&self, _slot: usize) -> Option<&Request> {
        None
    }
    /// Fork the live request in `src` into the free slot `dst` under
    /// `plan` (a Fastest-of-N racing replica sharing `src`'s verified
    /// prefix — `Worker::fork`). Engines without forking support error,
    /// which the arbiter treats as "cannot race here".
    fn fork(&mut self, _src: usize, _dst: usize, _plan: SlotPlan) -> Result<()> {
        bail!("engine does not support replica forking")
    }
    /// Weight-update invalidation hook: the policy weights changed
    /// mid-wave, so every draft-side cache (draft-model KV rows, token
    /// drafter indices) is stale and must be rebuilt from the verified
    /// sequences before the next round. Target-side state is the new
    /// weights' problem, not this hook's. Lossless by construction —
    /// drafts only *propose*; verification decides every token. Default
    /// no-op for engines without draft-side state.
    fn invalidate_draft_state(&mut self) -> Result<()> {
        Ok(())
    }
    /// Install a shared wave-global draft-corpus handle
    /// ([`crate::drafter::corpus`]): engines with token drafters seed
    /// every new admission's drafter from the latest published snapshot.
    /// Default no-op for engines without draft-side state.
    fn set_corpus(&mut self, _h: CorpusHandle) {}
    /// Cumulative weight-update invalidations absorbed
    /// ([`ServeEngine::invalidate_draft_state`] calls). The batcher polls
    /// the delta at round boundaries to trigger corpus decay; engines
    /// without the hook report 0 forever.
    fn invalidations(&self) -> u64 {
        0
    }
    /// Install a per-phase span recorder: subsequent rounds emit
    /// Draft/Verify/Apply (and KV-copy) spans into the shared flight
    /// recorder. Default no-op for engines without instrumentation.
    fn attach_tracer(&mut self, _t: Tracer) {}
    /// Contribute engine-side series (runtime copy/execute ledger, chaos
    /// injection counters, ...) to a scrape snapshot. Default no-op.
    fn collect_metrics(&self, _reg: &mut MetricRegistry) {}
    /// Extract the slot's full migration payload for cross-worker
    /// transport — the request plus, where the engine owns one, its
    /// verified-prefix KV row — freeing the slot (the cross-runtime
    /// sibling of [`ServeEngine::retire`]). Default: retire only, no
    /// row; the destination re-materializes state through admission's
    /// prefill + catch-up replay (byte-identical, just slower).
    fn extract_payload(&mut self, slot: usize) -> Result<MigrationPayload> {
        Ok(MigrationPayload::new(self.retire(slot)?))
    }
    /// Snapshot the live slot's migration payload WITHOUT freeing it —
    /// the cross-worker race-fork path: the source keeps verifying while
    /// the staged copy travels (stamp/rollback, the `engine/overlap.rs`
    /// discipline at cluster scale). Default: clone the request, ship no
    /// row.
    fn snapshot_payload(&self, slot: usize) -> Result<MigrationPayload> {
        let req = self
            .request(slot)
            .cloned()
            .ok_or_else(|| anyhow!("slot {slot} empty (payload snapshot)"))?;
        Ok(MigrationPayload::new(req))
    }
    /// Install a migrated payload into the free slot `slot` — the
    /// inverse of [`ServeEngine::extract_payload`]. Default: ordinary
    /// admission (the prefill + catch-up replay rebuilds the row from
    /// the verified sequence; engines with row support insert directly).
    fn insert_payload(&mut self, slot: usize, p: MigrationPayload, plan: SlotPlan) -> Result<()> {
        self.admit(slot, p.req, plan)
    }
    /// Chaos hook: possibly mangle an outbound migration frame in flight
    /// (returns true when the frame was corrupted). The identity wire in
    /// production; a seeded Bernoulli bit-flipper under
    /// `--chaos transport=p`.
    fn corrupt_frame(&mut self, _frame: &mut [u8]) -> bool {
        false
    }
}

impl ServeEngine for Worker<'_> {
    fn capacity(&self) -> usize {
        self.bucket()
    }

    fn validate(&self, req: &Request) -> Result<()> {
        self.validate_request(req)
    }

    fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
        Worker::admit_with_plan(self, slot, req, plan)
    }

    fn retire(&mut self, slot: usize) -> Result<Request> {
        Worker::retire(self, slot)
    }

    fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
        Worker::round(self, rep)
    }

    fn is_done(&self, slot: usize) -> bool {
        Worker::is_done(self, slot)
    }

    fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
        Worker::plan(self, slot).cloned()
    }

    fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
        Worker::set_plan(self, slot, plan)
    }

    fn verify_discipline(&self) -> VerifyDiscipline {
        self.cfg.verify
    }

    fn request(&self, slot: usize) -> Option<&Request> {
        Worker::request(self, slot)
    }

    fn fork(&mut self, src: usize, dst: usize, plan: SlotPlan) -> Result<()> {
        Worker::fork(self, src, dst, plan)
    }

    fn invalidate_draft_state(&mut self) -> Result<()> {
        Worker::invalidate_draft_state(self)
    }

    fn set_corpus(&mut self, h: CorpusHandle) {
        Worker::set_corpus(self, h)
    }

    fn invalidations(&self) -> u64 {
        Worker::invalidation_count(self)
    }

    fn attach_tracer(&mut self, t: Tracer) {
        Worker::set_tracer(self, t)
    }

    fn collect_metrics(&self, reg: &mut MetricRegistry) {
        self.rt.stats.snapshot().register_metrics(reg);
    }

    fn extract_payload(&mut self, slot: usize) -> Result<MigrationPayload> {
        // Row first (non-destructive) so an extract failure leaves the
        // slot intact for the caller's salvage path.
        let row = Worker::migration_row(self, slot)?;
        Ok(MigrationPayload { req: Worker::retire(self, slot)?, row: Some(row) })
    }

    fn snapshot_payload(&self, slot: usize) -> Result<MigrationPayload> {
        let req = Worker::request(self, slot)
            .cloned()
            .ok_or_else(|| anyhow!("slot {slot} empty (payload snapshot)"))?;
        Ok(MigrationPayload { row: Some(Worker::migration_row(self, slot)?), req })
    }

    fn insert_payload(&mut self, slot: usize, p: MigrationPayload, plan: SlotPlan) -> Result<()> {
        match p.row {
            Some(row) => Worker::admit_with_row(self, slot, p.req, plan, &row),
            // row-less payload (source salvaged request state only):
            // rebuild through the ordinary prefill + catch-up replay
            None => Worker::admit_with_plan(self, slot, p.req, plan),
        }
    }
}

/// A retired request plus its serving timeline.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub req: Request,
    /// Arrival (enqueue) time.
    pub arrival_s: f64,
    /// Tick time at which the request was retired.
    pub finished_s: f64,
}

/// Per-tick outcome summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct TickReport {
    pub retired: usize,
    pub admitted: usize,
    /// Slots that ran in this tick's engine round.
    pub active: usize,
    pub generated: u64,
    pub replanned: bool,
    /// Slots Algorithm 2 rewrote this tick.
    pub reconfigured: usize,
    /// Racing replicas Algorithm 3 forked this tick.
    pub raced: usize,
}

/// The continuous-batching loop state.
pub struct Batcher<E: ServeEngine> {
    engine: E,
    pub queue: AdmissionQueue,
    pub slots: SlotAllocator,
    pub replan: Replanner,
    pub metrics: ServeMetrics,
    /// Cumulative engine counters across all rounds.
    pub report: EngineReport,
    /// Request-level reconfiguration (Algorithm 2), fired every
    /// `period` rounds when present.
    pub reconfig: Option<Reconfigurator>,
    /// In-process Fastest-of-N racing (Algorithm 3, `--fon-race`): tail
    /// stragglers are forked into idle slots and raced under other draft
    /// methods; the first finisher wins, admissions preempt replicas.
    pub race: Option<RaceArbiter>,
    /// Wave-global draft corpus (PERF.md §Online draft learning):
    /// finished requests' verified tokens are harvested here and
    /// published to the engine's drafters as immutable snapshots at
    /// round boundaries. `None` = feature off.
    corpus: Option<DraftCorpus>,
    /// Engine invalidation count at the last corpus roundup — the
    /// weight-update edge detector that triggers corpus decay and prior
    /// re-widening at the drained round boundary.
    seen_invalidations: u64,
    /// Per-method `(accepted, drafted)` counters at the last prior
    /// reset: measured-acceptance feedback is computed as deltas against
    /// this base, so a decayed wave re-measures from scratch instead of
    /// dragging pre-update evidence along.
    prior_base: BTreeMap<String, (u64, u64)>,
    /// Per-slot arrival timestamp of the occupying request.
    arrival_s: Vec<f64>,
    /// Per-slot priority class of the occupying request (quarantined
    /// requests requeue at the front of their ORIGINAL lane).
    prio_s: Vec<Priority>,
    /// Degradation-ladder state: consecutive `Degradable` faults the
    /// slot's occupant has absorbed (resets on admit/retire) and the tick
    /// after which a degraded slot may retry speculation (None = not
    /// degraded). Exponential backoff: 2, 4, 8, ... ticks.
    degrade_attempts: Vec<u32>,
    degrade_until: Vec<Option<u64>>,
    /// Quarantine retry counts per request id (entries cleared on
    /// completion; a retired-for-quarantine request keeps its entry so
    /// repeat faults walk toward the budget).
    retries: BTreeMap<u64, u32>,
    /// Quarantine retry budget per request: one admission + this many
    /// re-admissions, then the request is rejected with a typed reason.
    pub retry_budget: u32,
    /// Ticks seen — the degradation ladder's backoff clock.
    ticks: u64,
    finished: Vec<FinishedRequest>,
    /// Run speculative rounds (false = vanilla decode every round).
    spec: bool,
    /// Overlapped tick order (`--overlap`): the engine round runs FIRST
    /// each tick, and admissions / replanning / race launches run after
    /// it — off the decode critical path, hidden behind the step the
    /// overlapped worker already has in flight. Token outputs are
    /// identical either way (the sampling tape is keyed by (seed,
    /// request, position), never by tick phase order); only round
    /// scheduling shifts. Off by default — the sequential order is the
    /// A/B baseline and what the phase-order tests pin.
    overlap: bool,
    /// Per-phase span recorder, shared with the engine (None = off).
    tracer: Option<Tracer>,
    /// Prometheus scrape endpoint; the tick loop re-publishes a rendered
    /// snapshot periodically so scrapers never block serving.
    exporter: Option<MetricsExporter>,
    /// Flight-recorder post-mortems captured on engine-round faults
    /// (bounded; oldest dropped).
    pub fault_dumps: Vec<FaultDump>,
    /// Pre-round `report.per_slot` snapshot — the delta after the round
    /// is attributed to each slot's draft method (reused buffer).
    prev_per_slot: Vec<SlotAccept>,
    /// Optional real-time pacing sleep per tick (µs) so an external
    /// scraper can observe a smoke run mid-flight. Virtual serving time
    /// (`now_s`) is caller-injected and unaffected — determinism holds.
    pace_us: u64,
    /// The latest tick's `now_s`: the wall clock scrape-snapshot rates
    /// (tokens/s) are rendered against.
    last_now_s: f64,
}

/// Re-publish the scrape snapshot every this many ticks (when unpaced —
/// a paced run publishes every tick, it has real time to spend).
const PUBLISH_EVERY_TICKS: u64 = 16;

/// Fault dumps kept for the post-mortem trace (oldest dropped).
const MAX_FAULT_DUMPS: usize = 8;

/// Rounds of spans snapshotted into each fault dump.
const FAULT_DUMP_ROUNDS: u64 = 4;

impl<E: ServeEngine> Batcher<E> {
    pub fn new(engine: E, queue_cap: usize, replan: Replanner, spec: bool) -> Self {
        let cap = engine.capacity();
        // The engine's verify discipline is authoritative: align the
        // replanner here (and the reconfigurator in `with_reconfig`) so
        // a grouped engine always gets the snap-down planning its
        // β-per-group cost model needs, without callers having to
        // repeat the discipline in three places.
        let replan = replan.for_discipline(engine.verify_discipline());
        Batcher {
            queue: AdmissionQueue::new(queue_cap),
            slots: SlotAllocator::new(cap),
            replan,
            metrics: ServeMetrics::new(),
            report: EngineReport::default(),
            reconfig: None,
            race: None,
            corpus: None,
            seen_invalidations: 0,
            prior_base: BTreeMap::new(),
            arrival_s: vec![0.0; cap],
            prio_s: vec![Priority::Batch; cap],
            degrade_attempts: vec![0; cap],
            degrade_until: vec![None; cap],
            retries: BTreeMap::new(),
            retry_budget: 3,
            ticks: 0,
            finished: Vec::new(),
            spec,
            overlap: false,
            tracer: None,
            exporter: None,
            fault_dumps: Vec::new(),
            prev_per_slot: Vec::new(),
            pace_us: 0,
            last_now_s: 0.0,
            engine,
        }
    }

    /// Read access to the wrapped engine (e.g. to report a
    /// [`super::ChaosEngine`]'s injection counters after a run).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Enable request-level reconfiguration (Algorithm 2), aligned to the
    /// engine's verify discipline.
    pub fn with_reconfig(mut self, rc: Reconfigurator) -> Self {
        self.reconfig = Some(rc.for_discipline(self.engine.verify_discipline()));
        self
    }

    /// Enable in-process Fastest-of-N racing (Algorithm 3): the arbiter
    /// spends idle slots on tail races when occupancy is low and the
    /// priced launch gate passes; real admissions preempt replicas.
    pub fn with_racing(mut self, ar: RaceArbiter) -> Self {
        self.race = Some(ar);
        self
    }

    /// Attach a wave-global draft corpus (`--corpus`): every finished
    /// request's verified tokens are harvested into it, the pending
    /// harvest is folded into an immutable snapshot at round boundaries,
    /// and the engine (handed the shared [`CorpusHandle`] here) seeds
    /// new admissions' token drafters from the latest snapshot. Measured
    /// per-method acceptance feeds the replanner's and Reconfigurator's
    /// priors at the same boundaries; a weight-update invalidation
    /// decays the corpus and re-widens the priors.
    pub fn with_corpus(mut self, c: DraftCorpus) -> Self {
        self.install_corpus(c);
        self
    }

    /// Non-consuming [`Batcher::with_corpus`]: the cluster installs a
    /// tap of its master corpus on each already-built worker through
    /// this (the tap shares the master's snapshot handle, so one master
    /// publish is visible to every worker's engine at once).
    pub fn install_corpus(&mut self, c: DraftCorpus) {
        self.engine.set_corpus(c.handle());
        self.corpus = Some(c);
    }

    /// Serve in OVERLAPPED tick order: run the engine round before
    /// admissions / replanning / race launches so those bookkeeping
    /// phases hide behind the overlapped engine's in-flight step instead
    /// of stretching the decode critical path. Pair with
    /// `EngineConfig.overlap` on the worker for the full pipeline.
    pub fn with_overlap(mut self) -> Self {
        self.overlap = true;
        self
    }

    /// Enable per-phase round tracing into a flight recorder holding the
    /// most recent `capacity` spans; the recorder is shared with the
    /// engine (Draft/Verify/Apply/KV sub-spans) via `attach_tracer`.
    pub fn with_tracing(mut self, capacity: usize) -> Self {
        let t = Tracer::new(capacity);
        self.engine.attach_tracer(t.clone());
        self.tracer = Some(t);
        self
    }

    /// Attach a Prometheus scrape endpoint: the tick loop re-publishes a
    /// rendered [`MetricRegistry`] snapshot (every tick when paced, every
    /// [`PUBLISH_EVERY_TICKS`] otherwise) — scrapers read the snapshot,
    /// never the live loop.
    pub fn with_exporter(mut self, ex: MetricsExporter) -> Self {
        self.exporter = Some(ex);
        self
    }

    /// Sleep `pace_us` of real time after each tick (0 = off): stretches
    /// a smoke run so external scrapers can observe it mid-flight
    /// without touching the injected virtual clock.
    pub fn with_pace(mut self, pace_us: u64) -> Self {
        self.pace_us = pace_us;
        self
    }

    /// The installed span recorder, if tracing is on (the serve CLI
    /// exports its contents as a chrome://tracing JSON after the run).
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Offer a request to the admission queue (false = backpressure).
    pub fn enqueue(&mut self, req: Request, prio: Priority, now_s: f64) -> bool {
        self.queue.push(req, prio, now_s)
    }

    /// Nothing queued, nothing in flight.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.slots.occupancy() == 0
    }

    /// Completed requests retired so far (draining resets the list).
    pub fn drain_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    /// The slot plan the replanner's current decision maps to: the
    /// ladder-selected method and Algorithm 1 window, applied (not
    /// advised) on admission and at bucket crossings. Window 0 (no
    /// profitable speculative plan at this occupancy) and non-speculative
    /// batchers serve vanilla slots.
    fn current_plan(&self) -> SlotPlan {
        let p = &self.replan.plan;
        if !self.spec || p.window == 0 || p.method.is_empty() {
            SlotPlan::vanilla()
        } else {
            SlotPlan {
                method: DraftMethod::parse(&p.method),
                window: p.window,
                mode: PlanMode::Coupled,
            }
        }
    }

    /// One serving round: resolve races → retire → replan → admit →
    /// race-launch → decode → reconfigure. Publishes the scrape snapshot
    /// and applies the pacing sleep after the round — on faulted ticks
    /// too, so a scraper sees the failure counters, not a stale success.
    pub fn tick(&mut self, now_s: f64) -> Result<TickReport> {
        self.last_now_s = now_s;
        let res = if self.overlap {
            self.tick_inner_overlap(now_s)
        } else {
            self.tick_inner(now_s)
        };
        // corpus bookkeeping runs in the OUTER tick, after the inner
        // body: the inner paths early-return on zero occupancy, and the
        // tick that retires the last request must still publish its
        // harvest (and a faulted tick must still decay on a pause).
        self.corpus_roundup();
        if let Some(ex) = &self.exporter {
            if self.pace_us > 0 || self.ticks % PUBLISH_EVERY_TICKS == 1 {
                ex.publish(self.collect_registry(now_s).render());
            }
        }
        if self.pace_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.pace_us));
        }
        res
    }

    fn tick_inner(&mut self, now_s: f64) -> Result<TickReport> {
        let mut tr = TickReport::default();
        self.ticks += 1;
        let tracer = self.tracer.clone();
        if let Some(t) = &tracer {
            t.begin_round(self.ticks);
        }
        let mut mark = tracer.as_ref().map(|t| t.now_us());

        // 0. resolve finished races: the first member to finish wins, the
        //    losers are cancelled, and the winner retires as the race's
        //    single completion (losslessness is asserted inside resolve)
        if let Some(ar) = self.race.as_mut() {
            for fin in ar.resolve(&mut self.engine)? {
                for &s in &fin.freed {
                    self.slots.release(s)?;
                    self.reset_degrade(s);
                }
                self.retries.remove(&fin.req.id);
                let arrival = self.arrival_s[fin.primary];
                self.metrics.on_race_finish(
                    fin.replica_won,
                    &fin.winner_method,
                    fin.cancelled,
                    fin.wasted_rounds,
                );
                self.metrics.on_finish(now_s - arrival);
                if let Some(c) = self.corpus.as_mut() {
                    c.add_segment(&fin.req.seq);
                }
                self.finished.push(FinishedRequest {
                    req: fin.req,
                    arrival_s: arrival,
                    finished_s: now_s,
                });
                tr.retired += 1;
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Resolve, m, tr.retired as u32);
            mark = Some(t.now_us());
        }

        // 1. retire finished requests, freeing their slots (race members
        //    are the arbiter's to retire, never the plain path's)
        for slot in 0..self.engine.capacity() {
            if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                continue;
            }
            if self.slots.is_live(slot) && self.engine.is_done(slot) {
                let req = self.engine.retire(slot)?;
                self.slots.release(slot)?;
                self.reset_degrade(slot);
                self.retries.remove(&req.id);
                let arrival = self.arrival_s[slot];
                self.metrics.on_finish(now_s - arrival);
                // harvest the completed request's verified tokens into
                // the wave-global corpus (completion sites only — a
                // quarantined or migrating request continues elsewhere
                // and would double-count)
                if let Some(c) = self.corpus.as_mut() {
                    c.add_segment(&req.seq);
                }
                self.finished.push(FinishedRequest { req, arrival_s: arrival, finished_s: now_s });
                tr.retired += 1;
            }
        }

        // 1b. racing replicas yield to real work: while requests wait and
        //     no slot is free, cancel races (replica slots only — the
        //     primary keeps decoding) to make room for admissions
        if let Some(ar) = self.race.as_mut() {
            while !self.queue.is_empty() && self.slots.is_full() && ar.active_races() > 0 {
                let c = ar.cancel_one(&mut self.engine)?;
                for &s in &c.freed {
                    self.slots.release(s)?;
                }
                self.metrics.on_race_cancel(c.replicas, c.wasted_rounds);
            }
        }

        // 1c. degradation-ladder re-promotion: degraded slots whose
        //     backoff expired retry speculation under the current
        //     replanner plan; a repeat fault re-degrades them with a
        //     doubled backoff (capped), so a persistently broken drafter
        //     converges to near-permanent vanilla without ever being
        //     given up on.
        if self.spec {
            let plan = self.current_plan();
            for slot in 0..self.engine.capacity() {
                if !self.degrade_until[slot].is_some_and(|t| self.ticks >= t) {
                    continue;
                }
                self.degrade_until[slot] = None;
                if !self.slots.is_live(slot) || self.engine.is_done(slot) {
                    continue;
                }
                if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                    continue;
                }
                if plan.window > 0 {
                    self.engine.set_slot_plan(slot, plan.clone())?;
                    self.metrics.repromotions += 1;
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Retire, m, tr.retired as u32);
            mark = Some(t.now_us());
        }

        // 2. replan for the occupancy the admissions are about to
        //    produce, THEN prefill-join waiting requests under that plan:
        //    a burst that crosses a bucket is admitted directly on the
        //    crossing plan (no post-hoc rewrite, no drafter rebuild).
        let free = self.engine.capacity() - self.slots.occupancy();
        let predicted = self.slots.occupancy() + self.queue.len().min(free);
        let mut crossed = predicted > 0 && self.replan.on_occupancy(predicted).is_some();
        let admission_plan = self.current_plan();
        while !self.slots.is_full() {
            let Some(q) = self.queue.pop() else { break };
            // a malformed request is rejected individually — it must not
            // take down the batch it would have joined
            if self.engine.validate(&q.req).is_err() {
                self.metrics.invalid += 1;
                continue;
            }
            let slot = self
                .slots
                .alloc()
                .ok_or_else(|| anyhow!("slot allocator full despite free check"))?;
            let id = q.req.id;
            if let Err(e) = self.engine.admit(slot, q.req, admission_plan.clone()) {
                // a failed admission must not leak the slot
                self.slots.release(slot)?;
                return Err(e);
            }
            if let Some(rc) = &mut self.reconfig {
                rc.on_admit(slot, &self.report.per_slot);
            }
            self.arrival_s[slot] = q.enqueued_s;
            self.prio_s[slot] = q.prio;
            self.reset_degrade(slot);
            // a quarantined request re-entering a slot is a recovery: its
            // verified output survived the fault and decoding resumes
            if self.retries.contains_key(&id) {
                self.metrics.recoveries += 1;
            }
            if let Some(c) = self.corpus.as_mut() {
                // token-drafter admissions seed from the snapshot the
                // engine holds; count only warm offers
                if c.is_warm() && admission_plan.window > 0 && !admission_plan.method.is_model() {
                    c.note_seed();
                }
            }
            self.metrics.on_admit(now_s - q.enqueued_s);
            tr.admitted += 1;
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Admit, m, tr.admitted as u32);
            mark = Some(t.now_us());
        }

        // 3. the actual occupancy differs from the prediction only when
        //    queued requests were rejected as invalid; correct the bucket
        //    if so (same hysteresis — on_occupancy no-ops within a
        //    bucket). On any crossing this tick, a GROUPED engine resets
        //    every live slot ONCE to the final plan — heterogeneous plans
        //    each pay β there, so convergence is worth the rewrite (a
        //    no-op for slots already on it); the default FUSED engine
        //    leaves live slots' Algorithm-2-specialised plans standing.
        let occ = self.slots.occupancy();
        if occ == 0 {
            return Ok(tr);
        }
        crossed |= self.replan.on_occupancy(occ).is_some();
        if crossed {
            self.metrics.replans += 1;
            tr.replanned = true;
            if self.spec && self.engine.verify_discipline() == VerifyDiscipline::Grouped {
                let plan = self.current_plan();
                for slot in 0..self.engine.capacity() {
                    // race members keep their raced methods: rewriting a
                    // replica's plan would corrupt the race's semantics
                    if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                        continue;
                    }
                    if self.slots.is_live(slot) {
                        self.engine.set_slot_plan(slot, plan.clone())?;
                    }
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Replan, m, crossed as u32);
            mark = Some(t.now_us());
        }

        // 3b. spend idle capacity on tail races (Algorithm 3): only when
        //     nothing waits for admission and occupancy sits below the
        //     arbiter's threshold; the launch gate prices each replica
        //     (fork + extra fused verify row vs expected rounds saved)
        if self.spec && self.race.is_some() && self.queue.is_empty() && !self.slots.is_full() {
            let occ_now = self.slots.occupancy();
            let want = self.race.as_ref().unwrap().cfg.max_replicas;
            let mut pool = Vec::with_capacity(want);
            while pool.len() < want {
                match self.slots.alloc() {
                    Some(s) => pool.push(s),
                    None => break,
                }
            }
            let ar = self.race.as_mut().unwrap();
            let considered = ar.consider(&mut self.engine, occ_now, &pool);
            // whatever happened, unused pool slots go back to the
            // allocator BEFORE any error propagates — an early `?` here
            // would leak them for the rest of the serve run
            let used = match &considered {
                Ok(u) => *u,
                Err(_) => 0,
            };
            for &s in &pool[used..] {
                self.slots.release(s)?;
            }
            let used = match considered {
                Ok(u) => u,
                // a Degradable fork failure degrades the race to the
                // members already forked (possibly none) — never the
                // serve loop; the primary keeps decoding either way
                Err(e)
                    if e.downcast_ref::<SpecError>().map(|se| se.severity())
                        == Some(Severity::Degradable) =>
                {
                    self.metrics.degradations += 1;
                    0
                }
                Err(e) => return Err(e),
            };
            if used > 0 {
                self.metrics.on_race_launch(used);
                tr.raced = used;
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::RaceLaunch, m, tr.raced as u32);
            mark = Some(t.now_us());
        }

        // 4. one engine round under the live slot plans; typed
        //    speculation faults are absorbed here — Degradable slots
        //    fall down the ladder to vanilla, SlotFatal slots are
        //    quarantined — and only untyped / WorkerFatal errors abort
        //    the serve loop
        self.run_round(&mut tr, &tracer, &mut mark)?;

        // 5. request-level reconfiguration (Algorithm 2) on schedule.
        //    Live-slot state (plan clones) is gathered only on firing
        //    rounds; off-period rounds just advance the counter.
        if self.spec {
            if let Some(rc) = self.reconfig.as_mut() {
                let mut live = Vec::new();
                if rc.due() {
                    for slot in 0..self.engine.capacity() {
                        if !self.slots.is_live(slot) || self.engine.is_done(slot) {
                            continue;
                        }
                        // race members are off-limits to Algorithm 2: a
                        // method rewrite mid-race would break win
                        // attribution (the arbiter owns those slots)
                        if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                            continue;
                        }
                        // degraded slots sit out Algorithm 2 until the
                        // ladder re-promotes them (backoff owns them)
                        if self.degrade_until[slot].is_some() {
                            continue;
                        }
                        if let Some(p) = self.engine.slot_plan(slot) {
                            if p.window > 0 {
                                live.push(LiveSlot { slot, method: p.method });
                            }
                        }
                    }
                }
                let changes = rc.on_round(&self.report.per_slot, &live);
                if !changes.is_empty() {
                    self.metrics.reconfigs += 1;
                    self.metrics.reconfigured_slots += changes.len() as u64;
                    tr.reconfigured = changes.len();
                }
                for (slot, plan) in changes {
                    self.engine.set_slot_plan(slot, plan)?;
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Reconfig, m, tr.reconfigured as u32);
        }
        Ok(tr)
    }

    /// One engine round plus its telemetry — the shared decode step of
    /// the sequential and overlapped tick orders. Typed faults route
    /// through the recovery ladder exactly as before; after the round
    /// the engine's cumulative prefetch ledger is mirrored into the
    /// serve metrics (mirror, not add — `EngineReport` accumulates).
    fn run_round(
        &mut self,
        tr: &mut TickReport,
        tracer: &Option<Tracer>,
        mark: &mut Option<u64>,
    ) -> Result<()> {
        let before = self.report.total_generated;
        self.prev_per_slot.clone_from(&self.report.per_slot);
        tr.active = match self.engine.round(&mut self.report) {
            Ok(n) => n,
            Err(e) => self.on_round_error(e)?,
        };
        tr.generated = self.report.total_generated - before;
        if let (Some(t), Some(m)) = (tracer, *mark) {
            t.record(Phase::Round, m, tr.active as u32);
            *mark = Some(t.now_us());
        }
        self.attribute_round_delta();
        // occupancy re-read: freshly-forked replicas are live rows too
        self.metrics.on_round(self.slots.occupancy(), tr.generated);
        self.metrics.prefetch_hits = self.report.prefetch_hits;
        self.metrics.prefetch_rollbacks = self.report.prefetch_rollbacks;
        Ok(())
    }

    /// The overlapped tick order (`with_overlap`): races resolve and
    /// finished requests retire (freeing slots), degraded slots
    /// re-promote (their retried plans must land before decoding), then
    /// the engine ROUND runs immediately — the decode critical path is
    /// front-loaded — and replanning, admissions and race launches run
    /// after it, hidden behind the overlapped worker's next-round
    /// prefetch. A tick that starts idle admits first and rounds at the
    /// end instead (there is nothing in flight to overlap yet). Token
    /// outputs are identical to the sequential order — requests may just
    /// join the batch one round later, which shifts scheduling, never
    /// content.
    fn tick_inner_overlap(&mut self, now_s: f64) -> Result<TickReport> {
        let mut tr = TickReport::default();
        self.ticks += 1;
        let tracer = self.tracer.clone();
        if let Some(t) = &tracer {
            t.begin_round(self.ticks);
        }
        let mut mark = tracer.as_ref().map(|t| t.now_us());

        // resolve finished races (identical to the sequential phase)
        if let Some(ar) = self.race.as_mut() {
            for fin in ar.resolve(&mut self.engine)? {
                for &s in &fin.freed {
                    self.slots.release(s)?;
                    self.reset_degrade(s);
                }
                self.retries.remove(&fin.req.id);
                let arrival = self.arrival_s[fin.primary];
                self.metrics.on_race_finish(
                    fin.replica_won,
                    &fin.winner_method,
                    fin.cancelled,
                    fin.wasted_rounds,
                );
                self.metrics.on_finish(now_s - arrival);
                if let Some(c) = self.corpus.as_mut() {
                    c.add_segment(&fin.req.seq);
                }
                self.finished.push(FinishedRequest {
                    req: fin.req,
                    arrival_s: arrival,
                    finished_s: now_s,
                });
                tr.retired += 1;
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Resolve, m, tr.retired as u32);
            mark = Some(t.now_us());
        }

        // retire finished requests, freeing their slots
        for slot in 0..self.engine.capacity() {
            if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                continue;
            }
            if self.slots.is_live(slot) && self.engine.is_done(slot) {
                let req = self.engine.retire(slot)?;
                self.slots.release(slot)?;
                self.reset_degrade(slot);
                self.retries.remove(&req.id);
                let arrival = self.arrival_s[slot];
                self.metrics.on_finish(now_s - arrival);
                // harvest the completed request's verified tokens into
                // the wave-global corpus (completion sites only — a
                // quarantined or migrating request continues elsewhere
                // and would double-count)
                if let Some(c) = self.corpus.as_mut() {
                    c.add_segment(&req.seq);
                }
                self.finished.push(FinishedRequest { req, arrival_s: arrival, finished_s: now_s });
                tr.retired += 1;
            }
        }

        // re-promotion precedes the round: a retried speculative plan
        // decodes this very tick (same ladder semantics as sequential)
        if self.spec {
            let plan = self.current_plan();
            for slot in 0..self.engine.capacity() {
                if !self.degrade_until[slot].is_some_and(|t| self.ticks >= t) {
                    continue;
                }
                self.degrade_until[slot] = None;
                if !self.slots.is_live(slot) || self.engine.is_done(slot) {
                    continue;
                }
                if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                    continue;
                }
                if plan.window > 0 {
                    self.engine.set_slot_plan(slot, plan.clone())?;
                    self.metrics.repromotions += 1;
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Retire, m, tr.retired as u32);
            mark = Some(t.now_us());
        }

        // the ROUND, before any admission bookkeeping — unless this tick
        // starts idle (nothing in flight to hide the bookkeeping behind)
        let mut rounded = false;
        if self.slots.occupancy() > 0 {
            self.run_round(&mut tr, &tracer, &mut mark)?;
            rounded = true;
        }

        // racing replicas yield to real work before admissions
        if let Some(ar) = self.race.as_mut() {
            while !self.queue.is_empty() && self.slots.is_full() && ar.active_races() > 0 {
                let c = ar.cancel_one(&mut self.engine)?;
                for &s in &c.freed {
                    self.slots.release(s)?;
                }
                self.metrics.on_race_cancel(c.replicas, c.wasted_rounds);
            }
        }

        // replan for the post-admission occupancy, then prefill-join —
        // the same crossing logic as sequential, just after the round
        let free = self.engine.capacity() - self.slots.occupancy();
        let predicted = self.slots.occupancy() + self.queue.len().min(free);
        let mut crossed = predicted > 0 && self.replan.on_occupancy(predicted).is_some();
        let admission_plan = self.current_plan();
        while !self.slots.is_full() {
            let Some(q) = self.queue.pop() else { break };
            if self.engine.validate(&q.req).is_err() {
                self.metrics.invalid += 1;
                continue;
            }
            let slot = self
                .slots
                .alloc()
                .ok_or_else(|| anyhow!("slot allocator full despite free check"))?;
            let id = q.req.id;
            if let Err(e) = self.engine.admit(slot, q.req, admission_plan.clone()) {
                self.slots.release(slot)?;
                return Err(e);
            }
            if let Some(rc) = &mut self.reconfig {
                rc.on_admit(slot, &self.report.per_slot);
            }
            self.arrival_s[slot] = q.enqueued_s;
            self.prio_s[slot] = q.prio;
            self.reset_degrade(slot);
            if self.retries.contains_key(&id) {
                self.metrics.recoveries += 1;
            }
            if let Some(c) = self.corpus.as_mut() {
                // token-drafter admissions seed from the snapshot the
                // engine holds; count only warm offers
                if c.is_warm() && admission_plan.window > 0 && !admission_plan.method.is_model() {
                    c.note_seed();
                }
            }
            self.metrics.on_admit(now_s - q.enqueued_s);
            tr.admitted += 1;
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Admit, m, tr.admitted as u32);
            mark = Some(t.now_us());
        }

        let occ = self.slots.occupancy();
        if occ == 0 {
            return Ok(tr);
        }
        crossed |= self.replan.on_occupancy(occ).is_some();
        if crossed {
            self.metrics.replans += 1;
            tr.replanned = true;
            if self.spec && self.engine.verify_discipline() == VerifyDiscipline::Grouped {
                let plan = self.current_plan();
                for slot in 0..self.engine.capacity() {
                    if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                        continue;
                    }
                    if self.slots.is_live(slot) {
                        self.engine.set_slot_plan(slot, plan.clone())?;
                    }
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Replan, m, crossed as u32);
            mark = Some(t.now_us());
        }

        // spend idle capacity on tail races — next round's replicas
        if self.spec && self.race.is_some() && self.queue.is_empty() && !self.slots.is_full() {
            let occ_now = self.slots.occupancy();
            let want = self.race.as_ref().unwrap().cfg.max_replicas;
            let mut pool = Vec::with_capacity(want);
            while pool.len() < want {
                match self.slots.alloc() {
                    Some(s) => pool.push(s),
                    None => break,
                }
            }
            let ar = self.race.as_mut().unwrap();
            let considered = ar.consider(&mut self.engine, occ_now, &pool);
            let used = match &considered {
                Ok(u) => *u,
                Err(_) => 0,
            };
            for &s in &pool[used..] {
                self.slots.release(s)?;
            }
            let used = match considered {
                Ok(u) => u,
                Err(e)
                    if e.downcast_ref::<SpecError>().map(|se| se.severity())
                        == Some(Severity::Degradable) =>
                {
                    self.metrics.degradations += 1;
                    0
                }
                Err(e) => return Err(e),
            };
            if used > 0 {
                self.metrics.on_race_launch(used);
                tr.raced = used;
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::RaceLaunch, m, tr.raced as u32);
            mark = Some(t.now_us());
        }

        // idle-start tick: the round runs after the admissions instead
        if !rounded && self.slots.occupancy() > 0 {
            self.run_round(&mut tr, &tracer, &mut mark)?;
        }

        // request-level reconfiguration (Algorithm 2), as sequential
        if self.spec {
            if let Some(rc) = self.reconfig.as_mut() {
                let mut live = Vec::new();
                if rc.due() {
                    for slot in 0..self.engine.capacity() {
                        if !self.slots.is_live(slot) || self.engine.is_done(slot) {
                            continue;
                        }
                        if self.race.as_ref().is_some_and(|a| a.is_member(slot)) {
                            continue;
                        }
                        if self.degrade_until[slot].is_some() {
                            continue;
                        }
                        if let Some(p) = self.engine.slot_plan(slot) {
                            if p.window > 0 {
                                live.push(LiveSlot { slot, method: p.method });
                            }
                        }
                    }
                }
                let changes = rc.on_round(&self.report.per_slot, &live);
                if !changes.is_empty() {
                    self.metrics.reconfigs += 1;
                    self.metrics.reconfigured_slots += changes.len() as u64;
                    tr.reconfigured = changes.len();
                }
                for (slot, plan) in changes {
                    self.engine.set_slot_plan(slot, plan)?;
                }
            }
        }
        if let (Some(t), Some(m)) = (&tracer, mark) {
            t.record(Phase::Reconfig, m, tr.reconfigured as u32);
        }
        Ok(tr)
    }

    /// Attribute this round's per-slot drafted/accepted deltas to each
    /// slot's draft method — the per-method acceptance telemetry. Reads
    /// the pre-round snapshot taken in `tick_inner`; slots that drafted
    /// nothing (vanilla, idle) contribute nothing.
    fn attribute_round_delta(&mut self) {
        for (slot, cur) in self.report.per_slot.iter().enumerate() {
            let prev = self.prev_per_slot.get(slot).copied().unwrap_or_default();
            let drafted = cur.drafted - prev.drafted;
            let accepted = cur.accepted - prev.accepted;
            if drafted == 0 && accepted == 0 {
                continue;
            }
            if let Some(p) = self.engine.slot_plan(slot) {
                self.metrics.on_method_tokens(&p.method.label(), drafted, accepted);
            }
        }
    }

    /// Assemble the complete scrape snapshot: serve counters, the
    /// queue's rejection ledger, racing telemetry, engine-side series
    /// (runtime copy/execute ledger, chaos injections), slot gauges and
    /// the tracer's per-phase histograms — the same numbers `to_json`
    /// renders, in Prometheus form, from one source of truth.
    pub fn collect_registry(&self, wall_s: f64) -> MetricRegistry {
        let mut reg = MetricRegistry::new();
        self.metrics.register(&mut reg, wall_s);
        self.queue.register_metrics(&mut reg);
        if let Some(ar) = &self.race {
            ar.register_metrics(&mut reg);
        }
        let rep = &self.report;
        reg.counter(
            "specactor_engine_draft_hidden_seconds_total",
            "Draft seconds hidden behind the fused verify step by overlapped prefetch",
            rep.draft_hidden_s,
        );
        let engine_counters: [(&str, &str, u64); 11] = [
            ("target_steps", "Target model steps launched", rep.target_steps),
            ("draft_steps", "Draft model steps launched", rep.draft_steps),
            ("drafted_tokens", "Tokens proposed by drafters", rep.drafted_tokens),
            ("accepted_tokens", "Drafted tokens accepted by verification", rep.accepted_tokens),
            ("wasted_tokens", "Drafted tokens rejected by verification", rep.wasted_tokens),
            ("generated_tokens", "Tokens emitted into sequences", rep.total_generated),
            ("iterations", "Engine iterations run", rep.iterations),
            (
                "skipped_iterations",
                "Iterations advancing more than one token",
                rep.skipped_iterations,
            ),
            ("prefetch_hits", "Rounds served from a prefetched draft chunk", rep.prefetch_hits),
            (
                "prefetch_rollbacks",
                "Prefetch mirrors rolled back on mis-speculation",
                rep.prefetch_rollbacks,
            ),
            (
                "prefetch_deaths",
                "Prefetch threads lost (overlap degraded to sequential drafting)",
                rep.prefetch_deaths,
            ),
        ];
        for (name, help, v) in engine_counters {
            reg.counter(&format!("specactor_engine_{name}"), help, v as f64);
        }
        reg.counter("specactor_serve_ticks", "Serve-loop ticks run", self.ticks as f64);
        // the wave-global corpus ledger under its own family name (the
        // `specactor_serve_corpus_*` mirrors above reconcile to_json)
        let m = &self.metrics;
        let corpus_counters: [(&str, &str, u64); 5] = [
            ("tokens", "Corpus tokens indexed by the latest published snapshot", m.corpus_tokens),
            ("seeds", "Admissions seeded from a warm corpus snapshot", m.corpus_seeds),
            ("publishes", "Corpus snapshot epochs published", m.corpus_publishes),
            ("evictions", "Corpus segments evicted by the retention cap", m.corpus_evictions),
            ("decays", "Weight-update corpus decays", m.corpus_decays),
        ];
        for (name, help, v) in corpus_counters {
            reg.counter(&format!("specactor_corpus_{name}"), help, v as f64);
        }
        reg.gauge(
            "specactor_slots_occupancy",
            "Batch slots currently live",
            self.slots.occupancy() as f64,
        );
        reg.gauge(
            "specactor_slots_high_water",
            "Peak concurrent slot occupancy",
            self.slots.high_water as f64,
        );
        reg.gauge(
            "specactor_slots_capacity",
            "Batch slot capacity",
            self.engine.capacity() as f64,
        );
        reg.gauge(
            "specactor_fault_dumps",
            "Flight-recorder post-mortems held (bounded, oldest dropped)",
            self.fault_dumps.len() as f64,
        );
        self.engine.collect_metrics(&mut reg);
        if let Some(t) = &self.tracer {
            t.register_metrics(&mut reg);
        }
        reg
    }

    /// Publish the end-of-run scrape snapshot (no-op without an
    /// exporter) so a scraper arriving after the last tick still sees
    /// the final totals rather than a mid-run snapshot.
    pub fn publish_final(&self, wall_s: f64) {
        if let Some(ex) = &self.exporter {
            ex.publish(self.collect_registry(wall_s).render());
        }
    }

    /// Round-boundary corpus bookkeeping (no-op without `with_corpus`):
    ///
    /// 1. **decay** — a weight-update invalidation (the chaos `pause=N`
    ///    protocol, `ServeEngine::invalidate_draft_state`) makes every
    ///    corpus token stale against the new weights, so the corpus
    ///    publishes an empty epoch, reseeds from the live slots'
    ///    verified prefixes (those survive the update — verification
    ///    owns them), and the planner priors re-widen to their profiled
    ///    values ([`Replanner::note_decay`], `Reconfigurator::note_decay`);
    /// 2. **publish** — the tick's harvested completions fold into a new
    ///    immutable snapshot (one epoch per boundary, never per token),
    ///    traced as [`Phase::CorpusPublish`];
    /// 3. **feed** — on publish/decay boundaries only, per-method
    ///    measured acceptance deltas (against [`Batcher::prior_base`])
    ///    flow into the replanner and Reconfigurator so Algorithm 1/2
    ///    start from measured rates instead of static profiles.
    fn corpus_roundup(&mut self) {
        if self.corpus.is_none() {
            return;
        }
        let inv = self.engine.invalidations();
        let mut decayed = false;
        if inv > self.seen_invalidations {
            self.seen_invalidations = inv;
            let c = self.corpus.as_mut().unwrap();
            if c.decay_on_invalidate() {
                c.decay();
                decayed = true;
            }
        }
        if decayed {
            // only a publishing corpus reseeds locally: a cluster tap's
            // reseed would be drained at the master's decay boundary and
            // discarded (the cluster sweeps every worker's live prefixes
            // itself as the sole reseed source)
            if self.corpus.as_ref().unwrap().is_publisher() {
                let mut seqs: Vec<Vec<i32>> = Vec::new();
                for slot in 0..self.engine.capacity() {
                    if self.slots.is_live(slot) {
                        if let Some(r) = self.engine.request(slot) {
                            seqs.push(r.seq.clone());
                        }
                    }
                }
                let c = self.corpus.as_mut().unwrap();
                for s in &seqs {
                    c.add_segment(s);
                }
            }
            self.note_prior_decay();
        }
        let mut published = false;
        {
            let c = self.corpus.as_mut().unwrap();
            if c.publish_due() {
                let m = self.tracer.as_ref().map(|t| t.now_us());
                let folded = c.publish();
                if let (Some(t), Some(m)) = (&self.tracer, m) {
                    t.record(Phase::CorpusPublish, m, folded as u32);
                }
                published = true;
            }
            self.metrics.set_corpus_stats(&c.stats);
        }
        if published || decayed {
            self.feed_measured_deltas();
        }
    }

    /// Feed per-method measured acceptance deltas (counted against
    /// [`Batcher::prior_base`]) into the replanner and Reconfigurator.
    /// Called on local publish/decay boundaries and, via the cluster, at
    /// MASTER corpus boundaries (worker taps never publish themselves,
    /// so the cluster drives the feed cadence for its workers).
    pub fn feed_measured_deltas(&mut self) {
        let deltas: Vec<(String, f64, u64, u64)> = self
            .metrics
            .method_acceptance()
            .into_iter()
            .map(|(m, _, a, d)| {
                let (a0, d0) = self.prior_base.get(&m).copied().unwrap_or((0, 0));
                let (da, dd) = (a.saturating_sub(a0), d.saturating_sub(d0));
                let rate = if dd > 0 { da as f64 / dd as f64 } else { 0.0 };
                (m, rate, da, dd)
            })
            .collect();
        self.replan.feed_measured(&deltas);
        if let Some(rc) = self.reconfig.as_mut() {
            rc.feed_measured(&deltas);
        }
    }

    /// Reset the measured-acceptance feedback to "no evidence yet": the
    /// planner priors return to their profiled values and future deltas
    /// measure from this instant. Called on local corpus decay and, via
    /// the cluster, when the MASTER corpus decays (every worker's priors
    /// re-widen together even though only one engine saw the pause).
    pub fn note_prior_decay(&mut self) {
        self.replan.note_decay();
        if let Some(rc) = self.reconfig.as_mut() {
            rc.note_decay();
        }
        self.prior_base = self
            .metrics
            .method_acceptance()
            .into_iter()
            .map(|(m, _, a, d)| (m, (a, d)))
            .collect();
    }

    /// Mutable access to the attached corpus (the cluster drains worker
    /// taps and relays decay flags through this).
    pub fn corpus_mut(&mut self) -> Option<&mut DraftCorpus> {
        self.corpus.as_mut()
    }

    fn reset_degrade(&mut self, slot: usize) {
        self.degrade_attempts[slot] = 0;
        self.degrade_until[slot] = None;
    }

    /// Route a typed engine-round failure through the recovery ladder.
    /// Untyped and [`Severity::WorkerFatal`] errors stay fatal exactly as
    /// before the taxonomy existed. Returns the post-recovery occupancy
    /// (standing in for the aborted round's active-slot count).
    fn on_round_error(&mut self, e: anyhow::Error) -> Result<usize> {
        let (sev, slot) = match e.downcast_ref::<SpecError>() {
            Some(se) => (se.severity(), se.slot()),
            None => return Err(e),
        };
        self.capture_fault_dump(&e, sev, slot);
        match sev {
            Severity::WorkerFatal => return Err(e),
            Severity::Degradable => match slot {
                Some(s) => self.degrade_slot(s)?,
                None => {
                    // batch-wide (a dead decoupled drafter thread): every
                    // live slot degrades to vanilla; the fused verify
                    // path carries them all in one target step per round
                    for s in 0..self.engine.capacity() {
                        self.degrade_slot(s)?;
                    }
                }
            },
            Severity::SlotFatal => {
                let s = slot.ok_or(e)?;
                self.quarantine(s)?;
            }
        }
        Ok(self.slots.occupancy())
    }

    /// Flight-recorder post-mortem: on a typed engine-round fault,
    /// snapshot the last [`FAULT_DUMP_ROUNDS`] rounds of spans plus the
    /// victim slot's plan and acceptance timeline BEFORE recovery mutates
    /// them. No-op when tracing is off; the dump list is bounded.
    fn capture_fault_dump(&mut self, e: &anyhow::Error, sev: Severity, slot: Option<usize>) {
        let Some(t) = &self.tracer else {
            return;
        };
        let severity = match sev {
            Severity::Degradable => "degradable",
            Severity::SlotFatal => "slot_fatal",
            Severity::WorkerFatal => "worker_fatal",
        };
        let (plan, drafted, accepted) = match slot {
            Some(s) => {
                let plan = self
                    .engine
                    .slot_plan(s)
                    .map(|p| format!("{}:{}", p.method.label(), p.window))
                    .unwrap_or_else(|| "?".to_string());
                let acc = self.report.per_slot.get(s).copied().unwrap_or_default();
                (plan, acc.drafted, acc.accepted)
            }
            None => ("batch".to_string(), self.report.drafted_tokens, self.report.accepted_tokens),
        };
        if self.fault_dumps.len() >= MAX_FAULT_DUMPS {
            self.fault_dumps.remove(0);
        }
        self.fault_dumps.push(FaultDump {
            round: self.ticks,
            error: format!("{e:#}"),
            severity: severity.to_string(),
            slot,
            plan,
            drafted,
            accepted,
            spans: t.recent_spans(FAULT_DUMP_ROUNDS),
        });
    }

    /// Degradation ladder, down-rung: force the slot to vanilla decode
    /// (window 0 — provably lossless, the sampling tape is keyed by
    /// (seed, request, position), never by plan) and schedule an
    /// exponentially backed-off re-promotion attempt. Races touching the
    /// slot are cancelled first — a fault inside the speculation
    /// machinery is not worth preserving speculative races for.
    fn degrade_slot(&mut self, slot: usize) -> Result<()> {
        if !self.slots.is_live(slot) {
            return Ok(());
        }
        self.uncouple_from_races(slot)?;
        if !self.slots.is_live(slot) || self.engine.is_done(slot) {
            // the slot was a cancelled replica (or finished): nothing
            // left to degrade — the primary decodes on unaffected
            return Ok(());
        }
        if let Some(p) = self.engine.slot_plan(slot) {
            if p.window > 0 {
                self.engine.set_slot_plan(slot, SlotPlan::vanilla())?;
            }
        }
        let n = self.degrade_attempts[slot] + 1;
        self.degrade_attempts[slot] = n;
        // backoff 2, 4, 8, ... 64 ticks of guaranteed-progress vanilla
        self.degrade_until[slot] = Some(self.ticks + (2u64 << (n - 1).min(5)));
        self.metrics.degradations += 1;
        Ok(())
    }

    /// Quarantine, the SlotFatal rung: the slot's state can no longer be
    /// trusted in place, so retire it and re-enqueue the request at the
    /// FRONT of its original priority lane with its verified output
    /// preserved — re-admission replays the whole sequence through the
    /// ordinary prefill + catch-up path into a fresh row. Bounded by the
    /// per-request retry budget; exhaustion is a typed rejection, never a
    /// silent loss.
    fn quarantine(&mut self, slot: usize) -> Result<()> {
        if !self.slots.is_live(slot) {
            return Ok(());
        }
        self.uncouple_from_races(slot)?;
        if !self.slots.is_live(slot) {
            return Ok(()); // cancelled replica: the primary carries on
        }
        let req = self.engine.retire(slot)?;
        self.slots.release(slot)?;
        let prio = self.prio_s[slot];
        let arrival = self.arrival_s[slot];
        self.reset_degrade(slot);
        self.metrics.quarantines += 1;
        let n = self.retries.entry(req.id).or_insert(0);
        *n += 1;
        if *n > self.retry_budget {
            self.retries.remove(&req.id);
            self.queue.note_reject(RejectReason::RetryExhausted);
        } else {
            self.queue.requeue_front(req, prio, arrival);
            self.metrics.requeues += 1;
        }
        Ok(())
    }

    /// Cancel races until `slot` is no longer a member (replica slots are
    /// freed; a race's primary keeps decoding). `cancel_one` pops races
    /// newest-first, so uncoupling an early member may cancel younger
    /// races too — conservative, and only on the fault path.
    fn uncouple_from_races(&mut self, slot: usize) -> Result<()> {
        let Some(ar) = self.race.as_mut() else {
            return Ok(());
        };
        while ar.is_member(slot) && ar.active_races() > 0 {
            let c = ar.cancel_one(&mut self.engine)?;
            for &s in &c.freed {
                self.slots.release(s)?;
            }
            self.metrics.on_race_cancel(c.replicas, c.wasted_rounds);
        }
        Ok(())
    }
}

/// How a request left its worker during migration / evacuation — the
/// discriminator for what the destination must do (and whether the hop
/// charges the quarantine retry budget).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvacKind {
    /// Full payload extracted — the KV row (where the engine owns one)
    /// migrates via `RowTransport`; the destination inserts it directly,
    /// no re-prefill, no retry charge.
    Extracted,
    /// The engine's extract path no longer answered; the request state
    /// was salvaged by cloning and must re-prefill at the destination
    /// under the retry budget (front-of-lane, like a quarantine).
    Salvaged,
    /// Never admitted — it was still waiting in the dead worker's local
    /// queue; re-routes to a survivor without touching the retry budget.
    Queued,
}

/// One request stripped off a worker by [`Batcher::evacuate`] or
/// [`Batcher::extract_slot`], with the scheduling bookkeeping the
/// destination needs to adopt it faithfully (latency is measured from the
/// original arrival, and quarantine retries travel with the request so
/// the budget is global, not per-worker).
#[derive(Clone, Debug)]
pub struct Evacuee {
    pub payload: MigrationPayload,
    pub prio: Priority,
    pub arrival_s: f64,
    /// Quarantine retries already consumed by this request.
    pub retries: u32,
    pub kind: EvacKind,
}

// ---- cluster support ----------------------------------------------------
//
// `serve::cluster::Cluster` composes one batcher per worker; these
// methods are the supervisor's surface for slot migration, dead-worker
// evacuation and cross-worker racing. They live here because they need
// the batcher's private bookkeeping (arrival stamps, priority lanes,
// degrade state, the retry ledger).
impl<E: ServeEngine> Batcher<E> {
    /// Mutable engine access (the cluster's transport/chaos wire hook).
    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    /// Scheduling load: occupied slots plus locally queued requests —
    /// the cluster's least-loaded routing key.
    pub fn load(&self) -> usize {
        self.slots.occupancy() + self.queue.len()
    }

    /// Ticks this batcher has served (its heartbeat clock).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Is `slot` currently a member of a local Fastest-of-N race?
    pub fn is_race_member(&self, slot: usize) -> bool {
        self.race.as_ref().is_some_and(|a| a.is_member(slot))
    }

    /// The occupying request's (priority, arrival) bookkeeping, `None`
    /// for free slots.
    pub fn slot_meta(&self, slot: usize) -> Option<(Priority, f64)> {
        self.slots.is_live(slot).then(|| (self.prio_s[slot], self.arrival_s[slot]))
    }

    /// Work-stealing extract on a HEALTHY worker: pull one live slot's
    /// migration payload (local races uncoupled first), freeing the
    /// slot. Returns `None` when the slot is not migratable — empty,
    /// finished, or cancelled out from under us by race uncoupling. An
    /// engine extract failure also returns `None` and leaves the slot
    /// running in place: the destructive salvage fallback is reserved
    /// for evacuating the dead ([`Batcher::evacuate`]).
    pub fn extract_slot(&mut self, slot: usize) -> Result<Option<Evacuee>> {
        if !self.slots.is_live(slot) || self.engine.is_done(slot) {
            return Ok(None);
        }
        self.uncouple_from_races(slot)?;
        if !self.slots.is_live(slot) || self.engine.is_done(slot) {
            return Ok(None);
        }
        let (prio, arrival_s) = (self.prio_s[slot], self.arrival_s[slot]);
        let payload = match self.engine.extract_payload(slot) {
            Ok(p) => p,
            Err(_) => return Ok(None),
        };
        self.slots.release(slot)?;
        self.reset_degrade(slot);
        let retries = self.retries.remove(&payload.req.id).unwrap_or(0);
        Ok(Some(Evacuee { payload, prio, arrival_s, retries, kind: EvacKind::Extracted }))
    }

    /// Death-path evacuation: strip EVERY live slot and the local queue
    /// off a worker declared dead. Local races are cancelled first;
    /// where the engine's extract path still answers, the full payload
    /// (row included) is taken, otherwise the request state is salvaged
    /// by cloning for front-of-lane re-prefill — zero requests are lost
    /// either way. Duplicate ids (an uncancellable race replica on a
    /// dying engine) are dropped after the first copy.
    pub fn evacuate(&mut self) -> Vec<Evacuee> {
        let mut out: Vec<Evacuee> = Vec::new();
        if let Some(ar) = self.race.as_mut() {
            while ar.active_races() > 0 {
                match ar.cancel_one(&mut self.engine) {
                    Ok(c) => {
                        for &s in &c.freed {
                            let _ = self.slots.release(s);
                        }
                        self.metrics.on_race_cancel(c.replicas, c.wasted_rounds);
                    }
                    // the dying engine refused the cancel: fall through —
                    // the id-dedup below keeps one copy per request
                    Err(_) => break,
                }
            }
        }
        let mut seen: BTreeSet<u64> = BTreeSet::new();
        for slot in 0..self.engine.capacity() {
            if !self.slots.is_live(slot) {
                continue;
            }
            let (prio, arrival_s) = (self.prio_s[slot], self.arrival_s[slot]);
            let (payload, kind) = match self.engine.extract_payload(slot) {
                Ok(p) => (p, EvacKind::Extracted),
                Err(_) => match self.engine.request(slot).cloned() {
                    Some(req) => (MigrationPayload::new(req), EvacKind::Salvaged),
                    None => {
                        let _ = self.slots.release(slot);
                        continue;
                    }
                },
            };
            let _ = self.slots.release(slot);
            self.reset_degrade(slot);
            if !seen.insert(payload.req.id) {
                continue;
            }
            let retries = self.retries.remove(&payload.req.id).unwrap_or(0);
            out.push(Evacuee { payload, prio, arrival_s, retries, kind });
        }
        while let Some(q) = self.queue.pop() {
            if !seen.insert(q.req.id) {
                continue;
            }
            let retries = self.retries.remove(&q.req.id).unwrap_or(0);
            out.push(Evacuee {
                payload: MigrationPayload::new(q.req),
                prio: q.prio,
                arrival_s: q.enqueued_s,
                retries,
                kind: EvacKind::Queued,
            });
        }
        out
    }

    /// Adopt a migrated payload into a free slot (the destination half
    /// of slot migration / evacuation / cross-worker race forks) and
    /// return the slot it landed in. Not an admission for metrics
    /// purposes — the request was admitted once already, at its source;
    /// its arrival stamp and retry ledger carry over.
    pub fn adopt(&mut self, e: &Evacuee) -> Result<usize> {
        let Some(slot) = self.slots.alloc() else {
            bail!("no free slot to adopt request {}", e.payload.req.id)
        };
        let plan = self.current_plan();
        let seeded = self
            .corpus
            .as_ref()
            .is_some_and(|c| c.is_warm() && plan.window > 0 && !plan.method.is_model());
        if let Err(err) = self.engine.insert_payload(slot, e.payload.clone(), plan) {
            let _ = self.slots.release(slot);
            return Err(err);
        }
        if seeded {
            if let Some(c) = self.corpus.as_mut() {
                c.note_seed();
            }
        }
        self.prio_s[slot] = e.prio;
        self.arrival_s[slot] = e.arrival_s;
        self.reset_degrade(slot);
        if e.retries > 0 {
            self.retries.insert(e.payload.req.id, e.retries);
        }
        Ok(slot)
    }

    /// Front-of-lane requeue of a recovered request (evacuation fallback
    /// / transport escalation). `charge` walks the quarantine retry
    /// budget — the re-prefill path costs a retry exactly as an
    /// in-process quarantine does; a row that merely needs a free slot
    /// re-queues uncharged. Exhaustion is a typed rejection, never a
    /// silent loss. Returns false when the budget rejected the request.
    pub fn readmit(
        &mut self,
        req: Request,
        prio: Priority,
        arrival_s: f64,
        prior_retries: u32,
        charge: bool,
    ) -> bool {
        let n = prior_retries + u32::from(charge);
        if n > self.retry_budget {
            self.retries.remove(&req.id);
            self.queue.note_reject(RejectReason::RetryExhausted);
            return false;
        }
        if n > 0 {
            self.retries.insert(req.id, n);
        }
        self.queue.requeue_front(req, prio, arrival_s);
        self.metrics.requeues += 1;
        true
    }

    /// Force-cancel a live slot (a cluster-level race loser). The
    /// request state is retired and RETURNED, not completed — the loser
    /// of a Fastest-of-N race produced the same tokens as the winner
    /// (the sampling tape is keyed by (seed, request, position)), so
    /// dropping it loses nothing.
    pub fn cancel_slot(&mut self, slot: usize) -> Result<Option<Request>> {
        if !self.slots.is_live(slot) {
            return Ok(None);
        }
        self.uncouple_from_races(slot)?;
        if !self.slots.is_live(slot) {
            return Ok(None);
        }
        let req = self.engine.retire(slot)?;
        self.slots.release(slot)?;
        self.reset_degrade(slot);
        self.retries.remove(&req.id);
        Ok(Some(req))
    }

    /// Record a fault post-mortem into the flight recorder on behalf of
    /// the cluster: heartbeat-deadline deaths never pass through
    /// `on_round_error` (which captures the in-band faults), so the
    /// supervisor dumps them here before evacuating.
    pub fn record_fault(&mut self, e: &anyhow::Error) {
        let (sev, slot) = match e.downcast_ref::<SpecError>() {
            Some(se) => (se.severity(), se.slot()),
            None => (Severity::WorkerFatal, None),
        };
        self.capture_fault_dump(e, sev, slot);
    }
}

/// Outcome of [`drive_open_loop`].
#[derive(Clone, Copy, Debug, Default)]
pub struct OpenLoopReport {
    /// Virtual serving time at the end of the run (equals accumulated wall
    /// time when `dt` is None).
    pub elapsed_s: f64,
    pub offered: usize,
    /// Requests lost to backpressure during this run — both outright
    /// refusals and queued entries evicted by higher-priority arrivals
    /// (the queue's own counter), so
    /// `completed + rejected + metrics.invalid == offered`.
    pub rejected: usize,
    pub ticks: u64,
}

/// Drive a batcher through an **open-loop** arrival schedule: requests
/// join at their arrival times regardless of completions (the serving
/// regime; closed-loop replay would hide queueing).
///
/// `arrivals` is (absolute arrival seconds, request, priority), ascending
/// by time. `dt` fixes the virtual time advanced per tick (deterministic
/// smoke/test mode); with `None` each tick advances by its measured wall
/// duration — real serving time.
pub fn drive_open_loop<E: ServeEngine>(
    b: &mut Batcher<E>,
    arrivals: Vec<(f64, Request, Priority)>,
    dt: Option<f64>,
) -> Result<OpenLoopReport> {
    if arrivals.windows(2).any(|w| w[1].0 < w[0].0) {
        bail!("arrivals must be sorted by time");
    }
    let mut rep = OpenLoopReport { offered: arrivals.len(), ..Default::default() };
    let rejected0 = b.queue.rejected;
    let mut now = 0.0f64;
    let mut pending = arrivals.into_iter().peekable();
    loop {
        while pending.peek().map(|(t, _, _)| *t <= now).unwrap_or(false) {
            let (t, req, prio) = pending.next().unwrap();
            b.enqueue(req, prio, t);
        }
        if b.idle() {
            match pending.peek() {
                // fast-forward an idle server to the next arrival
                Some((t, _, _)) => {
                    now = *t;
                    continue;
                }
                None => break,
            }
        }
        let t0 = std::time::Instant::now();
        b.tick(now)?;
        rep.ticks += 1;
        now += dt.unwrap_or_else(|| t0.elapsed().as_secs_f64());
    }
    rep.elapsed_s = now;
    rep.rejected = (b.queue.rejected - rejected0) as usize;
    Ok(rep)
}

/// Deterministic engine stand-in: no runtime, no artifacts. Each round
/// advances every active request by a seeded pseudo-random number of
/// tokens shaped like speculative acceptance: the request's intrinsic
/// acceptance probability (skewed by id — most requests accept well, a
/// tail accepts poorly) gates a chain of up to `window` bonus advances,
/// where `window` comes from the slot's applied [`SlotPlan`]. Per-slot
/// drafted/accepted counters feed the reconfigurator exactly as the real
/// engine's do, so the batcher's admission / retirement / replanning /
/// reconfiguration logic can be exercised hermetically (unit tests,
/// `specactor serve --smoke`, `benches/reconfig_gain.rs`).
pub struct SyntheticEngine {
    slots: Vec<Option<Request>>,
    plans: Vec<SlotPlan>,
    seed: u64,
    rounds: u64,
    /// Modelled verify discipline: token output is identical, but
    /// `target_steps` counts what the real engine would launch — 1 per
    /// round when fused, one per plan group (plus a vanilla step) when
    /// grouped — so benches can A/B the step count hermetically.
    verify: VerifyDiscipline,
    /// Tail modulus: request ids with `id % tail_mod == tail_mod - 1`
    /// form the low-acceptance tail (`with_tail_every` varies the skew).
    tail_mod: u64,
    /// Draft-state invalidations received (weight-update hook calls) —
    /// the synthetic engine has no draft caches to rebuild, so the hook
    /// just counts, letting tests assert the pause protocol fired.
    pub invalidations: u64,
    /// Model the overlapped engine's prefetch ledger: a slot whose
    /// previous round full-accepted consumes a "prefetched" chunk this
    /// round (hit + hidden draft time); a sent prediction invalidated by
    /// a partial accept counts a rollback. Token output is untouched —
    /// exactly the real engine's invariant.
    overlap: bool,
    /// Per-slot "last round full-accepted" state backing the model.
    prev_full: Vec<bool>,
    /// Wave-global corpus handle (`None` = feature off): token-drafter
    /// admissions peek at the latest snapshot and model a seeded
    /// drafter's acceptance boost over their first rounds.
    corpus: Option<CorpusHandle>,
    /// Rounds of modelled seeded-drafter acceptance boost left, per slot.
    warm_left: Vec<u8>,
    /// Slot seeded from a PRE-invalidation snapshot (stale corpus): its
    /// modelled drafter proposes near-garbage until it retires — the
    /// collapse the decay-on-invalidate rule exists to prevent.
    stale: Vec<bool>,
    /// Snapshot epoch observed at the last weight-update invalidation.
    inval_epoch: u64,
}

/// Rounds a corpus-seeded admission keeps its modelled acceptance boost
/// (after which the request's own history dominates, as in the real
/// drafters, whose per-request automata absorb the verified sequence).
const CORPUS_WARM_ROUNDS: u8 = 6;
/// Modelled acceptance of a warm-seeded token drafter during the boost.
const CORPUS_WARM_ACCEPT: f64 = 0.95;
/// Modelled acceptance of a drafter seeded from a stale (pre-update)
/// corpus: the old weights' continuations rarely survive verification.
const CORPUS_STALE_ACCEPT: f64 = 0.1;

impl SyntheticEngine {
    pub fn new(capacity: usize, seed: u64) -> Self {
        assert!(capacity > 0);
        SyntheticEngine {
            slots: (0..capacity).map(|_| None).collect(),
            plans: (0..capacity).map(|_| SlotPlan::vanilla()).collect(),
            seed,
            rounds: 0,
            verify: VerifyDiscipline::Fused,
            tail_mod: 4,
            invalidations: 0,
            overlap: false,
            prev_full: vec![false; capacity],
            corpus: None,
            warm_left: vec![0; capacity],
            stale: vec![false; capacity],
            inval_epoch: 0,
        }
    }

    /// Model the overlapped engine's prefetch hit/rollback/hidden-time
    /// counters (`serve --smoke --overlap`). Deterministic, token-exact.
    pub fn with_overlap(mut self) -> Self {
        self.overlap = true;
        self
    }

    /// Model a grouped-verify engine instead (A/B step accounting).
    pub fn with_discipline(mut self, d: VerifyDiscipline) -> Self {
        self.verify = d;
        self
    }

    /// Make every `m`-th request a low-acceptance tail request instead of
    /// every 4th (the acceptance-skew axis of `benches/fon_race.rs`).
    pub fn with_tail_every(mut self, m: u64) -> Self {
        self.tail_mod = m.max(2);
        self
    }

    /// Target steps the modelled engine launches for the CURRENT active
    /// plan mix: fused = 1; grouped = one per `(method, window)` group
    /// plus one shared vanilla decode step.
    fn steps_for_round(&self) -> u64 {
        let live: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].as_ref().map(|r| !r.done).unwrap_or(false))
            .collect();
        if live.is_empty() {
            return 0;
        }
        match self.verify {
            VerifyDiscipline::Fused => 1,
            VerifyDiscipline::Grouped => {
                let mut reps: Vec<usize> = Vec::new();
                for &i in &live {
                    if !reps.iter().any(|&r| same_group(&self.plans[r], &self.plans[i])) {
                        reps.push(i);
                    }
                }
                reps.len() as u64
            }
        }
    }

    fn is_tail(&self, id: u64) -> bool {
        id % self.tail_mod == self.tail_mod - 1
    }

    /// Intrinsic method-aware acceptance probability: a skewed mix — most
    /// requests draft ~0.85 whatever the method, while the `1/tail_mod`
    /// tail minority drafts poorly under every method EXCEPT the
    /// suffix-automaton drafter (the hidden skew Algorithm 2 reacts to
    /// and a Fastest-of-N race exploits: a tail straggler raced onto sam
    /// finishes fast).
    fn accept_for(&self, id: u64, method: &DraftMethod) -> f64 {
        if self.is_tail(id) {
            if *method == DraftMethod::Sam {
                0.8
            } else {
                0.2
            }
        } else {
            0.85
        }
    }

    /// Admission-time corpus peek: a token-drafter plan seeds from the
    /// latest published snapshot — a warm POST-update snapshot grants
    /// the acceptance boost, a warm PRE-update snapshot marks the slot
    /// stale, a cold snapshot (or a model-drafter/vanilla plan) does
    /// nothing. Token output is untouched either way: seeding only
    /// changes how many drafted tokens verification accepts.
    fn note_admit_seed(&mut self, slot: usize, plan: &SlotPlan) {
        self.warm_left[slot] = 0;
        self.stale[slot] = false;
        let Some(h) = &self.corpus else { return };
        if plan.window == 0 || plan.method.is_model() {
            return;
        }
        let snap = h.load();
        if !snap.is_warm() {
            return;
        }
        if snap.epoch > self.inval_epoch {
            self.warm_left[slot] = CORPUS_WARM_ROUNDS;
        } else {
            self.stale[slot] = true;
        }
    }
}

impl ServeEngine for SyntheticEngine {
    fn capacity(&self) -> usize {
        self.slots.len()
    }

    fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("slot {slot} out of range");
        }
        if self.slots[slot].is_some() {
            bail!("slot {slot} already occupied");
        }
        self.note_admit_seed(slot, &plan);
        self.slots[slot] = Some(req);
        self.plans[slot] = plan;
        self.prev_full[slot] = false;
        Ok(())
    }

    fn retire(&mut self, slot: usize) -> Result<Request> {
        if let Some(pf) = self.prev_full.get_mut(slot) {
            *pf = false;
        }
        if let Some(w) = self.warm_left.get_mut(slot) {
            *w = 0;
        }
        if let Some(s) = self.stale.get_mut(slot) {
            *s = false;
        }
        self.slots
            .get_mut(slot)
            .and_then(|s| s.take())
            .ok_or_else(|| anyhow!("slot {slot} empty"))
    }

    fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
        self.rounds += 1;
        rep.target_steps += self.steps_for_round();
        let mut active = 0usize;
        for i in 0..self.slots.len() {
            let Some((id, done)) = self.slots[i].as_ref().map(|r| (r.id, r.done)) else {
                continue;
            };
            if done {
                continue;
            }
            active += 1;
            let w = self.plans[i].window;
            let mut p = self.accept_for(id, &self.plans[i].method);
            if w > 0 && !self.plans[i].method.is_model() {
                if self.stale[i] {
                    p = CORPUS_STALE_ACCEPT;
                } else if self.warm_left[i] > 0 {
                    p = p.max(CORPUS_WARM_ACCEPT);
                    self.warm_left[i] -= 1;
                }
            }
            let r = self.slots[i].as_mut().unwrap();
            let mut adv = 1usize;
            let mut acc = 0usize;
            if w > 0 {
                let mut rng = position_rng(self.seed, r.id, self.rounds);
                while acc < w && rng.bernoulli(p) {
                    acc += 1;
                }
                adv += acc;
                r.accept.observe(w, acc);
                rep.drafted_tokens += w as u64;
                rep.accepted_tokens += acc as u64;
                rep.wasted_tokens += (w - acc) as u64;
                let sa = rep.slot_accept(i);
                sa.drafted += w as u64;
                sa.accepted += acc as u64;
            }
            let adv = adv.min(r.budget - r.generated());
            if self.overlap && w > 0 {
                // modelled prefetch ledger: last round's held prediction
                // is consumed as a hit now (its draft time was hidden);
                // this round's prediction holds only on an untruncated
                // full accept, otherwise the mirror rolls back
                if self.prev_full[i] {
                    rep.prefetch_hits += 1;
                    rep.draft_hidden_s += w as f64 * 1e-6;
                }
                let held = acc == w && adv == 1 + acc;
                self.prev_full[i] = held;
                if !held {
                    rep.prefetch_rollbacks += 1;
                }
            }
            for _ in 0..adv {
                let t = (r.id as i32).wrapping_mul(31).wrapping_add(r.seq.len() as i32) & 0x7fff;
                r.seq.push(t);
            }
            r.iterations += 1;
            rep.total_generated += adv as u64;
            if adv > 1 {
                rep.skipped_iterations += 1;
            }
            if r.generated() >= r.budget {
                r.done = true;
            }
        }
        if active > 0 {
            rep.iterations += 1;
        }
        Ok(active)
    }

    fn is_done(&self, slot: usize) -> bool {
        self.slots
            .get(slot)
            .and_then(|s| s.as_ref())
            .map(|r| r.done)
            .unwrap_or(false)
    }

    fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
        self.plans.get(slot).cloned()
    }

    fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
        if slot >= self.plans.len() {
            bail!("slot {slot} out of range");
        }
        self.plans[slot] = plan;
        Ok(())
    }

    fn verify_discipline(&self) -> VerifyDiscipline {
        self.verify
    }

    fn request(&self, slot: usize) -> Option<&Request> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    fn fork(&mut self, src: usize, dst: usize, plan: SlotPlan) -> Result<()> {
        if src >= self.slots.len() || dst >= self.slots.len() {
            bail!("fork {src} -> {dst} out of range");
        }
        let Some(req) = self.slots[src].clone() else {
            bail!("fork source slot {src} is empty");
        };
        if req.done {
            bail!("fork source request {} already finished", req.id);
        }
        if self.slots[dst].is_some() {
            bail!("fork destination slot {dst} already occupied");
        }
        self.note_admit_seed(dst, &plan);
        self.plans[dst] = plan;
        self.slots[dst] = Some(req);
        self.prev_full[dst] = false;
        Ok(())
    }

    fn invalidate_draft_state(&mut self) -> Result<()> {
        self.invalidations += 1;
        // live drafters rebuild UNSEEDED from their verified sequences
        // (the worker's invalidation semantics): acceptance boosts and
        // staleness both end here; only the snapshot epoch at this
        // instant decides whether FUTURE admissions seed warm or stale
        for w in self.warm_left.iter_mut() {
            *w = 0;
        }
        for s in self.stale.iter_mut() {
            *s = false;
        }
        if let Some(h) = &self.corpus {
            self.inval_epoch = h.epoch();
        }
        Ok(())
    }

    fn set_corpus(&mut self, h: CorpusHandle) {
        self.corpus = Some(h);
    }

    fn invalidations(&self) -> u64 {
        self.invalidations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::costmodel::CostModel;

    fn replanner() -> Replanner {
        Replanner::new(
            CostModel::paper_32b(),
            vec![
                ("draft_mid".to_string(), 0.82),
                ("draft_small".to_string(), 0.74),
                ("ngram".to_string(), 0.40),
            ],
            vec![1, 2, 4],
            vec![1, 3, 7],
            7,
        )
    }

    fn mk_batcher(capacity: usize, queue_cap: usize) -> Batcher<SyntheticEngine> {
        Batcher::new(SyntheticEngine::new(capacity, 99), queue_cap, replanner(), true)
    }

    fn req(id: u64, budget: usize) -> Request {
        Request::new(id, vec![1, 2, 3, 4], budget)
    }

    #[test]
    fn overlapped_tick_order_serves_identically_and_counts_prefetch() {
        let drive = |overlap: bool| {
            let eng = SyntheticEngine::new(4, 99);
            let eng = if overlap { eng.with_overlap() } else { eng };
            let mut b = Batcher::new(eng, 16, replanner(), true);
            if overlap {
                b = b.with_overlap();
            }
            for i in 0..8 {
                assert!(b.enqueue(req(i, 20), Priority::Batch, 0.0));
            }
            let mut now = 0.0;
            let mut guard = 0;
            while !b.idle() {
                b.tick(now).unwrap();
                now += 0.01;
                guard += 1;
                assert!(guard < 500, "overlap={overlap} failed to drain");
            }
            let mut fins = b.drain_finished();
            fins.sort_by_key(|f| f.req.id);
            (fins, b.metrics.clone(), b.report.clone())
        };
        let (seq_fins, seq_m, _) = drive(false);
        let (ov_fins, ov_m, ov_rep) = drive(true);
        assert_eq!(seq_m.completed, 8);
        assert_eq!(ov_m.completed, 8);
        // token identity: the tick phase order shifts scheduling only —
        // every request's generated sequence is byte-identical
        assert_eq!(seq_fins.len(), ov_fins.len());
        for (s, o) in seq_fins.iter().zip(&ov_fins) {
            assert_eq!(s.req.id, o.req.id);
            assert_eq!(s.req.seq, o.req.seq, "request {} diverged", s.req.id);
        }
        // the sequential path reports no prefetch activity; the
        // overlapped engine's ledger flows into the serve metrics
        assert_eq!(seq_m.prefetch_hits, 0);
        assert!(ov_m.prefetch_hits > 0, "overlap produced no prefetch hits");
        assert_eq!(ov_m.prefetch_hits, ov_rep.prefetch_hits);
        assert_eq!(ov_m.prefetch_rollbacks, ov_rep.prefetch_rollbacks);
        assert!(ov_rep.draft_hidden_s > 0.0);
        let reg = {
            let eng = SyntheticEngine::new(2, 1).with_overlap();
            let mut b = Batcher::new(eng, 4, replanner(), true).with_overlap();
            b.report.prefetch_hits = 5;
            b.report.draft_hidden_s = 0.25;
            b.collect_registry(1.0)
        };
        assert_eq!(reg.find("specactor_engine_prefetch_hits", &[]), Some(5.0));
        assert_eq!(reg.find("specactor_engine_draft_hidden_seconds_total", &[]), Some(0.25));
    }

    #[test]
    fn serves_everything_to_completion() {
        let mut b = mk_batcher(2, 16);
        for i in 0..5u64 {
            assert!(b.enqueue(req(i, 10), Priority::Batch, i as f64 * 0.01));
        }
        let mut now = 0.1;
        let mut guard = 0;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
            guard += 1;
            assert!(guard < 1000, "serve loop did not converge");
        }
        let fin = b.drain_finished();
        assert_eq!(fin.len(), 5);
        assert!(fin.iter().all(|f| f.req.generated() == 10));
        assert!(fin.iter().all(|f| f.finished_s >= f.arrival_s));
        assert_eq!(b.metrics.completed, 5);
        assert_eq!(b.metrics.tokens, 50);
        // capacity 2 with 5 requests: someone must have waited
        assert!(b.metrics.mean_queue_wait_s() > 0.0);
        assert_eq!(b.slots.high_water, 2);
    }

    #[test]
    fn occupancy_changes_trigger_replans() {
        let mut b = mk_batcher(4, 16);
        b.enqueue(req(0, 40), Priority::Batch, 0.0);
        let t1 = b.tick(0.0).unwrap();
        assert!(t1.replanned); // first plan establishment counts as applied
        assert_eq!(b.replan.plan.bucket, 1);
        // three more arrivals push occupancy 1 -> 4: bucket crossing
        for i in 1..4u64 {
            b.enqueue(req(i, 40), Priority::Batch, 0.1);
        }
        let t2 = b.tick(0.1).unwrap();
        assert!(t2.replanned);
        assert_eq!(t2.admitted, 3);
        assert_eq!(b.replan.plan.bucket, 4);
        let t3 = b.tick(0.2).unwrap();
        assert!(!t3.replanned);
    }

    #[test]
    fn replanned_method_is_applied_to_slots() {
        let mut b = mk_batcher(4, 16);
        b.enqueue(req(0, 40), Priority::Batch, 0.0);
        b.tick(0.0).unwrap();
        let applied = b.engine().slot_plan(0).unwrap();
        let planned = &b.replan.plan;
        if planned.window > 0 {
            assert_eq!(applied.method.label(), planned.method, "method must be applied");
            assert_eq!(applied.window, planned.window, "window must be applied");
        } else {
            assert!(applied.is_vanilla());
        }
    }

    #[test]
    fn synthetic_step_accounting_is_discipline_aware() {
        // 3 live slots on distinct plans (two spec groups + vanilla): a
        // grouped round launches 3 target steps, a fused round exactly 1.
        let mk = |d: VerifyDiscipline| {
            let mut e = SyntheticEngine::new(4, 3).with_discipline(d);
            e.admit(0, req(0, 8), SlotPlan::coupled(DraftMethod::Sam, 2)).unwrap();
            e.admit(1, req(1, 8), SlotPlan::decoupled(DraftMethod::Ngram, 4)).unwrap();
            e.admit(2, req(2, 8), SlotPlan::vanilla()).unwrap();
            e
        };
        let mut rep = EngineReport::default();
        mk(VerifyDiscipline::Grouped).round(&mut rep).unwrap();
        assert_eq!(rep.target_steps, 3, "grouped: G spec groups + vanilla");
        let mut rep = EngineReport::default();
        mk(VerifyDiscipline::Fused).round(&mut rep).unwrap();
        assert_eq!(rep.target_steps, 1, "fused: one step per round");
    }

    #[test]
    fn fused_bucket_crossings_keep_specialised_plans() {
        // Specialise slot 0's plan by hand, then push occupancy across a
        // bucket boundary. The fused serve loop must leave the special
        // plan in place; the grouped loop must reset it to the common
        // replanner plan.
        for d in [VerifyDiscipline::Fused, VerifyDiscipline::Grouped] {
            let mut b = Batcher::new(
                SyntheticEngine::new(4, 11).with_discipline(d),
                16,
                replanner(),
                true,
            );
            b.enqueue(req(0, 40), Priority::Batch, 0.0);
            b.tick(0.0).unwrap();
            let special = SlotPlan::coupled(DraftMethod::Sam, 5);
            b.engine.set_slot_plan(0, special.clone()).unwrap();
            for i in 1..4u64 {
                b.enqueue(req(i, 40), Priority::Batch, 0.1);
            }
            let tr = b.tick(0.1).unwrap();
            assert!(tr.replanned, "occupancy 1 -> 4 must cross a bucket");
            let now = b.engine.slot_plan(0).unwrap();
            match d {
                VerifyDiscipline::Fused => assert_eq!(
                    now, special,
                    "fused crossing must not herd the specialised slot"
                ),
                VerifyDiscipline::Grouped => assert_ne!(
                    now, special,
                    "grouped crossing must reset to the common plan"
                ),
            }
        }
    }

    #[test]
    fn burst_admissions_get_the_crossing_plan() {
        // A burst from idle crosses a replan bucket in the same tick that
        // admits it: replanning runs BEFORE the admissions (on the
        // occupancy they are about to produce), so every burst slot must
        // come out of the tick on the plan its own occupancy implies —
        // never the stale pre-burst plan.
        let mut b = mk_batcher(4, 16);
        for i in 0..4u64 {
            b.enqueue(req(i, 40), Priority::Batch, 0.0);
        }
        let tr = b.tick(0.0).unwrap();
        assert_eq!(tr.admitted, 4);
        assert!(tr.replanned, "occupancy 0 -> 4 must establish the bucket-4 plan");
        let want = b.replan.plan.clone();
        for slot in 0..4usize {
            let p = b.engine().slot_plan(slot).unwrap();
            if want.window > 0 {
                assert_eq!(p.window, want.window, "slot {slot} kept a stale window");
                assert_eq!(p.method.label(), want.method, "slot {slot} kept a stale method");
            } else {
                assert!(p.is_vanilla(), "slot {slot} should run vanilla at this occupancy");
            }
        }
    }

    #[test]
    fn tail_race_wins_and_everything_completes() {
        use crate::coordinator::race::RaceArbiter;
        // ids 0..2 accept ~0.85 under every method; id 3 is the tail
        // (0.2) whose hidden good method is sam. With racing enabled the
        // tail must be forked onto sam and the replica must win, without
        // duplicating or losing any request.
        let mut b = Batcher::new(SyntheticEngine::new(8, 99), 16, replanner(), true)
            .with_racing(RaceArbiter::synthetic());
        for i in 0..4u64 {
            b.enqueue(req(i, 40), Priority::Batch, 0.0);
        }
        let mut now = 0.0;
        let mut guard = 0;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
            guard += 1;
            assert!(guard < 2000, "racing serve loop did not converge");
        }
        assert!(b.metrics.races > 0, "the tail straggler was never raced");
        assert!(b.metrics.race_launches >= b.metrics.races);
        assert!(b.metrics.race_wins >= 1, "the sam replica must win the tail race");
        assert_eq!(b.metrics.race_wins_by_method.get("sam"), Some(&b.metrics.race_wins));
        assert!(b.metrics.race_cancelled_replicas > 0, "losing replicas must be cancelled");
        let mut done: Vec<u64> = b.drain_finished().iter().map(|f| f.req.id).collect();
        done.sort_unstable();
        assert_eq!(done, vec![0, 1, 2, 3], "races must not lose or duplicate requests");
        assert_eq!(b.metrics.completed, 4);
        assert_eq!(b.slots.occupancy(), 0, "race slots must all be freed");
        // every launched race ends exactly once: resolved or preempted
        assert_eq!(
            b.metrics.races,
            b.metrics.race_resolutions + b.metrics.race_preemptions,
            "race accounting must reconcile"
        );
    }

    #[test]
    fn admissions_preempt_racing_replicas() {
        use crate::coordinator::race::RaceArbiter;
        let mut b = Batcher::new(SyntheticEngine::new(4, 5), 16, replanner(), true)
            .with_racing(RaceArbiter::synthetic());
        // id 3 (tail) + id 0: occupancy 2 of 4 = at the race threshold
        b.enqueue(req(3, 40), Priority::Batch, 0.0);
        b.enqueue(req(0, 40), Priority::Batch, 0.0);
        b.tick(0.0).unwrap(); // admit + first round (acceptance evidence)
        let mut raced = 0;
        for i in 1..6 {
            raced += b.tick(i as f64 * 0.01).unwrap().raced;
        }
        assert!(raced > 0, "idle slots must be spent on the tail race");
        assert!(b.slots.is_full(), "replicas occupy the free slots");
        // a real request arrives while replicas hold every slot: the race
        // must be preempted so the admission goes through
        b.enqueue(req(8, 10), Priority::Batch, 0.1);
        let tr = b.tick(0.1).unwrap();
        assert_eq!(tr.admitted, 1, "preemption must free a slot for the admission");
        assert!(b.metrics.race_cancelled_replicas > 0);
        assert_eq!(b.race.as_ref().unwrap().active_races(), 0);
        let mut now = 0.2;
        let mut guard = 0;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
            guard += 1;
            assert!(guard < 2000, "post-preemption serving did not converge");
        }
        let done = b.drain_finished().len();
        assert_eq!(done, 3, "all three requests must complete");
        assert!(b.metrics.race_preemptions > 0, "the preempted race must be counted");
        assert_eq!(
            b.metrics.races,
            b.metrics.race_resolutions + b.metrics.race_preemptions,
            "race accounting must reconcile after preemption"
        );
    }

    #[test]
    fn priorities_jump_the_queue() {
        let mut b = mk_batcher(1, 16);
        b.enqueue(req(0, 6), Priority::Batch, 0.0);
        b.tick(0.0).unwrap(); // id 0 occupies the only slot
        b.enqueue(req(1, 6), Priority::Background, 0.1);
        b.enqueue(req(2, 6), Priority::Interactive, 0.2);
        let mut now = 0.3;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
        }
        let order: Vec<u64> = b.drain_finished().iter().map(|f| f.req.id).collect();
        assert_eq!(order, vec![0, 2, 1], "interactive must pass background");
    }

    #[test]
    fn vanilla_mode_generates_one_token_per_round() {
        let mut b = Batcher::new(SyntheticEngine::new(1, 7), 4, replanner(), false);
        b.enqueue(req(0, 5), Priority::Batch, 0.0);
        let mut ticks = 0;
        let mut now = 0.0;
        while !b.idle() {
            let tr = b.tick(now).unwrap();
            assert!(tr.generated <= 1);
            now += 0.01;
            ticks += 1;
        }
        assert_eq!(ticks, 6, "5 decode rounds + 1 retire tick");
    }

    #[test]
    fn reconfiguration_rewrites_straggler_plans() {
        use crate::coordinator::reconfig::Reconfigurator;
        // ids 0..2 accept at 0.85, id 3 at 0.2 (the synthetic tail skew):
        // the below-average tail must be re-planned by Algorithm 2 while
        // the batch drains, and serving must still complete everything.
        let mut b = mk_batcher(4, 16).with_reconfig(Reconfigurator::synthetic(2));
        for i in 0..4u64 {
            b.enqueue(req(i, 40), Priority::Batch, 0.0);
        }
        let mut now = 0.0;
        let mut reconfigured = 0usize;
        let mut guard = 0;
        while !b.idle() {
            let tr = b.tick(now).unwrap();
            reconfigured += tr.reconfigured;
            now += 0.01;
            guard += 1;
            assert!(guard < 2000, "serve loop did not converge");
        }
        assert!(reconfigured > 0, "Algorithm 2 never fired");
        assert!(b.metrics.reconfigs > 0);
        assert_eq!(b.metrics.reconfigured_slots as usize, reconfigured);
        assert_eq!(b.drain_finished().len(), 4, "reconfiguration must not lose requests");
    }

    #[test]
    fn open_loop_driver_fast_forwards_idle_gaps() {
        let mut b = mk_batcher(2, 8);
        let arrivals = vec![
            (0.0, req(0, 8), Priority::Batch),
            (0.0, req(1, 8), Priority::Batch),
            (1000.0, req(2, 8), Priority::Batch), // long idle gap
        ];
        let rep = drive_open_loop(&mut b, arrivals, Some(0.001)).unwrap();
        assert_eq!(rep.offered, 3);
        assert_eq!(rep.rejected, 0);
        assert_eq!(b.drain_finished().len(), 3);
        // the idle gap is skipped, not ticked through
        assert!(rep.ticks < 100, "driver spun through the idle gap: {} ticks", rep.ticks);
        assert!(rep.elapsed_s >= 1000.0);
    }

    #[test]
    fn open_loop_driver_counts_backpressure() {
        // queue of 1 and capacity 1: a burst of simultaneous arrivals sheds
        let mut b = mk_batcher(1, 1);
        let arrivals: Vec<(f64, Request, Priority)> =
            (0..6u64).map(|i| (0.0, req(i, 30), Priority::Batch)).collect();
        let rep = drive_open_loop(&mut b, arrivals, Some(0.001)).unwrap();
        assert!(rep.rejected > 0, "expected backpressure rejections");
        let done = b.drain_finished().len();
        assert_eq!(done + rep.rejected, 6);
        assert!(drive_open_loop(
            &mut b,
            vec![(1.0, req(9, 4), Priority::Batch), (0.5, req(10, 4), Priority::Batch)],
            Some(0.001)
        )
        .is_err(), "unsorted arrivals must error");
    }

    #[test]
    fn invalid_request_is_rejected_not_fatal() {
        // an engine that refuses one specific request at validation time:
        // the batcher must drop that request and keep serving the rest
        struct Picky(SyntheticEngine);
        impl ServeEngine for Picky {
            fn capacity(&self) -> usize {
                self.0.capacity()
            }
            fn validate(&self, req: &Request) -> Result<()> {
                if req.id == 1 {
                    bail!("bad prompt geometry")
                }
                Ok(())
            }
            fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
                self.0.admit(slot, req, plan)
            }
            fn retire(&mut self, slot: usize) -> Result<Request> {
                self.0.retire(slot)
            }
            fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
                self.0.round(rep)
            }
            fn is_done(&self, slot: usize) -> bool {
                self.0.is_done(slot)
            }
            fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
                self.0.slot_plan(slot)
            }
            fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
                self.0.set_slot_plan(slot, plan)
            }
        }
        let mut b = Batcher::new(Picky(SyntheticEngine::new(2, 5)), 8, replanner(), true);
        for i in 0..3u64 {
            b.enqueue(req(i, 6), Priority::Batch, 0.0);
        }
        let mut now = 0.0;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
        }
        let mut done: Vec<u64> = b.drain_finished().iter().map(|f| f.req.id).collect();
        done.sort_unstable();
        assert_eq!(done, vec![0, 2], "valid requests must still be served");
        assert_eq!(b.metrics.invalid, 1);
        assert_eq!(b.metrics.completed, 2);
    }

    #[test]
    fn failed_admission_does_not_leak_the_slot() {
        struct Failing(SyntheticEngine);
        impl ServeEngine for Failing {
            fn capacity(&self) -> usize {
                self.0.capacity()
            }
            fn admit(&mut self, _slot: usize, _req: Request, _plan: SlotPlan) -> Result<()> {
                bail!("prefill failed")
            }
            fn retire(&mut self, slot: usize) -> Result<Request> {
                self.0.retire(slot)
            }
            fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
                self.0.round(rep)
            }
            fn is_done(&self, slot: usize) -> bool {
                self.0.is_done(slot)
            }
            fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
                self.0.slot_plan(slot)
            }
            fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
                self.0.set_slot_plan(slot, plan)
            }
        }
        let mut b = Batcher::new(Failing(SyntheticEngine::new(2, 1)), 4, replanner(), true);
        b.enqueue(req(0, 4), Priority::Batch, 0.0);
        assert!(b.tick(0.0).is_err());
        assert_eq!(b.slots.occupancy(), 0, "slot leaked by failed admit");
    }

    /// SyntheticEngine wrapper that raises typed faults from `round`:
    /// one-shot faults keyed by round number, or the same fault every
    /// round (`every`). Faulted rounds never reach the inner engine, so
    /// no partial state is left behind — like the real injection sites.
    struct Faulty {
        e: SyntheticEngine,
        faults: Vec<(u64, SpecError)>,
        every: Option<SpecError>,
        rounds: u64,
    }

    impl Faulty {
        fn new(e: SyntheticEngine) -> Self {
            Faulty { e, faults: Vec::new(), every: None, rounds: 0 }
        }
    }

    impl ServeEngine for Faulty {
        fn capacity(&self) -> usize {
            self.e.capacity()
        }
        fn admit(&mut self, slot: usize, req: Request, plan: SlotPlan) -> Result<()> {
            self.e.admit(slot, req, plan)
        }
        fn retire(&mut self, slot: usize) -> Result<Request> {
            self.e.retire(slot)
        }
        fn round(&mut self, rep: &mut EngineReport) -> Result<usize> {
            self.rounds += 1;
            let now = self.rounds;
            if let Some(pos) = self.faults.iter().position(|(r, _)| *r == now) {
                let (_, se) = self.faults.remove(pos);
                return Err(se.into());
            }
            if let Some(se) = &self.every {
                return Err(se.clone().into());
            }
            self.e.round(rep)
        }
        fn is_done(&self, slot: usize) -> bool {
            self.e.is_done(slot)
        }
        fn slot_plan(&self, slot: usize) -> Option<SlotPlan> {
            self.e.slot_plan(slot)
        }
        fn set_slot_plan(&mut self, slot: usize, plan: SlotPlan) -> Result<()> {
            self.e.set_slot_plan(slot, plan)
        }
        fn request(&self, slot: usize) -> Option<&Request> {
            self.e.request(slot)
        }
    }

    /// The synthetic token stream is a pure function of (id, position) —
    /// the whole point: any completed request must carry exactly this
    /// sequence, whatever faults were survived along the way.
    fn expected_seq(id: u64, prompt: &[i32], budget: usize) -> Vec<i32> {
        let mut seq = prompt.to_vec();
        for _ in 0..budget {
            let t = (id as i32).wrapping_mul(31).wrapping_add(seq.len() as i32) & 0x7fff;
            seq.push(t);
        }
        seq
    }

    fn drain_to_idle<E: ServeEngine>(b: &mut Batcher<E>, from_s: f64) -> Vec<FinishedRequest> {
        let mut now = from_s;
        let mut guard = 0;
        while !b.idle() {
            b.tick(now).unwrap();
            now += 0.01;
            guard += 1;
            assert!(guard < 3000, "serve loop did not converge");
        }
        b.drain_finished()
    }

    #[test]
    fn degradable_fault_degrades_to_vanilla_and_completes() {
        let mut f = Faulty::new(SyntheticEngine::new(2, 99));
        f.faults.push((2, SpecError::DraftCatchUp { slot: 0, detail: "lost".into() }));
        let mut b = Batcher::new(f, 8, replanner(), true);
        b.enqueue(req(0, 20), Priority::Batch, 0.0);
        b.enqueue(req(2, 20), Priority::Batch, 0.0);
        b.tick(0.0).unwrap(); // admit + round 1
        b.tick(0.01).unwrap(); // round 2 faults: slot 0 degrades
        assert_eq!(b.metrics.degradations, 1);
        assert!(b.engine().slot_plan(0).unwrap().is_vanilla(), "slot 0 must run vanilla");
        assert!(b.degrade_until[0].is_some(), "slot 0 must be in backoff");
        assert!(b.degrade_until[1].is_none(), "slot 1 is unaffected");
        let mut fin = drain_to_idle(&mut b, 0.02);
        fin.sort_by_key(|f| f.req.id);
        assert_eq!(fin.len(), 2, "the degraded request must still complete");
        for f in &fin {
            assert_eq!(f.req.seq, expected_seq(f.req.id, &[1, 2, 3, 4], 20), "tokens diverged");
        }
        assert_eq!(b.metrics.lost, 0);
    }

    #[test]
    fn batch_wide_degradable_fault_degrades_every_live_slot() {
        let mut f = Faulty::new(SyntheticEngine::new(4, 7));
        f.faults.push((2, SpecError::DrafterDead { detail: "thread died".into() }));
        let mut b = Batcher::new(f, 8, replanner(), true);
        for i in 0..3u64 {
            b.enqueue(req(i, 16), Priority::Batch, 0.0);
        }
        b.tick(0.0).unwrap();
        b.tick(0.01).unwrap(); // drafter dies: all three slots degrade
        assert_eq!(b.metrics.degradations, 3);
        for slot in 0..3 {
            assert!(b.engine().slot_plan(slot).unwrap().is_vanilla());
        }
        let fin = drain_to_idle(&mut b, 0.02);
        assert_eq!(fin.len(), 3, "fused verify must carry degraded slots to completion");
    }

    #[test]
    fn slot_fatal_fault_quarantines_and_preserves_tokens() {
        let mut f = Faulty::new(SyntheticEngine::new(1, 13));
        f.faults.push((3, SpecError::KvRowInvalid { slot: 0, detail: "row gone".into() }));
        let mut b = Batcher::new(f, 8, replanner(), true);
        b.enqueue(req(0, 24), Priority::Batch, 0.0);
        let fin = drain_to_idle(&mut b, 0.0);
        assert_eq!(fin.len(), 1, "quarantine must neither lose nor duplicate the request");
        assert_eq!(fin[0].req.seq, expected_seq(0, &[1, 2, 3, 4], 24), "verified output lost");
        assert_eq!(b.metrics.quarantines, 1);
        assert_eq!(b.metrics.requeues, 1);
        assert_eq!(b.metrics.recoveries, 1, "re-admission must be counted as a recovery");
        assert_eq!(b.queue.rejected, 0);
        assert_eq!(b.metrics.lost, 0);
    }

    #[test]
    fn retry_budget_exhaustion_is_a_typed_rejection() {
        let mut f = Faulty::new(SyntheticEngine::new(1, 3));
        f.every = Some(SpecError::KvRowInvalid { slot: 0, detail: "always".into() });
        let mut b = Batcher::new(f, 8, replanner(), true);
        b.enqueue(req(0, 10), Priority::Batch, 0.0);
        let fin = drain_to_idle(&mut b, 0.0);
        assert!(fin.is_empty(), "a permanently faulting slot cannot complete its request");
        // initial admission + retry_budget re-admissions, each quarantined
        assert_eq!(b.metrics.quarantines, b.retry_budget as u64 + 1);
        assert_eq!(b.metrics.requeues, b.retry_budget as u64);
        assert_eq!(b.queue.rejected_retry_exhausted, 1, "exhaustion must be a typed rejection");
        assert_eq!(b.queue.rejected, 1);
        assert!(b.retries.is_empty(), "rejection must clear the retry ledger");
    }

    #[test]
    fn degraded_slot_is_repromoted_after_backoff() {
        let mut b = mk_batcher(2, 8);
        b.enqueue(req(0, 60), Priority::Batch, 0.0);
        b.tick(0.0).unwrap();
        b.degrade_slot(0).unwrap();
        assert_eq!(b.metrics.degradations, 1);
        assert_eq!(b.degrade_until[0], Some(b.ticks + 2), "first backoff is 2 ticks");
        let spec_planned = b.replan.plan.window > 0;
        let mut now = 0.01;
        for _ in 0..3 {
            b.tick(now).unwrap();
            now += 0.01;
        }
        assert!(b.degrade_until[0].is_none(), "backoff must expire");
        if spec_planned {
            assert_eq!(b.metrics.repromotions, 1, "the slot must retry speculation");
            assert!(!b.engine().slot_plan(0).unwrap().is_vanilla());
        }
        // a second degrade doubles the backoff
        b.degrade_slot(0).unwrap();
        assert_eq!(b.degrade_until[0], Some(b.ticks + 4), "second backoff is 4 ticks");
        drain_to_idle(&mut b, now);
        assert_eq!(b.metrics.completed, 1);
    }

    /// Replanner profiled so the ngram token drafter wins selection —
    /// the wave-global corpus seeds token drafters only, so these tests
    /// need the serve plan to actually carry one.
    fn ngram_replanner() -> Replanner {
        Replanner::new(
            CostModel::paper_32b(),
            vec![("ngram".to_string(), 0.90), ("draft_small".to_string(), 0.60)],
            vec![1, 2, 4],
            vec![1, 3, 7],
            7,
        )
    }

    /// A publisher corpus pre-warmed with one published segment.
    fn warm_corpus() -> DraftCorpus {
        let mut c = DraftCorpus::new();
        c.add_segment(&expected_seq(100, &[1, 2, 3, 4], 64));
        assert!(c.publish() > 0);
        assert!(c.is_warm());
        c
    }

    #[test]
    fn corpus_seeded_admissions_accept_better_with_identical_tokens() {
        let drive = |corpus: Option<DraftCorpus>| {
            let mut b = Batcher::new(SyntheticEngine::new(4, 99), 16, ngram_replanner(), true);
            if let Some(c) = corpus {
                b = b.with_corpus(c);
            }
            for i in 0..8u64 {
                assert!(b.enqueue(req(i, 24), Priority::Batch, 0.0));
            }
            let mut fins = drain_to_idle(&mut b, 0.0);
            fins.sort_by_key(|f| f.req.id);
            (fins, b.metrics.clone())
        };
        let (cold_fins, cold_m) = drive(None);
        let (warm_fins, warm_m) = drive(Some(warm_corpus()));
        // losslessness: seeding changes proposals and acceptance, never
        // the verified output (the tape is keyed by (seed, id, position))
        assert_eq!(cold_fins.len(), warm_fins.len());
        for (c, w) in cold_fins.iter().zip(&warm_fins) {
            assert_eq!(c.req.id, w.req.id);
            assert_eq!(c.req.seq, w.req.seq, "request {} diverged under seeding", c.req.id);
            assert_eq!(w.req.seq, expected_seq(w.req.id, &[1, 2, 3, 4], 24));
        }
        assert!(warm_m.corpus_seeds > 0, "warm token-drafter admissions must count as seeds");
        assert!(warm_m.corpus_publishes >= 1);
        assert!(warm_m.corpus_tokens > 0, "completions must be harvested and published");
        assert_eq!(cold_m.corpus_seeds, 0, "the cold run has no corpus at all");
        // acceptance-at-admission uplift: the seeded run converts a
        // strictly larger fraction of drafted tokens
        let rate = |m: &ServeMetrics| {
            let d: u64 = m.method_drafted.values().sum();
            let a: u64 = m.method_accepted.values().sum();
            assert!(d > 0, "speculative plans must have drafted");
            a as f64 / d as f64
        };
        assert!(
            rate(&warm_m) > rate(&cold_m),
            "seeded acceptance {:.3} must beat cold {:.3}",
            rate(&warm_m),
            rate(&cold_m)
        );
        // and the seeded wave drains no slower
        assert!(warm_m.rounds <= cold_m.rounds, "seeding must not cost rounds");
    }

    #[test]
    fn pause_decays_the_corpus_and_reseeds_from_live_slots() {
        use crate::serve::chaos::{ChaosEngine, FaultPlan};
        let plan = FaultPlan::parse("seed=3,pause=4").unwrap();
        let engine = ChaosEngine::new(SyntheticEngine::new(4, 99), plan);
        let mut b =
            Batcher::new(engine, 16, ngram_replanner(), true).with_corpus(warm_corpus());
        for i in 0..8u64 {
            assert!(b.enqueue(req(i, 24), Priority::Batch, 0.0));
        }
        let fins = drain_to_idle(&mut b, 0.0);
        assert_eq!(fins.len(), 8, "pauses must not lose requests");
        for f in &fins {
            assert_eq!(
                f.req.seq,
                expected_seq(f.req.id, &[1, 2, 3, 4], 24),
                "request {} diverged across the weight update",
                f.req.id
            );
        }
        assert!(b.metrics.corpus_decays >= 1, "pause=4 must decay the corpus");
        // the decay epoch plus the live-slot reseed republication (and
        // the pre-warm publish) all land on the publish counter
        assert!(b.metrics.corpus_publishes >= 3);
        assert!(b.metrics.corpus_tokens > 0, "reseed + completions must rewarm the corpus");
        assert_eq!(b.metrics.lost, 0);
        // the scrape carries the corpus family under both names
        let reg = b.collect_registry(1.0);
        assert!(reg.find("specactor_corpus_decays", &[]).unwrap() >= 1.0);
        assert_eq!(
            reg.find("specactor_corpus_seeds", &[]),
            reg.find("specactor_serve_corpus_seeds", &[]),
            "alias and mirror must agree"
        );
    }

    #[test]
    fn persisted_corpus_skips_decay_and_stays_lossless() {
        use crate::serve::chaos::{ChaosEngine, FaultPlan};
        // the stale-corpus control arm (benches/corpus_gain.rs): decay
        // disabled, so a pause leaves the pre-update snapshot standing
        // and new admissions seed stale — slower, but still lossless
        let plan = FaultPlan::parse("seed=3,pause=4").unwrap();
        let engine = ChaosEngine::new(SyntheticEngine::new(4, 99), plan);
        let mut b = Batcher::new(engine, 16, ngram_replanner(), true)
            .with_corpus(warm_corpus().persist_across_updates());
        for i in 0..8u64 {
            assert!(b.enqueue(req(i, 24), Priority::Batch, 0.0));
        }
        let fins = drain_to_idle(&mut b, 0.0);
        assert_eq!(fins.len(), 8);
        for f in &fins {
            assert_eq!(f.req.seq, expected_seq(f.req.id, &[1, 2, 3, 4], 24));
        }
        assert_eq!(b.metrics.corpus_decays, 0, "persist arm must never decay");
        assert!(b.engine().pauses >= 1, "the pause schedule must have fired");
    }
}
