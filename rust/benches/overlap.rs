//! Sequential vs overlapped fused-round execution (the overlapped
//! draft/verify tentpole A/B), written to `BENCH_overlap.json` (the
//! `BENCH_*.json` trajectory convention, see PERF.md §Overlapped
//! execution).
//!
//! Hermetic: the paper's analytic cost model prices one fused decoupled
//! round per grid cell under both schedules:
//!
//! * **sequential** — the pre-overlap engine: every round pays its window
//!   of drafts serially, then the ragged verify
//!   (`w·D(b) + verify_fused`, i.e. `with_overlap_eff(0.0)`);
//! * **overlapped** — the shipped `--overlap` engine: round R+1's drafts
//!   run on the prefetch thread while round R's verify is in flight, so
//!   only mis-speculated rounds pay drafting on the critical path
//!   (`(1 − h)·w·D(b) + verify_fused` with hit rate `h = p^w`, the
//!   probability the previous round fully accepted — the only case the
//!   stamped prefetch chunk is valid).
//!
//! The grid sweeps occupancy × per-token acceptance × window. The
//! in-bench acceptance criterion: overlapped ≤ sequential on EVERY cell
//! and strictly below on every `w ≥ 2` cell. A second, measured section
//! drives the overlapped [`SyntheticEngine`] to a drained batch per
//! occupancy and reports its actual prefetch hit rate and hidden-draft
//! seconds, and a simulated tracer timeline asserts the chrome-trace
//! shape: `PrefetchDraft`/`PrefetchKvH2d` spans concurrent with `Round`.

use std::path::Path;

use specactor::drafter::DraftMethod;
use specactor::engine::{EngineReport, Request, SlotPlan};
use specactor::obs::{chrome_trace, Phase, Tracer};
use specactor::planner::costmodel::CostModel;
use specactor::planner::tgs::step_up;
use specactor::serve::{ServeEngine, SyntheticEngine};
use specactor::util::benchkit::Bench;
use specactor::util::cli::Args;
use specactor::util::Json;

/// Lowered step-window grid (input positions per row) of the default AOT
/// artifact set.
const STEP_GRID: [usize; 4] = [1, 2, 4, 8];

/// Modelled fused-round latency at occupancy `b`, window `w`, with a
/// fraction `hidden` of rounds served from the prefetch mirror (drafting
/// off the critical path). `hidden = 0` is the sequential engine.
fn round_latency(m: &CostModel, b: usize, w: usize, hidden: f64) -> f64 {
    let serial = 1.0 - hidden.clamp(0.0, 1.0);
    serial * w as f64 * m.draft("ngram", b)
        + m.verify_fused(m.g_ref, (w + 1) as f64, step_up(&STEP_GRID, w + 1), b)
}

/// Expected accepted drafts per round at per-token acceptance `p` and
/// window `w`: `Σ_{i=1..w} p^i` (a draft lands only if every draft
/// before it landed).
fn expected_accepts(p: f64, w: usize) -> f64 {
    (1..=w).map(|i| p.powi(i as i32)).sum()
}

/// Drive the overlapped synthetic engine to a drained batch and report
/// (rounds, prefetch hits, rollbacks, hidden-draft seconds).
fn measured_overlap(n: usize, budget: usize, seed: u64) -> (u64, u64, u64, f64) {
    let mut e = SyntheticEngine::new(n, seed).with_overlap();
    for i in 0..n {
        let plan = SlotPlan::coupled(DraftMethod::Ngram, 4);
        e.admit(i, Request::new(i as u64, vec![0; 8], budget), plan).expect("admit");
    }
    let mut rep = EngineReport::default();
    let mut rounds = 0u64;
    while e.round(&mut rep).expect("round") > 0 {
        rounds += 1;
    }
    (rounds, rep.prefetch_hits, rep.prefetch_rollbacks, rep.draft_hidden_s)
}

/// Simulated overlapped-round timeline: one verify span with the next
/// round's prefetch draft + KV staging inside its window, then the
/// chrome-trace concurrency assertion the ISSUE names.
fn trace_shape_check() {
    let t = Tracer::new(64);
    t.begin_round(1);
    let t0 = t.now_us();
    // verify (Round) occupies [t0, t0+1000); the prefetch thread drafts
    // round 2 and stages its KV inside that window
    t.record_with_dur(Phase::Round, t0, 1000, 0);
    t.record_with_dur(Phase::PrefetchDraft, t0 + 100, 400, 0);
    t.record_with_dur(Phase::PrefetchKvH2d, t0 + 500, 200, 0);
    let events = t.events();
    let round = events.iter().find(|e| e.phase == Phase::Round).expect("round span");
    for p in [Phase::PrefetchDraft, Phase::PrefetchKvH2d] {
        let s = events.iter().find(|e| e.phase == p).expect("prefetch span");
        let concurrent = s.t_start_us < round.t_start_us + round.dur_us
            && s.t_start_us + s.dur_us > round.t_start_us;
        assert!(concurrent, "{} span must overlap the verify window", p.label());
    }
    let j = chrome_trace(&events, &[]);
    let parsed = Json::parse(&j.to_string()).expect("chrome trace is valid JSON");
    let names: Vec<String> = parsed
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents")
        .iter()
        .filter_map(|e| e.get("name").as_str().map(str::to_string))
        .collect();
    assert!(names.iter().any(|n| n == Phase::PrefetchDraft.label()));
    assert!(names.iter().any(|n| n == Phase::PrefetchKvH2d.label()));
}

fn main() {
    let mut args = Args::from_env().unwrap();
    let budget = args.opt_parse("budget", 48usize);
    let seed = args.opt_parse("seed", 7u64);
    let json_out = args.opt("json-out", "BENCH_overlap.json");
    args.finish().unwrap();

    trace_shape_check();

    let m = CostModel::paper_32b();
    let mut bench = Bench::new(0, 1);
    let mut extra: Vec<Vec<(&str, Json)>> = Vec::new();

    for b in [2usize, 4, 8, 16] {
        for &p in &[0.3f64, 0.6, 0.85, 0.95] {
            for w in [1usize, 2, 4] {
                let hidden = p.powi(w as i32); // prev-round full-accept rate
                let seq = round_latency(&m, b, w, 0.0);
                let ovl = round_latency(&m, b, w, hidden);
                let toks = 1.0 + expected_accepts(p, w);
                let tgs_seq = toks * b as f64 / seq;
                let tgs_ovl = toks * b as f64 / ovl;
                // acceptance criterion: overlap never loses, and wins
                // outright wherever there is a window worth hiding
                assert!(
                    ovl <= seq,
                    "b={b} p={p} w={w}: overlapped round above sequential"
                );
                if w >= 2 {
                    assert!(
                        ovl < seq,
                        "b={b} p={p} w={w}: overlapped round not strictly below"
                    );
                }
                let speedup = seq / ovl;
                println!(
                    "b={b:<3} p={p:<5} w={w}  round {seq:>9.6}s -> {ovl:>9.6}s  \
                     ({speedup:.3}x)  hidden {hidden:.3}  tgs {tgs_seq:>7.1} -> {tgs_ovl:>7.1}"
                );
                bench.record(&format!("overlap b={b} p={p} w={w}"), ovl);
                extra.push(vec![
                    ("occupancy", Json::num(b as f64)),
                    ("acceptance", Json::num(p)),
                    ("window", Json::num(w as f64)),
                    ("hidden_frac", Json::num(hidden)),
                    ("round_sequential_s", Json::num(seq)),
                    ("round_overlapped_s", Json::num(ovl)),
                    ("speedup", Json::num(speedup)),
                    ("tgs_sequential", Json::num(tgs_seq)),
                    ("tgs_overlapped", Json::num(tgs_ovl)),
                ]);
            }
        }
    }

    // measured section: the shipped overlapped engine's own ledger
    for n in [2usize, 4, 8, 16] {
        let (rounds, hits, rollbacks, hidden_s) = measured_overlap(n, budget, seed);
        assert!(hits > 0, "n={n}: overlapped engine never hit its prefetch");
        assert!(hidden_s > 0.0, "n={n}: no draft time hidden");
        let hit_rate = hits as f64 / rounds.max(1) as f64;
        println!(
            "measured n={n:<3} rounds {rounds:>4}  hits {hits:>4} ({hit_rate:.3})  \
             rollbacks {rollbacks:>4}  hidden {hidden_s:.6}s"
        );
        // the extra fields merge per-index onto recorded rows, so the
        // measured section records its hidden-draft seconds as the series
        bench.record(&format!("measured overlap n={n} budget={budget}"), hidden_s);
        extra.push(vec![
            ("measured_occupancy", Json::num(n as f64)),
            ("measured_rounds", Json::num(rounds as f64)),
            ("measured_prefetch_hits", Json::num(hits as f64)),
            ("measured_hit_rate", Json::num(hit_rate)),
            ("measured_rollbacks", Json::num(rollbacks as f64)),
            ("measured_hidden_s", Json::num(hidden_s)),
        ]);
    }

    bench
        .write_json(Path::new(&json_out), "overlap", &extra)
        .expect("write BENCH_overlap.json");
    println!("wrote {json_out}");
}
