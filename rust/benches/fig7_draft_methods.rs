//! Figure 7: characterization of per-request speedup by draft method on
//! the DAPO trace — which fraction of requests each method wins.
use specactor::ladder::Ladder;
use specactor::sim::{gen_step_requests, TraceConfig};
use specactor::util::cli::Args;
use specactor::util::Rng;

fn main() {
    let mut args = Args::from_env().unwrap();
    args.finish().unwrap();
    let cfg = TraceConfig::dapo_32b_20k();
    let m = cfg.cost_model();
    let mut rng = Rng::new(11);
    let reqs = gen_step_requests(&cfg, 140, &mut rng);
    let ladder = Ladder::build(&m, 8, 4, &cfg.profiled_acceptance());

    let mut wins = std::collections::BTreeMap::<String, usize>::new();
    let mut speedup_sum = std::collections::BTreeMap::<String, f64>::new();
    for r in reqs.iter().take(4096) {
        let mut best = ("", f64::MIN);
        for (meth, p) in &r.accept {
            // per-request speedup of this method at its true acceptance
            let s = specactor::planner::tgs::tgs_coupled(&m, meth, 4, 4, 8, *p)
                / specactor::planner::tgs::tgs_vanilla(&m, 8);
            *speedup_sum.entry(meth.clone()).or_default() += s;
            if s > best.1 {
                best = (meth, s);
            }
        }
        *wins.entry(best.0.to_string()).or_default() += 1;
    }
    println!("== Fig 7 — best draft method per request (DAPO-32B-20K, 4096 reqs) ==");
    let n: usize = wins.values().sum();
    for (meth, c) in &wins {
        println!(
            "{:<14} wins {:>5.1}%   mean speedup {:.2}x (ladder rank {})",
            meth,
            *c as f64 / n as f64 * 100.0,
            speedup_sum[meth] / 4096.0,
            ladder.rank_of(meth)
        );
    }
    println!("(paper: most requests prefer 0.5B, some 1.5B, some n-gram)");
}
